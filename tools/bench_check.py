#!/usr/bin/env python
"""Bench regression gate: compare a fresh ``BENCH_online.json`` (written by
``benchmarks/online_throughput.py``, plus the ``engine_decode``,
``http_serving`` and ``robustness`` sections merged in by
``benchmarks/engine_decode.py``, ``benchmarks/http_serving.py`` and
``benchmarks/robustness.py``) against the committed baseline.

Usage::

    python tools/bench_check.py [CURRENT] [BASELINE] [--update-baseline]

Defaults: ``results/bench/BENCH_online.json`` vs.
``benchmarks/baselines/BENCH_online.json``.  ``--update-baseline`` copies the
current run over the baseline (after an intentional serving-plane change —
commit the result) instead of comparing.

What is compared, and how:

* **schema + config** must match exactly — a drifted schema or changed run
  parameters makes the numbers incomparable, which is its own failure
  (exit 2), distinct from a regression (exit 1).
* **deterministic counters** (completed, submitted, dropped, tripped flags,
  autoscale peak/end replica counts, engine token/step/dispatch counts) must
  match exactly: the virtual-clock simulator streams and the greedy engine
  runs are seeded, so any drift is a behaviour change.
* **continuous metrics** (sustained QPS, p50/p99, cost, deferral/packing and
  pressure counts, engine tokens/s and admission latency) are compared with
  per-metric relative tolerances — loose enough to absorb float/library (and,
  for the wall-clock engine rates, hardware) drift across runners, tight
  enough to catch a real serving-plane regression.

Wall-clock fields are never compared (CI machines vary).  The CI ``bench``
job runs this BLOCKING; each failure class carries a distinct GitHub
annotation (``::error title=...``) so a red job is attributable at a glance:

* ``bench-missing``      — current run or baseline file absent (exit 2)
* ``bench-incomparable`` — schema/config mismatch; regenerate the baseline
  (exit 2)
* ``bench-regression``   — metrics outside tolerance (exit 1)
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys

# metric -> relative tolerance; anything not listed here (and not in EXACT)
# is ignored (e.g. wall_s).  Bands are sized for cross-runner noise (GitHub
# hosted runners vary widely in speed and BLAS builds); the seeded counters
# in EXACT are the behaviour-change tripwire, these catch real drift.
TOLERANCES = {
    "sustained_qps": 0.25,
    "p50_s": 0.40,
    "p99_s": 0.40,
    "fixed_p99_s": 0.40,
    "defer_p99_s": 0.40,
    "pack_qps": 0.25,
    "defer_qps": 0.25,
    "mean_utility": 0.20,
    "cost": 0.25,
    "budget_allowance": 0.15,
    "cache_hits": 0.25,
    "deferred": 0.75,
    "capacity_deferred": 0.50,
    "capacity_packed": 0.50,
    "cap_packed": 0.50,
    "capacity_held": 0.50,
    "pack_held": 0.50,
    "defer_held": 0.50,
    "fixed_pressure": 0.50,
    "auto_pressure": 0.50,
    "n_scale_events": 0.50,
    "reroutes": 0.50,
    "replica_failures": 0.50,
    "replica_ejections": 0.50,
    # engine_decode: wall-clock rates vary with runner hardware — bands are
    # wide; the seeded counters in EXACT (and the >= 3x assert inside the
    # benchmark itself) are the real tripwire.  "speedup" is deliberately
    # ungated: it is derivable from the two tokens_per_s rows already gated,
    # and a separate relative band would quietly demand more than the
    # benchmark's own >= 3x contract
    "tokens_per_s": 0.75,
    "batched_ms": 0.75,
    "sequential_ms": 0.75,
    # http_serving: loopback-HTTP wall-clock rates and latencies — dominated
    # by runner speed and thread scheduling; the exact counters (completed,
    # total_chunks — the >= 2-chunks-per-stream wire contract) are the
    # tripwire, these catch order-of-magnitude drift
    "qps": 0.80,
    "latency_p50_s": 0.80,
    "latency_p99_s": 0.80,
    "ttfc_p50_s": 0.80,
    # semcache_sweep: the hit counters are EXACT (seeded stream, deterministic
    # embedding space); these bands absorb float drift in the utility/cost
    # accounting the hits feed into
    "hit_rate": 0.25,
    "utility_loss": 0.30,
    "eps_bound": 0.25,
    "cost_saved": 0.50,
    # robustness: the seeded robust-λ sweep is deterministic modulo BLAS
    # float drift in the fitted utilities — the exact flags
    # (within_worst_case, beats_point_estimate, lam0_identical) and the
    # hang/timeout/ejection counters are the tripwire, these absorb drift
    "est_utility": 0.20,
    "amortized_cost": 0.25,
    "realized_utility": 0.30,
    "upgrades": 0.25,
}
# counter metrics sit near 0 in healthy baselines, where a purely relative
# band degenerates to [0, 0]; the tolerance is taken over max(|baseline|,
# this floor) so a one-count float-drift flip never reads as a regression
ABS_FLOOR = {
    "cache_hits": 8,
    "deferred": 8,
    "capacity_deferred": 20,
    "capacity_packed": 20,
    "cap_packed": 20,
    "capacity_held": 20,
    "pack_held": 20,
    "defer_held": 20,
    "fixed_pressure": 20,
    "auto_pressure": 20,
    "n_scale_events": 4,
    "reroutes": 4,
    "replica_failures": 4,
    "replica_ejections": 2,
    # loopback latencies sit in the low-milliseconds on fast runners, where a
    # relative band is narrower than OS scheduling jitter
    "latency_p50_s": 0.2,
    "latency_p99_s": 0.5,
    "ttfc_p50_s": 0.2,
    "hit_rate": 0.05,
    "utility_loss": 1.0,
    "cost_saved": 1e-5,
}
EXACT = {"completed", "submitted", "dropped", "tripped", "breaker_tripped",
         "replicas", "window_s", "phase", "max_replicas", "end_replicas",
         "slots", "k", "path", "steps", "dispatches", "prefills",
         "gen_tokens", "n_requests",
         # paged-KV leg: memory footprint and allocator counters are pure
         # functions of the seeded greedy run — any drift is a layout or
         # sharing behaviour change, not runner noise
         "peak_kv_bytes", "page_size", "peak_pages", "prefix_shares",
         "cow_forks",
         # speculative-decode leg: per-run round/draft/accept counters of the
         # seeded trained-pair greedy run — drift means the draft/verify
         # behaviour (or the training recipe feeding it) changed.
         # "accept_rate" and "speedup" are deliberately ungated: both are
         # derivable from fields already compared
         "rounds", "drafted", "accepted", "bonus",
         # http_serving: wire-contract counters — every request must complete
         # and every stream must carry exactly 2 content chunks on the
         # deterministic simulated pool; any drift is a framing/demux change
         "scenario", "mode", "clients", "total_chunks",
         # semcache_sweep: seeded near-dup stream over a deterministic
         # embedding space — hit/miss/insert counts and the off-vs-inf
         # bit-identity flag are behaviour-change tripwires
         "sim_threshold", "sem_hits", "sem_misses", "sem_insertions",
         "off_identical",
         # robustness: per-member autoscale event counters (ONLY the
         # bottleneck member may carry events), the robust-walk contract
         # flags, and the hung-replica fault counters — the scripted burst
         # and the seeded chaos schedule make every one deterministic
         "leg", "lam", "member", "events_up", "events_down", "cost_margin",
         "within_worst_case", "beats_point_estimate", "lam0_identical",
         "hangs", "timeouts", "ejections", "breaker_closed"}

UPDATE_HINT = ("if the change is intentional, refresh the baseline: "
               "BENCH_QUICK=1 python benchmarks/online_throughput.py "
               "--pool sim --duration 10 && "
               "BENCH_QUICK=1 python benchmarks/engine_decode.py && "
               "BENCH_QUICK=1 python benchmarks/http_serving.py && "
               "BENCH_QUICK=1 python benchmarks/robustness.py && "
               "python tools/bench_check.py --update-baseline "
               "(then commit benchmarks/baselines/BENCH_online.json)")


def _annotate(kind: str, msg: str) -> None:
    """One-line GitHub Actions annotation; a distinct ``title`` per failure
    class lets CI distinguish mismatch / regression / missing at a glance
    (plain greppable output locally)."""
    first = msg.splitlines()[0]
    print(f"::error title={kind}::{first}")


def _rows(section):
    return section if isinstance(section, list) else [section]


def _key(row: dict) -> tuple:
    # window_s/replicas/phase key the online sections; slots/k/path key the
    # engine_decode sweep; mode/clients key the http_serving matrix;
    # sim_threshold keys the semcache sweep; leg/lam/member key the
    # robustness rows (absent fields stay None, so keys never collide
    # across sections)
    return (row.get("window_s"), row.get("replicas"), row.get("phase"),
            row.get("slots"), row.get("k"), row.get("path"),
            row.get("mode"), row.get("clients"),
            repr(row.get("sim_threshold")),
            row.get("leg"), row.get("lam"), row.get("member"))


def compare(current: dict, baseline: dict) -> list[str]:
    problems: list[str] = []
    if current.get("schema") != baseline.get("schema"):
        return [f"schema mismatch: current={current.get('schema')} "
                f"baseline={baseline.get('schema')} (regenerate the baseline)"]
    if current.get("config") != baseline.get("config"):
        return [f"config mismatch (numbers not comparable):\n"
                f"  current : {current.get('config')}\n"
                f"  baseline: {baseline.get('config')}"]
    sections = sorted(set(baseline) - {"schema", "config"})
    for sec in sections:
        cur_rows = {_key(r): r for r in _rows(current.get(sec, []))}
        for base_row in _rows(baseline[sec]):
            where = f"{sec}[{_key(base_row)}]"
            cur = cur_rows.get(_key(base_row))
            if cur is None:
                problems.append(f"{where}: row missing from current run")
                continue
            for metric, base_v in base_row.items():
                if metric not in cur:
                    problems.append(f"{where}.{metric}: missing from current run")
                    continue
                cur_v = cur[metric]
                if metric in EXACT:
                    if cur_v != base_v:
                        problems.append(f"{where}.{metric}: {cur_v!r} != "
                                        f"baseline {base_v!r} (exact)")
                elif metric in TOLERANCES:
                    tol = TOLERANCES[metric]
                    span = tol * max(abs(base_v), ABS_FLOOR.get(metric, 0.0))
                    lo, hi = base_v - span, base_v + span
                    if not (lo - 1e-12 <= cur_v <= hi + 1e-12):
                        problems.append(
                            f"{where}.{metric}: {cur_v:.6g} outside "
                            f"[{lo:.6g}, {hi:.6g}] (baseline {base_v:.6g} "
                            f"± {tol:.0%})")
    return problems


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="online-serving bench regression gate")
    ap.add_argument("current", nargs="?",
                    default="results/bench/BENCH_online.json")
    ap.add_argument("baseline", nargs="?",
                    default="benchmarks/baselines/BENCH_online.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="copy CURRENT over BASELINE (intentional change) "
                         "instead of comparing")
    args = ap.parse_args(argv[1:])
    try:
        with open(args.current) as f:
            current = json.load(f)
    except OSError as e:
        print(f"bench_check: cannot read current run {args.current}: {e}")
        _annotate("bench-missing", f"current bench run not found: {args.current}")
        return 2
    if args.update_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"bench_check: baseline updated — {args.current} -> "
              f"{args.baseline}; commit it with the serving-plane change")
        return 0
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except OSError as e:
        print(f"bench_check: cannot read baseline {args.baseline}: {e}")
        _annotate("bench-missing", f"committed baseline not found: "
                                   f"{args.baseline}")
        return 2
    problems = compare(current, baseline)
    if not problems:
        print(f"bench_check: OK — {args.current} within tolerance of "
              f"{args.baseline}")
        return 0
    schema_issue = any("mismatch" in p for p in problems[:1])
    print(f"bench_check: {len(problems)} problem(s) vs {args.baseline}:")
    for p in problems:
        print(f"  - {p}")
    print(f"bench_check: {UPDATE_HINT}")
    if schema_issue:
        _annotate("bench-incomparable",
                  f"bench schema/config drifted — numbers not comparable; "
                  f"{UPDATE_HINT}")
        return 2
    _annotate("bench-regression",
              f"{len(problems)} metric(s) outside tolerance of the committed "
              f"baseline; {UPDATE_HINT}")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
