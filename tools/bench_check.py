#!/usr/bin/env python
"""Bench regression gate: compare a fresh ``BENCH_online.json`` (written by
``benchmarks/online_throughput.py``) against the committed baseline.

Usage::

    python tools/bench_check.py [CURRENT] [BASELINE]

Defaults: ``results/bench/BENCH_online.json`` vs.
``benchmarks/baselines/BENCH_online.json``.

What is compared, and how:

* **schema + config** must match exactly — a drifted schema or changed run
  parameters makes the numbers incomparable, which is its own failure
  (exit 2), distinct from a regression (exit 1).
* **deterministic counters** (completed, submitted, dropped, tripped flags)
  must match exactly: the virtual-clock simulator streams are seeded, so any
  drift is a behaviour change.
* **continuous metrics** (sustained QPS, p50/p99, cost, deferral counts) are
  compared with per-metric relative tolerances — loose enough to absorb
  float/library drift across environments, tight enough to catch a real
  serving-plane regression.

Wall-clock fields are never compared (CI machines vary).  The CI job runs
this non-blocking (the bench job uploads both files as artifacts); run it
locally after touching the serving plane.
"""
from __future__ import annotations

import json
import sys

# metric -> relative tolerance; anything not listed here (and not in EXACT)
# is ignored (e.g. wall_s)
TOLERANCES = {
    "sustained_qps": 0.15,
    "p50_s": 0.25,
    "p99_s": 0.25,
    "mean_utility": 0.15,
    "cost": 0.15,
    "budget_allowance": 0.10,
    "cache_hits": 0.25,
    "deferred": 0.50,
    "capacity_deferred": 0.50,
    "reroutes": 0.50,
    "replica_failures": 0.50,
    "replica_ejections": 0.50,
}
# counter metrics sit near 0 in healthy baselines, where a purely relative
# band degenerates to [0, 0]; the tolerance is taken over max(|baseline|,
# this floor) so a one-count float-drift flip never reads as a regression
ABS_FLOOR = {
    "cache_hits": 8,
    "deferred": 8,
    "capacity_deferred": 20,
    "reroutes": 4,
    "replica_failures": 4,
    "replica_ejections": 2,
}
EXACT = {"completed", "submitted", "dropped", "tripped", "breaker_tripped",
         "replicas", "window_s"}


def _rows(section):
    return section if isinstance(section, list) else [section]


def _key(row: dict) -> tuple:
    return (row.get("window_s"), row.get("replicas"))


def compare(current: dict, baseline: dict) -> list[str]:
    problems: list[str] = []
    if current.get("schema") != baseline.get("schema"):
        return [f"schema mismatch: current={current.get('schema')} "
                f"baseline={baseline.get('schema')} (regenerate the baseline)"]
    if current.get("config") != baseline.get("config"):
        return [f"config mismatch (numbers not comparable):\n"
                f"  current : {current.get('config')}\n"
                f"  baseline: {baseline.get('config')}"]
    sections = sorted(set(baseline) - {"schema", "config"})
    for sec in sections:
        cur_rows = {_key(r): r for r in _rows(current.get(sec, []))}
        for base_row in _rows(baseline[sec]):
            where = f"{sec}[{_key(base_row)}]"
            cur = cur_rows.get(_key(base_row))
            if cur is None:
                problems.append(f"{where}: row missing from current run")
                continue
            for metric, base_v in base_row.items():
                if metric not in cur:
                    problems.append(f"{where}.{metric}: missing from current run")
                    continue
                cur_v = cur[metric]
                if metric in EXACT:
                    if cur_v != base_v:
                        problems.append(f"{where}.{metric}: {cur_v!r} != "
                                        f"baseline {base_v!r} (exact)")
                elif metric in TOLERANCES:
                    tol = TOLERANCES[metric]
                    span = tol * max(abs(base_v), ABS_FLOOR.get(metric, 0.0))
                    lo, hi = base_v - span, base_v + span
                    if not (lo - 1e-12 <= cur_v <= hi + 1e-12):
                        problems.append(
                            f"{where}.{metric}: {cur_v:.6g} outside "
                            f"[{lo:.6g}, {hi:.6g}] (baseline {base_v:.6g} "
                            f"± {tol:.0%})")
    return problems


def main(argv: list[str]) -> int:
    cur_path = argv[1] if len(argv) > 1 else "results/bench/BENCH_online.json"
    base_path = argv[2] if len(argv) > 2 else "benchmarks/baselines/BENCH_online.json"
    try:
        with open(cur_path) as f:
            current = json.load(f)
    except OSError as e:
        print(f"bench_check: cannot read current run {cur_path}: {e}")
        return 2
    try:
        with open(base_path) as f:
            baseline = json.load(f)
    except OSError as e:
        print(f"bench_check: cannot read baseline {base_path}: {e}")
        return 2
    problems = compare(current, baseline)
    if not problems:
        print(f"bench_check: OK — {cur_path} within tolerance of {base_path}")
        return 0
    schema_issue = any("mismatch" in p for p in problems[:1])
    print(f"bench_check: {len(problems)} problem(s) vs {base_path}:")
    for p in problems:
        print(f"  - {p}")
    return 2 if schema_issue else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
