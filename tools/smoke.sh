#!/usr/bin/env bash
# CI-friendly smoke target: exercises the entry points end-to-end with
# shrunken instances —
#   1. the offline RoBatch pipeline on the calibrated simulator (quickstart,
#      driven through the RunSpec/Gateway control-plane API),
#   2. the REAL tiny pool (src/repro/configs/tiny_pool.py) trained under a
#      small step count, scheduled offline AND streamed online,
#   3. the online serving CLI over the simulator, ONCE PER REGISTERED POLICY
#      (repro.api.list_policies()) — a policy that registers but crashes at
#      plan time fails smoke,
#   4. the HTTP front-end: boot `serve http` on an ephemeral port, curl a
#      streamed completion and /metrics, SIGTERM, assert a clean shutdown.
# Wired into the suite as a slow-marked test:
#   PYTHONPATH=src python -m pytest -m slow tests/test_smoke.py
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# pin jax to the host CPU backend: with a bundled libtpu, default backend
# discovery probes for TPU hardware and can block indefinitely in containers
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python examples/quickstart.py agnews qwen3 \
    --n-train 192 --n-val 48 --n-test 96 --coreset 32

python examples/serve_pool.py --steps "${SMOKE_STEPS:-60}" \
    --n-train 16 --n-test 16 --coreset 8 \
    --online-seconds 4 --online-qps 4

POLICIES=$(python -c "import repro.api; print(' '.join(repro.api.list_policies()))")
for policy in $POLICIES; do
    python -m repro.launch.serve online --policy "$policy" \
        --qps 20 --duration 5 --n-train 128 --coreset 32
done

# real-time plane: wall-clock pacing behind a live arrival thread, across
# 2-replica members (capacity caps + least-loaded dispatch)
python -m repro.launch.serve online --realtime --duration 3 --qps 10 \
    --n-train 128 --coreset 32 --replicas 2

# semantic cache: embedding-space near-duplicate hits priced at u·(1−ε(sim))
# (docs/caching.md) — the launcher must print its hit/miss summary line
SEM_OUT=$(python -m repro.launch.serve online --semantic-cache \
    --sim-threshold 0.85 --qps 20 --duration 5 --n-train 128 --coreset 32)
echo "$SEM_OUT"
echo "$SEM_OUT" | grep -q "^semcache: hits="

# chaos leg: latency noise on every member plus a short error burst on the
# most expensive one (docs/robustness.md) — the burst stays below the
# breaker threshold, so the launcher must print the fault-count marker and
# report every breaker still CLOSED while the window loop retries the work
CHAOS_OUT=$(python -m repro.launch.serve online --chaos 7 \
    --qps 20 --duration 5 --n-train 128 --coreset 32)
echo "$CHAOS_OUT"
echo "$CHAOS_OUT" | grep -q "^chaos: seed=7"
echo "$CHAOS_OUT" | grep -q "breakers_closed=True"

# HTTP front-end: ephemeral port, one streamed SSE completion + /metrics via
# curl, then SIGTERM — the launcher must report a clean shutdown
HTTP_LOG=$(mktemp)
python -m repro.launch.serve http --port 0 --n-train 128 --coreset 32 \
    --window 0.05 >"$HTTP_LOG" 2>&1 &
HTTP_PID=$!
for _ in $(seq 1 120); do
    grep -q "listening on" "$HTTP_LOG" && break
    kill -0 "$HTTP_PID" 2>/dev/null || { cat "$HTTP_LOG"; exit 1; }
    sleep 1
done
PORT=$(sed -n 's/.*listening on http:\/\/127\.0\.0\.1:\([0-9]*\).*/\1/p' "$HTTP_LOG")
[ -n "$PORT" ] || { echo "smoke: no http port in launcher output"; cat "$HTTP_LOG"; exit 1; }
STREAM=$(curl -sS -N --max-time 60 "http://127.0.0.1:$PORT/v1/chat/completions" \
    -H 'Content-Type: application/json' \
    -d '{"messages":[{"role":"user","content":"#3"}],"stream":true}')
echo "$STREAM" | grep -q '"object":"chat.completion.chunk"'
echo "$STREAM" | grep -q 'data: \[DONE\]'
curl -sS --max-time 30 "http://127.0.0.1:$PORT/metrics" \
    | grep -q '^robatch_member_pressure{member='
kill -TERM "$HTTP_PID"
wait "$HTTP_PID"
cat "$HTTP_LOG"
grep -q "serve http: shutdown clean" "$HTTP_LOG"
rm -f "$HTTP_LOG"

echo "smoke: OK"
