#!/usr/bin/env bash
# CI-friendly smoke target: exercises the entry points end-to-end with
# shrunken instances —
#   1. the offline RoBatch pipeline on the calibrated simulator (quickstart,
#      driven through the RunSpec/Gateway control-plane API),
#   2. the REAL tiny pool (src/repro/configs/tiny_pool.py) trained under a
#      small step count, scheduled offline AND streamed online,
#   3. the online serving CLI over the simulator, ONCE PER REGISTERED POLICY
#      (repro.api.list_policies()) — a policy that registers but crashes at
#      plan time fails smoke.
# Wired into the suite as a slow-marked test:
#   PYTHONPATH=src python -m pytest -m slow tests/test_smoke.py
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# pin jax to the host CPU backend: with a bundled libtpu, default backend
# discovery probes for TPU hardware and can block indefinitely in containers
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python examples/quickstart.py agnews qwen3 \
    --n-train 192 --n-val 48 --n-test 96 --coreset 32

python examples/serve_pool.py --steps "${SMOKE_STEPS:-60}" \
    --n-train 16 --n-test 16 --coreset 8 \
    --online-seconds 4 --online-qps 4

POLICIES=$(python -c "import repro.api; print(' '.join(repro.api.list_policies()))")
for policy in $POLICIES; do
    python -m repro.launch.serve online --policy "$policy" \
        --qps 20 --duration 5 --n-train 128 --coreset 32
done

# real-time plane: wall-clock pacing behind a live arrival thread, across
# 2-replica members (capacity caps + least-loaded dispatch)
python -m repro.launch.serve online --realtime --duration 3 --qps 10 \
    --n-train 128 --coreset 32 --replicas 2

echo "smoke: OK"
