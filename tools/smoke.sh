#!/usr/bin/env bash
# CI-friendly smoke target: exercises the three entry points end-to-end with
# shrunken instances —
#   1. the offline RoBatch pipeline on the calibrated simulator (quickstart),
#   2. the REAL tiny pool (src/repro/configs/tiny_pool.py) trained under a
#      small step count, scheduled offline AND streamed online,
#   3. the online serving CLI over the simulator.
# Wired into the suite as a slow-marked test:
#   PYTHONPATH=src python -m pytest -m slow tests/test_smoke.py
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python examples/quickstart.py agnews qwen3 \
    --n-train 192 --n-val 48 --n-test 96 --coreset 32

python examples/serve_pool.py --steps "${SMOKE_STEPS:-60}" \
    --n-train 16 --n-test 16 --coreset 8 \
    --online-seconds 4 --online-qps 4

python -m repro.launch.serve online --qps 20 --duration 5 \
    --n-train 128 --coreset 32

echo "smoke: OK"
