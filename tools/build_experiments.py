import json, sys
sys.path.insert(0, "src")
from repro.analysis.report import dryrun_tables, roofline_table

PATH = "results/dryrun_final.json"
rows = json.load(open(PATH))
ok16 = [r for r in rows if r["status"]=="ok" and r["mesh"]=="16x16"]
ok512 = [r for r in rows if r["status"]=="ok" and r["mesh"]=="2x16x16"]

header = f"""# EXPERIMENTS

All dry-run artifacts: `results/dryrun_final.json` (post-§Perf code; the
pre-optimization baseline table is preserved in `results/dryrun.json`).
Regenerate: `PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both --out results/dryrun_final.json`.
Benchmarks: `PYTHONPATH=src python -m benchmarks.run` (per-figure JSON under `results/bench/`).

Hardware model (TPU v5e): 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link ICI.
Meshes: single pod 16×16 = 256 chips ("data","model"); multi-pod 2×16×16 = 512
chips ("pod","data","model"; pods are DP replicas).

## §Reproduction — paper-claims validation (faithful baseline)

The simulated pool is calibrated to §2's empirical studies; the *algorithm* under
test is the real Robatch implementation.  Claims checked (see benchmarks):

| Paper claim | Our measured result | Artifact |
|---|---|---|
| Routing beats single models on cost-accuracy (Fig. 2) | MLP/KNN router sweeps trace a frontier above the single-model points on AGNews/GSM8K | `results/bench/fig2_routing_impact.json` |
| Accuracy stable to a knee then collapses; small models collapse earlier (Fig. 3: 4B knee b≈16 AGNews / b≈8 GSM8K) | 4B accuracy halves at b=24 (AGNews) / b=8 (GSM8K); 14B/32B resilient ≥2× longer | `fig34_batching_impact.json` |
| Sys-prompt cost amortizes 1/b (Fig. 4: share 59.5%→8.4% AGNews, 90.1%→53.2% GSM8K) | measured shares 0.55→0.07 (AGNews b=1→16), 0.62→0.14 (GSM8K b=1→8).  GSM8K's b=1 share is below the paper's 90.1% because our billing uses a 1:4 output:input price ratio with difficulty-inflated CoT outputs; the amortization *shape* (÷8, ÷4.4) matches | same |
| RCU is V-shaped; ternary search finds b_effect cheaply (Fig. 5) | V-shape in all 6 tasks × 3 models; ~34 search probes vs ~100–135 exhaustive grid points | `fig5_rcu.json` |
| Robatch dominates adapted baselines' Pareto front (Fig. 7); gaps narrower on Gemma3/easy tasks | budget-matched Robatch non-dominated in 38/48 (79%) of (family, task, level) cells (71/96 counting both budget tags); losses concentrate exactly where the paper reports narrow gaps (gemma3 + easy classification at high budget) | `fig7_overall.json` |
| Joint > Router-Only and > Batch-Only, biggest at low/mid budget (Fig. 8) | low-budget accuracy: GSM8K 0.647 vs 0.564 (Router-Only) vs 0.610 (Batch-Only-mid); AGNews 0.813/0.783/0.797; curves converge at high budget as in the paper | `fig8_ablation.json` |
| Robust to coreset / embeddings / fit choice: differences ≤2% (Table 3, Fig. 9/10); KNN sensitive to k, k=1 clearly inferior | per-task mid-budget spreads: coreset method ≤0.018, coreset size ≤0.021, embeddings ≤0.029, scaling fit ≤0.040, MLP width ≤0.027; KNN k-sweep spread ≤0.081 with k=1 worst — matching the paper's sensitivity ordering | `table3_sensitivity.json` |
| Greedy scheduling dominates latency (76–86%), scales ~linearly (Fig. 11/12) | greedy 90–96% of routing-stage time; ≈linear growth 1k→16k queries; beyond-paper vectorized scheduler 4.6× faster at 16k queries (2.61→0.57 s) at parity 0.97–1.01 | `fig11/12 json` |
| NP-hardness reduction (Thm. 3.2) | max-coverage optimum ≡ constructed-instance optimum (brute-force equality, hypothesis-property-tested) | `tests/test_np_hardness.py` |

## §Dry-run — multi-pod compile results (post-§Perf code)

Every (architecture × applicable shape) cell lowered + compiled on both
production meshes: **{len(ok16)}/32 ok on 16×16 and {len(ok512)}/32 ok on 2×16×16 (8
`long_500k` cells per mesh are SKIP(full-attention) by assignment rule; 0 errors).**
`train_4k` lowers the full train step (fwd+bwd+AdamW update, grad accumulation,
ZeRO-1/FSDP shardings); `prefill_32k` the batched prefill with cache emission;
`decode_*` one token against a seq_len KV cache.

Memory columns: `tpu-est` removes XLA-**CPU** lowering artifacts that a TPU
build does not materialize (whole-stack f32 upcasts of bf16 dot operands —
MXU consumes bf16 natively — and loop-hoisted whole-stack FSDP all-gathers,
which TPU's scheduler keeps per-layer); `raw-cpu` is the uncorrected
memory_analysis of this CPU dry-run.  Known marginal cell: nemotron-4-340b
train_4k on a single pod is at the HBM edge even in theory (fp32 gradient
accumulation + moments for 340B on 256 × 16 GB chips); the multi-pod mesh
halves per-chip state and is the intended deployment for 340B training.

"""
tables = dryrun_tables(PATH)

roof = f"""

## §Roofline — per (arch × shape), single-pod 16×16 (post-§Perf code)

Terms (seconds/step, per chip): compute = HLO dot FLOPs / 197 TF/s;
memory = analytic HBM traffic / 819 GB/s (XLA-CPU 'bytes accessed' counts
unfused intermediates and is unusable; the analytic model's formulas are in
`repro/analysis/roofline.py` with constants documented inline); collective =
parsed per-device collective payload bytes / 50 GB/s, with the bf16-basis
value in parentheses (XLA-CPU upcasts bf16 payloads to f32; TPU moves bf16).
FLOPs and collective bytes are extracted from the optimized HLO with
while-loop trip-count multiplication (XLA's cost model counts loop bodies
once — verified).  `useful ratio` = MODEL_FLOPS / HLO FLOPs (6·N·D train,
2·N·D serve; N = active params for MoE) — values < 1 expose
remat/causal-waste/dispatch overhead; slightly > 1 means the 6ND convention
overcounts (GQA).  Decode/long cells are latency cells: per-step FLOPs are
tiny and the memory term (KV/state streaming) is the natural floor.

{roofline_table(PATH)}

"""
perf = open("tools/perf_section.md").read()
open("EXPERIMENTS.md","w").write(header + tables + roof + perf)
print("EXPERIMENTS.md rebuilt")
