import os

# Tests run on the single real CPU device (the dry-run sets its own 512-device
# flag in a subprocess; never set it globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest

# Hypothesis profile pinned for CI stability: no per-example deadline (hosted
# runners stall unpredictably under load — deadline flakes are pure noise)
# and derandomized so a red property test reproduces from the log.  CI sets
# REQUIRE_HYPOTHESIS=1, making a missing/broken hypothesis install a hard
# error instead of a silent skip of every property test (seed defect: four
# whole modules used to importorskip away).
try:
    from hypothesis import settings as _hyp_settings
    _hyp_settings.register_profile("ci", deadline=None, derandomize=True)
    _hyp_settings.load_profile("ci")
except ImportError:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise RuntimeError(
            "REQUIRE_HYPOTHESIS=1 but hypothesis is not importable — "
            "property tests would silently skip; fix the CI install")

from repro.data import make_simulated_pool, make_workload


@pytest.fixture(scope="session")
def agnews():
    return make_workload("agnews", n_train=512, n_val=128, n_test=256, seed=1)


@pytest.fixture(scope="session")
def gsm8k():
    return make_workload("gsm8k", n_train=512, n_val=128, n_test=256, seed=1)


@pytest.fixture(scope="session")
def pool():
    return make_simulated_pool("qwen3")


@pytest.fixture(scope="session")
def fitted_rb(agnews, pool):
    from repro.core import Robatch

    return Robatch(pool, agnews, coreset_size=64, router_kind="knn").fit()
