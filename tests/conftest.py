import os

# Tests run on the single real CPU device (the dry-run sets its own 512-device
# flag in a subprocess; never set it globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest

from repro.data import make_simulated_pool, make_workload


@pytest.fixture(scope="session")
def agnews():
    return make_workload("agnews", n_train=512, n_val=128, n_test=256, seed=1)


@pytest.fixture(scope="session")
def gsm8k():
    return make_workload("gsm8k", n_train=512, n_val=128, n_test=256, seed=1)


@pytest.fixture(scope="session")
def pool():
    return make_simulated_pool("qwen3")


@pytest.fixture(scope="session")
def fitted_rb(agnews, pool):
    from repro.core import Robatch

    return Robatch(pool, agnews, coreset_size=64, router_kind="knn").fit()
