"""Capacity-aware Δ-heap scheduling + backlog-driven replica autoscaling:
pack-vs-defer semantics, ReplicaSet.scale_to grow/drain/shrink, hysteresis
flap-freedom, and the closed loop through the online server."""
import numpy as np
import pytest

from repro.core.problem import group_into_batches
from repro.core.scheduler import (
    greedy_schedule,
    greedy_schedule_capped,
    greedy_schedule_window,
)
from repro.data.simulator import BatchResult
from repro.serving.autoscale import AutoscalePolicy, Autoscaler, ScaleEvent
from repro.serving.online import OnlineConfig, OnlineRobatchServer, WindowReport
from repro.serving.pool import ReplicaSet, replicate_simulated


# ---------------------------------------------------------------------------
# capacity-aware Δ-heap (greedy_schedule_capped)
# ---------------------------------------------------------------------------

def test_capped_schedule_bit_identical_when_caps_never_bind(fitted_rb, agnews):
    # property-style sweep: with caps ≥ the uncapped schedule's group demand,
    # the capacity-aware walk must return the uncapped schedule EXACTLY
    test = agnews.subset_indices("test")
    space = fitted_rb.candidate_space(test)
    base = float(space.cost[:, space.initial_state].sum())
    for n, mult in [(8, 1.5), (24, 3.0), (64, 8.0), (128, 2.0)]:
        idx = test[:n]
        sub = fitted_rb.candidate_space(idx)
        budget = float(sub.cost[:, sub.initial_state].sum()) * mult
        free = greedy_schedule(sub, idx, budget)
        loose = {k: len(idx) for k in range(3)}   # ≥ any possible demand
        capped = greedy_schedule_capped(sub, idx, budget, loose)
        assert np.array_equal(capped.assignment.model, free.assignment.model)
        assert np.array_equal(capped.assignment.batch, free.assignment.batch)
        assert np.array_equal(capped.assignment.query_idx, free.assignment.query_idx)
        assert capped.est_utility == free.est_utility
        assert capped.amortized_cost == free.amortized_cost
        assert capped.n_packed == 0 and len(capped.deferred_idx) == 0
    assert base > 0


def test_capped_schedule_defers_strictly_less_than_post_pass(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:48]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost.max(axis=1).sum())          # rich budget
    caps = {0: 1, 1: 1, 2: 1}
    defer = greedy_schedule_window(space, test, budget, group_caps=caps,
                                   cap_mode="defer")
    pack = greedy_schedule_window(space, test, budget, group_caps=caps)
    assert len(defer.deferred_idx) > 0                    # post-pass does defer
    assert len(pack.deferred_idx) < len(defer.deferred_idx)
    # packing respects both the caps and the budget
    per_model: dict = {}
    for state, _m in group_into_batches(pack.assignment):
        per_model[state.model] = per_model.get(state.model, 0) + 1
    assert all(n <= caps[k] for k, n in per_model.items())
    assert pack.amortized_cost <= budget + 1e-9
    # and serves strictly more work than wholesale deferral
    assert len(pack.assignment) > len(defer.assignment)


def test_per_member_attribution_sums_match_counts(fitted_rb, agnews):
    # scheduler side of the WindowReport attribution: per-member held/packed
    # breakdowns must reconcile exactly with the scalar counters
    test = agnews.subset_indices("test")[:48]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost.max(axis=1).sum())
    caps = {0: 1, 1: 1, 2: 1}
    for cap_mode in ("pack", "defer"):
        res = greedy_schedule_window(space, test, budget, group_caps=caps,
                                     cap_mode=cap_mode)
        assert sum(res.deferred_by_member.values()) == len(res.deferred_idx)
        assert sum(res.packed_by_member.values()) == res.n_packed
        assert all(k in caps for k in res.deferred_by_member)


def test_capped_schedule_spills_to_members_with_headroom(fitted_rb, agnews):
    # cap model 0 to one group but leave the others roomy: overflow must land
    # on other members (or wider batches), not be deferred outright
    test = agnews.subset_indices("test")[:32]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost.max(axis=1).sum())
    res = greedy_schedule_window(space, test, budget,
                                 group_caps={0: 1, 1: 8, 2: 8})
    assert len(res.deferred_idx) == 0
    per_model: dict = {}
    for state, _m in group_into_batches(res.assignment):
        per_model[state.model] = per_model.get(state.model, 0) + 1
    assert per_model.get(0, 0) <= 1


# ---------------------------------------------------------------------------
# ReplicaSet.scale_to: grow via factory / un-park, shrink via drain+retire
# ---------------------------------------------------------------------------

class _StubMember:
    def __init__(self, tag: float):
        self.name = "stub"
        self.c_in, self.c_out, self.context_len = 1.0, 2.0, 512
        self.tag = tag
        self.n_calls = 0

    def invoke_batch(self, wl, batch_idx):
        self.n_calls += 1
        return BatchResult(utilities=np.full(len(batch_idx), self.tag),
                           in_tokens=10, out_tokens=2, latency_s=0.01)


def test_scale_to_grows_with_factory_and_shrinks_by_retiring():
    built = []

    def factory():
        m = _StubMember(float(len(built) + 1))
        built.append(m)
        return m

    rs = ReplicaSet([_StubMember(0.0)], name="m", factory=factory)
    assert rs.n_replicas == 1
    assert rs.scale_to(3) == 3
    assert rs.n_replicas == 3 and len(built) == 2
    assert rs.n_available() == 3                     # new replicas are healthy
    # shrink: replicas retire (drain), they are not torn off the set
    assert rs.scale_to(1) == 1
    assert rs.n_replicas == 1 and len(rs.replicas) == 3
    assert rs.n_available() == 1
    # retired replicas take no new work
    for _ in range(6):
        rs.invoke_batch(None, np.arange(2))
    assert sum(m.n_calls for m in built if rs.tracker.replicas[
        rs.replicas.index(m)].retired) == 0
    # grow again: parked replicas are restored before the factory builds more
    assert rs.scale_to(2) == 2
    assert len(built) == 2                           # no new construction
    assert rs.n_replicas == 2


def test_scale_to_without_factory_is_bounded_by_attached_replicas():
    rs = ReplicaSet([_StubMember(0.0), _StubMember(1.0)], name="m")
    assert rs.scale_to(5) == 2                       # cannot build more
    assert rs.scale_to(0) == 1                       # floor is always 1
    assert rs.n_available() == 1


def test_replicate_simulated_carries_a_factory(pool):
    rs = replicate_simulated(pool[0], 1)
    assert rs.scale_to(3) == 3
    assert rs.replicas[1].name == pool[0].name       # interchangeable copies


# ---------------------------------------------------------------------------
# async warm attach: factory builds off the serving thread, joins next window
# ---------------------------------------------------------------------------

def test_async_build_returns_immediately_and_joins_at_boundary():
    import threading
    import time as _time

    gate = threading.Event()
    built = []

    def factory():
        gate.wait(timeout=5.0)            # a slow engine construction
        m = _StubMember(1.0)
        built.append(m)
        return m

    rs = ReplicaSet([_StubMember(0.0)], name="m", factory=factory,
                    async_build=True)
    t0 = _time.perf_counter()
    assert rs.scale_to(3) == 1            # no blocking on the build
    assert _time.perf_counter() - t0 < 1.0
    assert rs.n_pending_builds == 2
    assert rs.n_available() == 1          # nothing joined while gate is shut
    # a repeated request while builds are in flight never double-builds
    assert rs.scale_to(3) == 1
    assert rs.n_pending_builds == 2
    # dispatch keeps flowing on the existing replica meanwhile
    rs.invoke_batch(None, np.arange(2))
    gate.set()
    deadline = _time.time() + 5.0
    while rs.n_available() < 3 and _time.time() < deadline:
        _time.sleep(0.01)
    assert rs.n_available() == 3          # joined at a later boundary read
    assert rs.n_pending_builds == 0
    assert len(built) == 2
    assert rs.scale_to(1) == 1            # and they shrink like any replica


def test_autoscaler_tracks_async_pending_builds():
    import threading

    gate = threading.Event()
    rs = ReplicaSet([_StubMember(0.0)], name="m", async_build=True,
                    factory=lambda: (gate.wait(timeout=5.0), _StubMember(1.0))[1])
    asc = Autoscaler([rs], AutoscalePolicy(min_replicas=1, max_replicas=4,
                                           up_pressure=4, hold_windows=1,
                                           cooldown_s=0.0))
    fired = asc.observe(_rep(0.25, held=10), queue_depth=0, now=0.25)
    assert [(e.from_n, e.to_n) for e in fired] == [(1, 2)]
    assert "async build" in fired[0].reason
    assert rs.n_replicas == 1             # capacity arrives later, not inline
    # sustained breach grows the in-flight target, not a duplicate of step 1
    fired = asc.observe(_rep(0.5, held=10), queue_depth=0, now=0.5)
    assert [(e.from_n, e.to_n) for e in fired] == [(2, 3)]
    gate.set()


# ---------------------------------------------------------------------------
# per-member pressure attribution reaches the autoscaler's log
# ---------------------------------------------------------------------------

def test_autoscaler_decays_per_member_pressure_trace():
    rs = replicate_simulated_stub()
    asc = Autoscaler([rs], AutoscalePolicy())          # pressure_decay = 0.5
    asc.observe(WindowReport(t=0.25, n_capacity_held=5, n_cap_packed=3,
                             held_by_member=((0, 5),),
                             packed_by_member=((0, 2), (2, 1))),
                queue_depth=0, now=0.25)
    assert asc.pressure_by_member == {0: 7.0, 2: 1.0}
    asc.observe(WindowReport(t=0.5, held_by_member=((2, 4),)),
                queue_depth=0, now=0.5)
    # one window later the first burst has halved; the fresh one is undecayed
    assert asc.pressure_by_member == {0: 3.5, 2: 4.5}
    assert "pressure by member" in asc.summary()
    # idle windows decay the trace toward empty (no infinite-memory bias)
    t = 0.5
    for _ in range(16):
        t += 0.25
        asc.observe(WindowReport(t=t), queue_depth=0, now=t)
    assert asc.pressure_by_member == {}


def test_scale_action_resets_the_acting_members_trace():
    rs = replicate_simulated_stub()
    asc = Autoscaler([rs], AutoscalePolicy(min_replicas=1, max_replicas=4,
                                           up_pressure=4, hold_windows=2,
                                           cooldown_s=0.0))
    for t in (0.25, 0.5):
        fired = asc.observe(
            WindowReport(t=t, n_capacity_held=8, held_by_member=((0, 8),)),
            queue_depth=0, now=t)
    assert [(e.from_n, e.to_n) for e in fired] == [(1, 2)]
    assert 0 not in asc.pressure_by_member    # the action cleared its trace


# ---------------------------------------------------------------------------
# bottleneck-aware per-member control: only the pressured member moves
# ---------------------------------------------------------------------------

def _member_set(name, n=1, factory=True):
    reps = [_StubMember(float(i)) for i in range(n)]
    kw = {"factory": (lambda: _StubMember(9.0))} if factory else {}
    return ReplicaSet(reps, name=name, **kw)


def test_grow_targets_only_the_bottleneck_member():
    rs0, rs1 = _member_set("m0"), _member_set("m1")
    asc = Autoscaler([rs0, rs1],
                     AutoscalePolicy(min_replicas=1, max_replicas=4,
                                     up_pressure=4, hold_windows=2,
                                     cooldown_s=0.0))
    for t in (0.25, 0.5):
        asc.observe(WindowReport(t=t, n_capacity_held=8,
                                 held_by_member=((1, 8),)),
                    queue_depth=0, now=t)
    assert rs0.n_replicas == 1               # unpressured sibling untouched
    assert rs1.n_replicas == 2               # bottleneck grew
    assert asc.events_by_member() == {"m1": (1, 0)}


def test_members_shrink_independently_of_a_pressured_sibling():
    rs0 = _member_set("m0", n=2, factory=False)
    rs1 = _member_set("m1", n=2)
    asc = Autoscaler([rs0, rs1],
                     AutoscalePolicy(min_replicas=1, max_replicas=4,
                                     up_pressure=4, down_pressure=0,
                                     hold_windows=2, cooldown_s=0.0))
    for t in (0.25, 0.5):
        asc.observe(WindowReport(t=t, n_capacity_held=8,
                                 held_by_member=((1, 8),)),
                    queue_depth=0, now=t)
    assert rs0.n_replicas == 1               # idle member drained on its own
    assert rs1.n_replicas == 3               # while the bottleneck grew
    assert asc.events_by_member() == {"m0": (0, 1), "m1": (1, 0)}
    assert "actions by member" in asc.summary()


def test_scalar_only_reports_fall_back_to_pool_wide_grow():
    # legacy reports (no per-member attribution) must keep the original
    # every-scalable-member semantics
    rs0, rs1 = _member_set("m0"), _member_set("m1")
    asc = Autoscaler([rs0, rs1],
                     AutoscalePolicy(min_replicas=1, max_replicas=4,
                                     up_pressure=4, hold_windows=2,
                                     cooldown_s=0.0))
    for t in (0.25, 0.5):
        asc.observe(_rep(t, held=10), queue_depth=0, now=t)
    assert rs0.n_replicas == 2 and rs1.n_replicas == 2


def test_saturated_member_does_not_shrink_at_zero_pressure():
    # a member dispatching at its replica count is saturated even when the
    # caps kept the backlog away — it must not flap down
    rs0 = _member_set("m0", n=2, factory=False)
    asc = Autoscaler([rs0], AutoscalePolicy(min_replicas=1, max_replicas=4,
                                            down_pressure=0, hold_windows=2,
                                            cooldown_s=0.0))
    t = 0.0
    for _ in range(6):
        t += 0.25
        asc.observe(WindowReport(t=t, group_models=(0, 0)),
                    queue_depth=0, now=t)
    assert rs0.n_replicas == 2               # busy at cap: no shrink
    for _ in range(2):
        t += 0.25
        asc.observe(WindowReport(t=t), queue_depth=0, now=t)
    assert rs0.n_replicas == 1               # genuinely idle: drains


# ---------------------------------------------------------------------------
# Autoscaler: hysteresis + cooldown (no flapping)
# ---------------------------------------------------------------------------

def _rep(t, held=0, packed=0, late=0.0):
    return WindowReport(t=t, n_capacity_held=held, n_cap_packed=packed,
                        late_s=late)


def test_autoscaler_is_flap_free_under_oscillating_load():
    rs = replicate_simulated_stub()
    asc = Autoscaler([rs], AutoscalePolicy(min_replicas=1, max_replicas=4,
                                           up_pressure=4, down_pressure=0,
                                           hold_windows=2, cooldown_s=0.0))
    # load oscillates hi/lo every window: neither streak ever reaches
    # hold_windows, so a 40-window oscillation produces ZERO scale actions
    t = 0.0
    for i in range(40):
        t += 0.25
        asc.observe(_rep(t, held=8 if i % 2 == 0 else 0,
                         packed=0 if i % 2 == 0 else 0),
                    queue_depth=0 if i % 2 else 6, now=t)
    assert asc.events == []
    assert rs.n_replicas == 1


def replicate_simulated_stub():
    return ReplicaSet([_StubMember(0.0)], name="m",
                      factory=lambda: _StubMember(9.0))


def test_autoscaler_hysteresis_cooldown_and_bounds():
    rs = replicate_simulated_stub()
    asc = Autoscaler([rs], AutoscalePolicy(min_replicas=1, max_replicas=3,
                                           up_pressure=4, hold_windows=2,
                                           cooldown_s=1.0))
    # one breaching window is not enough (hysteresis)
    assert asc.observe(_rep(0.25, held=10), queue_depth=0, now=0.25) == []
    # the second consecutive breach acts
    fired = asc.observe(_rep(0.5, held=10), queue_depth=0, now=0.5)
    assert [(e.from_n, e.to_n) for e in fired] == [(1, 2)]
    # cooldown: sustained breach inside 1.0s does NOT act again
    assert asc.observe(_rep(0.75, held=10), queue_depth=0, now=0.75) == []
    assert asc.observe(_rep(1.0, held=10), queue_depth=0, now=1.0) == []
    # the first breach past the cooldown (streak already ≥ hold) grows again...
    fired = asc.observe(_rep(1.75, held=10), queue_depth=0, now=1.75)
    assert [(e.from_n, e.to_n) for e in fired] == [(2, 3)]
    # ...and never beyond max_replicas
    for t in (3.5, 3.75, 4.0, 4.25):
        asc.observe(_rep(t, held=10), queue_depth=0, now=t)
    assert rs.n_replicas == 3
    # idle windows shrink it back, floored at min_replicas
    t = 5.0
    for _ in range(20):
        t += 0.25
        asc.observe(_rep(t), queue_depth=0, now=t)
    assert rs.n_replicas == 1
    assert all(isinstance(e, ScaleEvent) for e in asc.events)


def test_autoscaler_floors_pool_to_min_replicas_up_front():
    rs = replicate_simulated_stub()
    Autoscaler([rs], AutoscalePolicy(min_replicas=2, max_replicas=4))
    assert rs.n_replicas == 2


# ---------------------------------------------------------------------------
# the closed loop: server backlog -> scale up -> drain -> scale down
# ---------------------------------------------------------------------------

def test_server_autoscales_up_under_ramp_and_back_down(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    base = float(fitted_rb.cost_model.state_cost(
        0, fitted_rb.calibrations[0].b_effect, test).mean())
    sets = [replicate_simulated(m, 1) for m in pool]
    cfg = OnlineConfig(
        budget_per_s=80.0 * base * 8.0, window_s=0.5,
        autoscale=AutoscalePolicy(min_replicas=1, max_replicas=4,
                                  up_pressure=4, down_pressure=0,
                                  up_queue_depth=24, down_queue_depth=4,
                                  hold_windows=2, cooldown_s=1.0))
    srv = OnlineRobatchServer(fitted_rb, sets, agnews, cfg)
    rng = np.random.default_rng(13)
    burst = [(1.0 + 6.0 * i / len(test), int(q))
             for i, q in enumerate(rng.permutation(test))]
    stats = srv.run(burst, max_ticks=200)
    for _ in range(12):                          # idle ticks after the drain
        srv.step()
    srv.close()
    assert stats.n_completed == stats.n_submitted
    assert srv.autoscaler is not None and srv.autoscaler.events
    peaks = [max(w.replica_counts) for w in srv.windows if w.replica_counts]
    assert max(peaks) > 1                        # grew under backlog
    assert max(srv.windows[-1].replica_counts) < max(peaks)  # shrank after drain
    ups = [e for e in srv.autoscaler.events if e.to_n > e.from_n]
    downs = [e for e in srv.autoscaler.events if e.to_n < e.from_n]
    assert ups and downs
    assert min(e.t for e in ups) < min(e.t for e in downs)


def test_autoscaled_run_holds_less_capacity_than_fixed_r1(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    base = float(fitted_rb.cost_model.state_cost(
        0, fitted_rb.calibrations[0].b_effect, test).mean())
    rng = np.random.default_rng(14)
    burst = [(1.0 + 4.0 * i / len(test), int(q))
             for i, q in enumerate(rng.permutation(test))]

    def run(autoscale):
        cfg = OnlineConfig(budget_per_s=80.0 * base * 8.0, window_s=0.5,
                           autoscale=autoscale)
        srv = OnlineRobatchServer(fitted_rb, [replicate_simulated(m, 1)
                                              for m in pool], agnews, cfg)
        stats = srv.run(burst, max_ticks=200)
        srv.close()
        return sum(w.n_capacity_held + w.n_cap_packed for w in stats.windows)

    fixed = run(None)
    scaled = run(AutoscalePolicy(min_replicas=1, max_replicas=4, up_pressure=4,
                                 hold_windows=2, cooldown_s=0.5))
    assert fixed > 0                              # R=1 was actually pressured
    assert scaled < fixed                         # added capacity relieved it


def test_window_reports_attribute_capacity_to_members(fitted_rb, agnews, pool):
    # a caps-bound R=1 burst of UNIQUE queries: the per-member breakdowns
    # must reconcile exactly with the scalar pressure counters
    test = agnews.subset_indices("test")
    base = float(fitted_rb.cost_model.state_cost(
        0, fitted_rb.calibrations[0].b_effect, test).mean())
    sets = [replicate_simulated(m, 1) for m in pool]
    srv = OnlineRobatchServer(fitted_rb, sets, agnews,
                              OnlineConfig(budget_per_s=80.0 * base * 8.0,
                                           window_s=0.5))
    rng = np.random.default_rng(15)
    burst = [(1.0 + 4.0 * i / len(test), int(q))
             for i, q in enumerate(rng.permutation(test))]
    srv.run(burst, max_ticks=200)
    srv.close()
    pressured = [w for w in srv.windows if w.n_capacity_held or w.n_cap_packed]
    assert pressured, "burst never bound the R=1 caps"
    for w in srv.windows:
        assert sum(c for _k, c in w.held_by_member) == w.n_capacity_held
        assert sum(c for _k, c in w.packed_by_member) == w.n_cap_packed
        assert all(0 <= k < len(sets) for k, _c in
                   w.held_by_member + w.packed_by_member)


def test_window_reports_carry_replica_counts(fitted_rb, agnews, pool):
    sets = [replicate_simulated(m, 2) for m in pool]
    test = agnews.subset_indices("test")
    base = float(fitted_rb.cost_model.state_cost(
        0, fitted_rb.calibrations[0].b_effect, test).mean())
    srv = OnlineRobatchServer(fitted_rb, sets, agnews,
                              OnlineConfig(budget_per_s=base * 40.0))
    srv.submit(int(test[0]), at=0.0)
    rep = srv.step(0.25)
    srv.close()
    assert rep.replica_counts == (2, 2, 2)


def test_pool_spec_round_trips_autoscale_bounds():
    from repro.api import PoolSpec, RunSpec

    spec = RunSpec(pool=PoolSpec(replicas=1, min_replicas=1, max_replicas=4))
    again = RunSpec.from_json(spec.to_json())
    assert again == spec
    pol = again.pool.autoscale_policy()
    assert pol is not None
    assert (pol.min_replicas, pol.max_replicas) == (1, 4)
    assert RunSpec().pool.autoscale_policy() is None
    with pytest.raises(ValueError, match="max_replicas"):
        PoolSpec(replicas=3, max_replicas=2).build()
