"""HTTP front-end: OpenAI wire schema, SSE streaming at decode_block cadence,
the live ingress bridge, Prometheus metrics, and parity with the in-process
serving path."""
import json
import re
import threading
import urllib.error
import urllib.request

import pytest

from repro.http import HttpFrontend, MetricsRegistry
from repro.http.protocol import (ApiError, completion_response,
                                 parse_chat_body, resolve_query_idx)
from repro.serving.online import (OnlineConfig, OnlineRobatchServer,
                                  StreamSink, WindowReport)


def _server(rb, pool, wl, **kw):
    cfg = OnlineConfig(budget_per_s=kw.pop("budget_per_s", 1e6),
                       window_s=kw.pop("window_s", 0.03), realtime=True, **kw)
    return OnlineRobatchServer(rb, pool, wl, cfg)


@pytest.fixture(scope="module")
def frontend(fitted_rb, pool, agnews):
    fe = HttpFrontend(_server(fitted_rb, pool, agnews), port=0).start()
    yield fe
    fe.stop()


@pytest.fixture(scope="module")
def base(frontend):
    return f"http://127.0.0.1:{frontend.port}"


def _post(base, payload, timeout=30.0):
    req = urllib.request.Request(
        base + "/v1/chat/completions", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    return urllib.request.urlopen(req, timeout=timeout)


def _get_json(base, path, timeout=10.0):
    with urllib.request.urlopen(base + path, timeout=timeout) as r:
        return json.loads(r.read())


def _sse_frames(resp):
    """Parse an SSE stream into its data payloads ([DONE] stays a sentinel)."""
    frames = []
    for line in resp:
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        payload = line[len(b"data: "):]
        frames.append("DONE" if payload == b"[DONE]" else json.loads(payload))
    return frames


# ---------------------------------------------------------------------------
# wire basics
# ---------------------------------------------------------------------------

def test_models_lists_pool_with_prices(base, pool):
    body = _get_json(base, "/v1/models")
    assert body["object"] == "list"
    names = [m["id"] for m in body["data"]]
    assert names == [m.name for m in pool]
    for m in body["data"]:
        assert m["pricing"]["input_per_1m_tokens"] > 0
        assert m["pricing"]["output_per_1m_tokens"] > 0


def test_unary_completion_roundtrip(base, pool):
    with _post(base, {"messages": [{"role": "user", "content": "#5"}],
                      "query_idx": 5}) as r:
        body = json.loads(r.read())
    assert body["object"] == "chat.completion"
    assert body["choices"][0]["finish_reason"] == "stop"
    content = body["choices"][0]["message"]["content"]
    ext = body["robatch"]
    # deterministic simulated content: "[member] qN utility=..."
    assert content == (f"[{pool[ext['model_idx']].name}] q{ext['query_idx']} "
                       f"utility={ext['utility']:.3f}")
    assert body["usage"]["total_tokens"] > 0
    assert body["id"].startswith("chatcmpl-") and body["created"] == 0


def test_bad_request_gets_openai_error_envelope(base):
    with pytest.raises(urllib.error.HTTPError) as ei:
        _post(base, {"messages": []})
    assert ei.value.code == 400
    err = json.loads(ei.value.read())["error"]
    assert err["type"] == "invalid_request_error" and err["message"]


def test_unknown_route_404s(base):
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(base + "/v2/nope", timeout=10)
    assert ei.value.code == 404


def test_healthz_reports_members_and_breakers(base, pool):
    body = _get_json(base, "/healthz")
    assert body["status"] in ("ok", "degraded")
    assert [m["name"] for m in body["members"]] == [m.name for m in pool]
    for m in body["members"]:
        assert m["breaker"] == "closed"
        assert m["available"] >= 1


# ---------------------------------------------------------------------------
# SSE streaming contract
# ---------------------------------------------------------------------------

def test_stream_frames_role_chunks_finish_done(base):
    with _post(base, {"messages": [{"role": "user", "content": "#9"}],
                      "query_idx": 9, "stream": True}) as r:
        assert r.headers["Content-Type"].startswith("text/event-stream")
        frames = _sse_frames(r)
    assert frames[-1] == "DONE"
    chunks = frames[:-1]
    assert all(f["object"] == "chat.completion.chunk" for f in chunks)
    assert chunks[0]["choices"][0]["delta"] == {"role": "assistant"}
    deltas = [c["choices"][0]["delta"].get("content") for c in chunks[1:-1]]
    # the wire contract the bench gate also pins: >= 2 content chunks before
    # the finish frame (decode_block cadence / StreamSink split guarantee)
    assert len(deltas) >= 2 and all(deltas)
    final = chunks[-1]["choices"][0]
    assert final["finish_reason"] == "stop" and final["delta"] == {}
    assert chunks[-1]["usage"]["total_tokens"] > 0
    assert chunks[-1]["robatch"]["model"] is not None


def test_stream_content_matches_unary(base):
    q = 17
    with _post(base, {"messages": [{"role": "user", "content": f"#{q}"}],
                      "query_idx": q, "stream": True}) as r:
        frames = _sse_frames(r)
    streamed = "".join(c["choices"][0]["delta"].get("content", "")
                       for c in frames[:-1])
    with _post(base, {"messages": [{"role": "user", "content": f"#{q}"}],
                      "query_idx": q}) as r:
        unary = json.loads(r.read())["choices"][0]["message"]["content"]
    assert streamed == unary


def test_concurrent_clients_all_complete(base):
    results, errors = [], []
    lock = threading.Lock()

    def client(c):
        try:
            for i in range(3):
                q = 30 + c * 3 + i
                stream = (c + i) % 2 == 0
                body = {"messages": [{"role": "user", "content": f"#{q}"}],
                        "query_idx": q, "stream": stream}
                with _post(base, body) as r:
                    if stream:
                        frames = _sse_frames(r)
                        ok = frames[-1] == "DONE" and len(frames) >= 5
                    else:
                        ok = bool(json.loads(r.read())["choices"][0]
                                  ["message"]["content"])
                with lock:
                    results.append(ok)
        except Exception as e:   # noqa: BLE001 — collected for the assert
            with lock:
                errors.append(e)

    threads = [threading.Thread(target=client, args=(c,)) for c in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors
    assert len(results) == 18 and all(results)


# ---------------------------------------------------------------------------
# parity with the in-process serving path
# ---------------------------------------------------------------------------

def test_unary_parity_with_inprocess_serve(fitted_rb, pool, agnews):
    """The same seeded requests produce bit-identical chat.completion bodies
    over the wire and through ``Gateway.serve`` — deterministic ids, content,
    routing and billing; wall-clock latency is the single timing field."""
    from repro.api.gateway import Gateway

    qs = [3, 11, 42, 7]
    window = 0.03
    gw = Gateway(pool, agnews, artifacts=fitted_rb)
    fe = gw.serve_http(OnlineConfig(budget_per_s=1e6, window_s=window,
                                    realtime=True))
    try:
        base = f"http://127.0.0.1:{fe.port}"
        got = []
        for q in qs:       # sequential: each request rides its own window
            with _post(base, {"messages": [{"role": "user", "content": "x"}],
                              "query_idx": q}) as r:
                got.append(json.loads(r.read()))
    finally:
        fe.stop()

    test_idx = agnews.subset_indices("test")
    arrivals = [(i * window * 2, int(test_idx[q])) for i, q in enumerate(qs)]
    gw.serve(arrivals, OnlineConfig(budget_per_s=1e6, window_s=window))
    by_rid = {r.rid: r for r in gw.server.completed}
    assert sorted(by_rid) == list(range(len(qs)))
    for rid, http_body in enumerate(got):
        req = by_rid[rid]
        want = completion_response(req, pool[req.model].name, agnews)
        lat_http = http_body["robatch"].pop("latency_s")
        lat_proc = want["robatch"].pop("latency_s")
        assert lat_http >= 0.0 and lat_proc >= 0.0
        assert http_body == want


# ---------------------------------------------------------------------------
# Prometheus metrics surface
# ---------------------------------------------------------------------------

_METRIC_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z0-9_]+=\"[^\"]*\""
    r"(,[a-zA-Z0-9_]+=\"[^\"]*\")*\})? (-?[0-9.e+-]+|\+Inf|NaN)$")


def test_metrics_prometheus_text_parses(base, pool):
    # drive some traffic first so counters are non-trivial
    with _post(base, {"messages": [{"role": "user", "content": "#2"}],
                      "query_idx": 2}) as r:
        r.read()
    with urllib.request.urlopen(base + "/metrics", timeout=10) as r:
        assert r.headers["Content-Type"].startswith("text/plain")
        text = r.read().decode()
    helped, typed, seen = set(), set(), set()
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# HELP "):
            helped.add(line.split()[2])
        elif line.startswith("# TYPE "):
            parts = line.split()
            assert parts[3] in ("counter", "gauge", "histogram")
            typed.add(parts[2])
        else:
            assert _METRIC_RE.match(line), f"unparseable sample: {line!r}"
            name = re.split(r"[{ ]", line)[0]
            base_name = re.sub(r"_(bucket|sum|count)$", "", name)
            seen.add(base_name if base_name in typed else name)
    # every sample belongs to a declared family and vice versa
    assert seen <= typed == helped
    for fam in ("robatch_requests_total", "robatch_pending_requests",
                "robatch_cost_dollars_total", "robatch_breaker_state",
                "robatch_cache_entries", "robatch_http_requests_total",
                "robatch_request_latency_seconds"):
        assert fam in typed, f"{fam} missing from /metrics"
    # satellite: per-member scheduling-pressure gauges, one per pool member
    for m in pool:
        assert f'robatch_member_pressure{{member="{m.name}"}}' in text
    # histogram buckets are cumulative and end at +Inf == _count
    bucket_lines = [ln for ln in text.splitlines()
                    if ln.startswith("robatch_http_request_seconds_bucket")]
    assert any('le="+Inf"' in ln for ln in bucket_lines)


def test_metrics_registry_binds_to_gateway_serve(fitted_rb, pool, agnews):
    """Gateway.serve(metrics=...) populates the same registry the HTTP
    surface renders — no front-end required."""
    from repro.api.gateway import Gateway

    gw = Gateway(pool, agnews, artifacts=fitted_rb)
    reg = MetricsRegistry()
    test_idx = agnews.subset_indices("test")
    arrivals = [(0.05 * i, int(test_idx[i])) for i in range(8)]
    gw.serve(arrivals, OnlineConfig(budget_per_s=1e6, window_s=0.25),
             metrics=reg)
    text = reg.render()
    m = re.search(r'robatch_requests_total\{outcome="served"\} (\d+)', text)
    assert m and int(m.group(1)) == 8
    assert "robatch_windows_total" in text


# ---------------------------------------------------------------------------
# ingress bridge + StreamSink semantics (no HTTP involved)
# ---------------------------------------------------------------------------

def test_stream_sink_splits_unstreamed_content_into_two_chunks():
    sink = StreamSink()
    sink.finish("hello world", split=True)
    kinds = []
    while not sink.q.empty():
        kinds.append(sink.q.get_nowait())
    deltas = [p for k, p in kinds if k == "delta"]
    assert len(deltas) == 2 and "".join(deltas) == "hello world"
    assert kinds[-1] == ("done", None)


def test_stream_sink_emits_only_uncovered_tail():
    sink = StreamSink()
    sink.push("hello ")
    sink.finish("hello world", split=True)
    out = []
    while not sink.q.empty():
        out.append(sink.q.get_nowait())
    assert out == [("delta", "hello "), ("delta", "world"), ("done", None)]


def test_bridge_drains_pending_on_stop(fitted_rb, pool, agnews):
    """Stopping the bridge must not strand a waiter: pending requests are
    served (or force-dropped) before run_bridge returns."""
    srv = _server(fitted_rb, pool, agnews, window_s=0.02)
    stop = threading.Event()
    t = threading.Thread(target=srv.run_bridge, args=(stop,), daemon=True)
    t.start()
    test_idx = agnews.subset_indices("test")
    reqs = [srv.submit_request(int(test_idx[i])) for i in range(4)]
    stop.set()
    t.join(timeout=30)
    assert not t.is_alive()
    for r in reqs:
        assert r.done_event.wait(1.0)
    srv.close()


# ---------------------------------------------------------------------------
# protocol unit coverage: body parsing and the query-resolution ladder
# ---------------------------------------------------------------------------

def test_parse_chat_body_validates_shape():
    ok = parse_chat_body(json.dumps(
        {"messages": [{"role": "user", "content": "hi"}], "stream": True,
         "query_idx": 4}).encode())
    assert ok == {"content": "hi", "stream": True, "model": None,
                  "query_idx": 4, "gen": None}
    for bad in (b"not json", b"[]", b'{"messages": []}',
                b'{"messages": [{"role": "assistant", "content": "x"}]}',
                b'{"messages": [{"role": "user", "content": "x"}], '
                b'"query_idx": "seven"}'):
        with pytest.raises(ApiError):
            parse_chat_body(bad)


def test_resolve_query_idx_ladder():
    universe = [100, 101, 102, 103]
    text_index = {"what is 2+2": 102}

    def resolve(content, query_idx=None):
        return resolve_query_idx({"content": content, "query_idx": query_idx},
                                 universe, text_index)

    assert resolve("anything", query_idx=2) == 102     # explicit position
    assert resolve("what is 2+2") == 102               # exact text (index 0 ok)
    assert resolve("#1") == 101 and resolve("q3") == 103
    h = resolve("free-form question")                  # stable hash fallback
    assert h in universe and h == resolve("free-form question")
    with pytest.raises(ApiError):
        resolve("x", query_idx=99)


# ---------------------------------------------------------------------------
# engine streaming hook: decode_block cadence
# ---------------------------------------------------------------------------

def test_engine_on_tokens_hook_fires_per_decode_block():
    import jax

    from repro.config import ShardingConfig, get_arch
    from repro.models.transformer import Model
    from repro.serving.engine import Request, ServingEngine

    cfg = get_arch("tiny-s")
    model = Model(cfg, ShardingConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    k = 4
    eng = ServingEngine(model, params, max_slots=2, max_len=128,
                        decode_block=k, eos_id=-1)
    blocks = {0: [], 1: []}
    done_flags = {0: [], 1: []}

    def hook(rid):
        def on_tokens(toks, done):
            blocks[rid].append(list(toks))
            done_flags[rid].append(done)
        return on_tokens

    reqs = [Request(rid=i, tokens=[1, 2, 3 + i], max_new=9,
                    on_tokens=hook(i)) for i in range(2)]
    eng.serve(reqs)
    for r in reqs:
        got = blocks[r.rid]
        assert sum(got, []) == r.out_tokens       # hook saw every token once
        assert len(got) >= 3                      # prefill + >= 2 decode blocks
        assert all(len(b) <= k for b in got)      # never more than one block
        assert done_flags[r.rid][-1] and not any(done_flags[r.rid][:-1])


# ---------------------------------------------------------------------------
# WindowReport.summary (satellite)
# ---------------------------------------------------------------------------

def test_window_report_summary_includes_kv_occupancy():
    rep = WindowReport(t=1.5, n_pending=3, n_admitted=2, n_groups=1,
                       spent=0.25, replica_counts=(1, 2),
                       kv_pages=((0, 10, 4, 1), (2, 5, 0, 0)))
    line = rep.summary()
    assert "t=1.50s" in line and "admitted=2" in line
    assert "replicas=[1, 2]" in line
    assert rep.kv_occupancy == 15
    assert "kv_pages[15 live: m0:10p/4sh/1cow m2:5p/0sh/0cow]" in line
    # simulated pools carry no kv telemetry — the field stays out of the line
    assert "kv_pages" not in WindowReport(t=0.0).summary()


# ---------------------------------------------------------------------------
# generation parsing + the documented unsupported-field contract
# ---------------------------------------------------------------------------

def _chat(**extra):
    body = {"messages": [{"role": "user", "content": "hi"}]}
    body.update(extra)
    return json.dumps(body).encode()


def test_parse_chat_body_builds_generation_config():
    from repro.serving.generation import GenerationConfig

    got = parse_chat_body(_chat(temperature=0.7, top_p=0.9, seed=5,
                                max_tokens=64))
    assert got["gen"] == GenerationConfig(max_new=64, temperature=0.7,
                                          top_p=0.9, seed=5)
    # any single sampling field is enough; the rest default
    assert parse_chat_body(_chat(seed=3))["gen"] == GenerationConfig(seed=3)
    assert parse_chat_body(_chat(max_completion_tokens=8))["gen"].max_new == 8
    # n=1 is the one accepted value of n (it's what we already do)
    assert parse_chat_body(_chat(n=1))["gen"] is None


def test_parse_chat_body_rejects_unsupported_openai_fields():
    """The documented subset contract: fields the batch-prompt plane cannot
    honor come back as a structured 400 pointing at the docs, never a
    silent ignore."""
    for field, value in (("logprobs", True), ("top_logprobs", 3),
                         ("logit_bias", {"50256": -100}), ("tools", [{}]),
                         ("tool_choice", "auto"), ("functions", [{}]),
                         ("function_call", "none"), ("stop", ["\n"]),
                         ("presence_penalty", 0.5),
                         ("frequency_penalty", 0.5), ("n", 2)):
        with pytest.raises(ApiError) as ei:
            parse_chat_body(_chat(**{field: value}))
        assert ei.value.status == 400
        assert ei.value.err_type == "unsupported_field_error"
        assert field.split("_")[0] in str(ei.value)
    # explicit null is indistinguishable from absent — accepted
    assert parse_chat_body(_chat(logprobs=None))["gen"] is None


def test_parse_chat_body_validates_sampling_ranges():
    for bad in (dict(temperature=-0.5), dict(temperature=2.5),
                dict(temperature="hot"), dict(top_p=0.0), dict(top_p=1.2),
                dict(seed=1.5), dict(seed=True), dict(max_tokens=0),
                dict(max_tokens="many")):
        with pytest.raises(ApiError) as ei:
            parse_chat_body(_chat(**bad))
        assert ei.value.status == 400


def test_frontend_stop_reports_clean_thread_exit(fitted_rb, pool, agnews):
    """A graceful stop joins the serving loop and the HTTP acceptor and
    records the clean exit in ``threads_leaked`` — the launcher's shutdown
    marker (``serve http: shutdown clean`` vs ``shutdown LEAKED``) keys off
    this list, so a wedged thread can never masquerade as a clean exit."""
    fe = HttpFrontend(_server(fitted_rb, pool, agnews), port=0).start()
    with _post(f"http://127.0.0.1:{fe.port}",
               {"messages": [{"role": "user", "content": "#1"}],
                "query_idx": 1}) as r:
        assert json.loads(r.read())["choices"]
    fe.stop()
    assert fe.threads_leaked == [], \
        f"graceful stop leaked threads: {fe.threads_leaked}"
