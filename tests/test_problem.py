"""Cost-model identities (Eqs. 1–4, 13) and batch-plan invariants."""
import numpy as np
import pytest

from repro.core.problem import Assignment, CostModel, group_into_batches


@pytest.fixture()
def cm(agnews, pool):
    return CostModel(pool, agnews)


def test_sys_cost_matches_eq2(cm, agnews, pool):
    for k, m in enumerate(pool):
        assert cm.sys_cost(k) == pytest.approx(agnews.sys_tokens * m.c_in / 1e6)


def test_query_cost_matches_eq2(cm, agnews, pool):
    idx = agnews.subset_indices("test")[:7]
    for k, m in enumerate(pool):
        want = agnews.in_tokens[idx] * m.c_in / 1e6 + agnews.out_tokens[idx] * m.c_out / 1e6
        np.testing.assert_allclose(cm.query_cost(k, idx), want)


def test_state_cost_amortizes_sys_prompt(cm, agnews):
    idx = agnews.subset_indices("test")[:5]
    c1 = cm.state_cost(0, 1, idx)
    c8 = cm.state_cost(0, 8, idx)
    np.testing.assert_allclose(c1 - c8, cm.sys_cost(0) * (1 - 1 / 8))


def test_exact_total_uses_ceiling(cm, agnews):
    # 10 queries at b=4 => ceil(10/4)=3 invocations
    idx = agnews.subset_indices("test")[:10]
    a = Assignment(query_idx=idx, model=np.zeros(10, int), batch=np.full(10, 4))
    want = 3 * cm.sys_cost(0) + cm.query_cost(0, idx).sum()
    assert cm.exact_total(a) == pytest.approx(want)


def test_amortized_vs_exact_equal_on_full_batches(cm, agnews):
    idx = agnews.subset_indices("test")[:16]
    a = Assignment(query_idx=idx, model=np.zeros(16, int), batch=np.full(16, 4))
    assert cm.amortized_total(a) == pytest.approx(cm.exact_total(a))


def test_amortized_lower_bounds_exact_on_partial_batches(cm, agnews):
    idx = agnews.subset_indices("test")[:10]
    a = Assignment(query_idx=idx, model=np.zeros(10, int), batch=np.full(10, 4))
    assert cm.amortized_total(a) <= cm.exact_total(a) + 1e-12


def test_group_into_batches_partitions_queries(cm, agnews):
    idx = agnews.subset_indices("test")[:33]
    rng = np.random.default_rng(0)
    a = Assignment(query_idx=idx, model=rng.integers(0, 3, 33),
                   batch=np.array([1, 2, 4])[rng.integers(0, 3, 33)])
    plan = group_into_batches(a)
    seen = np.concatenate([m for _, m in plan])
    assert sorted(seen.tolist()) == sorted(idx.tolist())
    for st, members in plan:
        assert 1 <= len(members) <= st.batch


def test_single_model_cost_reference(cm, agnews):
    idx = agnews.subset_indices("test")
    c_b1 = cm.single_model_cost(0, idx, 1)
    c_b8 = cm.single_model_cost(0, idx, 8)
    assert c_b8 < c_b1  # amortization always saves money
    saved = c_b1 - c_b8
    max_save = cm.sys_cost(0) * len(idx) * (1 - 1 / 8)
    assert saved <= max_save + 1e-9
