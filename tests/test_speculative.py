"""Routed speculative decoding: bit-identity of the draft/verify engine
against target-only decoding (the deterministic-match acceptance contract),
across draft quality extremes, slot counts and greedy/sampled requests, plus
the paged-KV rollback and telemetry invariants the rounds rely on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShardingConfig, get_arch
from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.generation import GenerationConfig
from repro.serving.speculative import SpeculativeEngine

TOK = ByteTokenizer()
MAX_LEN = 160
SYS = "system: you are a terse assistant; answer every query in order. "


@pytest.fixture(scope="module")
def tiny():
    cfg = get_arch("tiny-s")
    model = Model(cfg, ShardingConfig(remat="none"))
    return model, model.init(jax.random.PRNGKey(3))


@pytest.fixture(scope="module")
def draft_params(tiny):
    """Three draft-quality extremes: the target's own weights (all-accept),
    independent random weights (mixed accept), all-zero weights (the
    constant-logits degenerate draft — near-all-reject under sampling)."""
    model, params = tiny
    return {"identical": params,
            "random": model.init(jax.random.PRNGKey(99)),
            "zero": jax.tree.map(jnp.zeros_like, params)}


def _requests(sampled=False):
    """Shared-prefix batch with varying lengths and budgets; the sampled
    variant mixes per-request seeds/knobs (and exercises mixed batches via
    distinct configs per slot)."""
    out = []
    for i in range(5):
        p = SYS + f"query number {i} " + "ab" * (4 * i)
        g = None
        if sampled:
            g = GenerationConfig(max_new=8 + 4 * i, temperature=0.8,
                                 top_k=50, top_p=0.95, seed=7 + i)
        out.append(Request(rid=i, tokens=TOK.encode(p), max_new=8 + 4 * i,
                           gen=g))
    return out


@pytest.fixture(scope="module")
def target_only(tiny):
    """Reference streams from the target decoding alone (greedy + sampled)."""
    model, params = tiny

    def run(sampled):
        eng = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN,
                            decode_block=5, paged=True, page_size=16,
                            eos_id=-1)
        reqs = _requests(sampled)
        eng.serve(reqs)
        return [r.out_tokens for r in reqs]

    return {False: run(False), True: run(True)}


@pytest.mark.parametrize("sampled", [False, True])
@pytest.mark.parametrize("kind", ["identical", "random", "zero"])
@pytest.mark.parametrize("slots", [1, 4])
def test_bit_identical_to_target_only(tiny, draft_params, target_only,
                                      sampled, kind, slots):
    """The acceptance rule's whole point: whatever the draft proposes, the
    emitted stream IS the target-only stream — the draft moves the accept
    rate, never the text."""
    model, params = tiny
    spec = SpeculativeEngine(model, params, model, draft_params[kind],
                             max_slots=slots, max_len=MAX_LEN, spec_k=4,
                             page_size=16, eos_id=-1)
    reqs = _requests(sampled)
    spec.serve(reqs)
    assert [r.out_tokens for r in reqs] == target_only[sampled]
    # a full serve drains every slot: allocator consistent and empty on both
    # sides (truncation rollbacks never leak or double-free pages)
    for eng in (spec.target, spec.draft):
        eng.kv.alloc.check(tables=eng.kv.slot_pages)
        assert eng.kv.alloc.pages_in_use == 0


def test_identical_draft_accepts_nearly_everything(tiny, target_only):
    """Same weights on both sides ⇒ every comparison matches; the rate dips
    below 1.0 only because limit-truncated final windows count their unused
    draft positions as proposed."""
    model, params = tiny
    spec = SpeculativeEngine(model, params, model, params, max_slots=4,
                             max_len=MAX_LEN, spec_k=4, page_size=16,
                             eos_id=-1)
    spec.serve(_requests())
    assert spec.accept_rate() > 0.8
    assert spec.n_bonus > 0                  # fully accepted windows occurred


def test_counters_account_for_every_round(tiny, draft_params):
    model, params = tiny
    spec = SpeculativeEngine(model, params, model, draft_params["random"],
                             max_slots=4, max_len=MAX_LEN, spec_k=4,
                             page_size=16, eos_id=-1)
    reqs = _requests()
    spec.serve(reqs)
    n_tok = sum(len(r.out_tokens) for r in reqs)
    assert spec.n_rounds > 0
    # k proposals per active slot-round; acceptance can never exceed them
    assert spec.n_drafted % spec.spec_k == 0
    assert 0 <= spec.n_accepted <= spec.n_drafted
    # every emitted token is a prefill first-token, an accept, or ≤ 1
    # fallback/bonus token per slot-round — so totals bracket the stream
    assert spec.n_accepted + spec.n_bonus <= n_tok
    assert n_tok <= (spec.n_accepted + spec.n_drafted // spec.spec_k
                     + len(reqs))
    # each round is exactly one draft dispatch + one target dispatch
    assert spec.draft.n_decode_calls == spec.n_rounds
    assert spec.target.n_decode_calls == spec.n_rounds


def test_eos_retirement_parity(tiny, draft_params, target_only):
    """With a real (reachable) eos id the speculative engine must retire
    requests on exactly the token the target-only engine does — the window
    scan stops at EOS even mid-acceptance."""
    model, params = tiny
    flat = [t for w in target_only[False] for t in w[1:]]
    eos = max(set(flat), key=flat.count)     # a token greedy actually emits
    ref = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN,
                        decode_block=5, paged=True, page_size=16, eos_id=eos)
    r1 = _requests()
    ref.serve(r1)
    spec = SpeculativeEngine(model, params, model, draft_params["random"],
                             max_slots=4, max_len=MAX_LEN, spec_k=4,
                             page_size=16, eos_id=eos)
    r2 = _requests()
    spec.serve(r2)
    assert [r.out_tokens for r in r1] == [r.out_tokens for r in r2]
    assert any(eos in r.out_tokens for r in r2), "workload must hit EOS"


def test_spec_k_sweep_preserves_stream(tiny, draft_params, target_only):
    """The emitted stream is invariant to speculation depth (the window size
    only changes WHERE rounds fall, never what they emit)."""
    model, params = tiny
    for k in (1, 3, 8):
        spec = SpeculativeEngine(model, params, model, draft_params["random"],
                                 max_slots=4, max_len=MAX_LEN, spec_k=k,
                                 page_size=16, eos_id=-1)
        reqs = _requests()
        spec.serve(reqs)
        assert [r.out_tokens for r in reqs] == target_only[False], f"k={k}"


def test_generate_text_matches_plain_engine(tiny, draft_params):
    model, params = tiny
    plain = ServingEngine(model, params, max_slots=2, max_len=MAX_LEN,
                          decode_block=5, paged=True, page_size=16)
    spec = SpeculativeEngine(model, params, model, draft_params["random"],
                             max_slots=2, max_len=MAX_LEN, spec_k=4,
                             page_size=16)
    prompts = ["hello there", "speculate on this"]
    assert spec.generate_text(prompts, max_new=12) == \
        plain.generate_text(prompts, max_new=12)


def test_pool_member_surface(tiny, draft_params):
    """The drop-in contract ServedPoolMember and the replica factory rely
    on: config attributes, dispatch counters, kv occupancy with the draft
    footprint folded in."""
    model, params = tiny
    spec = SpeculativeEngine(model, params, model, draft_params["random"],
                             max_slots=2, max_len=MAX_LEN, spec_k=4,
                             page_size=16)
    assert spec.paged and spec.decode_block == 5
    spec.serve([Request(rid=0, tokens=TOK.encode("abc"), max_new=8)])
    occ = spec.kv_occupancy()
    # drained: no live pages on either side, but the peak saw both pools
    assert occ["kv_bytes"] == 0 and occ["draft_kv_bytes"] == 0
    assert occ["peak_kv_bytes"] > spec.target.kv_occupancy()["peak_kv_bytes"]
    assert spec.n_decode_calls == 2 * spec.n_rounds
    assert spec.n_prefill_calls >= 2         # target + shadow admission
    with pytest.raises(ValueError, match="spec_k"):
        SpeculativeEngine(model, params, model, params, spec_k=0)
