"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes and finiteness (assignment
requirement).  The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ARCH_IDS, ShardingConfig, get_arch
from repro.models.transformer import Model
from repro.training.optimizer import adamw


def _inputs_for(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend in ("vision", "audio") and not cfg.enc_dec:
        batch["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = Model(cfg, ShardingConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    batch = _inputs_for(cfg)

    # forward: shapes + finite
    logits, aux = model.forward(params, batch.get("tokens", batch.get("embeds")),
                                enc_inputs=batch.get("enc_embeds"))
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    # one train step: loss decreases over two steps on the same batch
    opt = adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    params, state, l0 = step(params, state, batch)
    params, state, l1 = step(params, state, batch)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0) + 1e-3, (float(l0), float(l1))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_arch(a).uses_kv_cache or get_arch(a).sub_quadratic])
def test_arch_smoke_decode(arch):
    """Prefill + one decode step matches the full forward on the extended seq."""
    cfg = get_arch(arch).reduced()
    if cfg.frontend == "vision":
        pytest.skip("decode consistency needs token inputs; VLM decode covered via dense trunks")
    model = Model(cfg, ShardingConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    enc = (jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)
           if cfg.enc_dec else None)
    logits, _ = model.forward(params, tokens, enc_inputs=enc)
    lg_pre, cache = model.prefill(params, tokens, max_len=S + 4, enc_inputs=enc)
    np.testing.assert_allclose(np.asarray(lg_pre[:, 0]), np.asarray(logits[:, -1]),
                               rtol=2e-2, atol=2e-2)
    tok = jnp.argmax(lg_pre[:, 0], -1).astype(jnp.int32)[:, None]
    lg_dec, _ = model.decode_step(params, tok, cache)
    logits2, _ = model.forward(params, jnp.concatenate([tokens, tok], axis=1), enc_inputs=enc)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]), np.asarray(logits2[:, -1]),
                               rtol=3e-2, atol=3e-2)


def test_scan_vs_unrolled_equivalence():
    """scan-over-groups must match the unrolled stack bit-for-bit-ish."""
    cfg = get_arch("qwen1.5-0.5b").reduced()
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 10)), jnp.int32)
    m_scan = Model(cfg, ShardingConfig(remat="none", scan_layers=True))
    params = m_scan.init(jax.random.PRNGKey(2))
    m_unroll = Model(cfg, ShardingConfig(remat="none", scan_layers=False))
    # re-key unrolled params from the scanned tree: rem{j} <- blocks stacked[j]
    up = {k: v for k, v in params.items() if k not in ("blocks",)}
    for j in range(cfg.n_layers):
        up[f"rem{j}"] = jax.tree.map(lambda x: x[j], params["blocks"]["b0"])
    l1, _ = m_scan.forward(params, tokens)
    l2, _ = m_unroll.forward(up, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)
