"""Slow smoke target: tools/smoke.sh runs the quickstart, the tiny real pool
(small step count), the online serving CLI once per registered policy, and
the HTTP front-end (ephemeral port, streamed curl, clean SIGTERM shutdown).

Deselected by default (pytest.ini adds ``-m "not slow"``); run with::

    PYTHONPATH=src python -m pytest -m slow tests/test_smoke.py
"""
import os
import subprocess

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_smoke_script():
    out = subprocess.run(["bash", os.path.join(ROOT, "tools", "smoke.sh")],
                         capture_output=True, text=True, timeout=2400)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "Routing stage" in out.stdout          # quickstart ran
    assert "fitting Robatch on the live pool" in out.stdout   # tiny pool ran
    # the serve CLI completed a stream under EVERY registered policy
    from repro.api import list_policies

    for name in list_policies():
        assert f"policy={name} windows=" in out.stdout, \
            f"serve CLI did not complete under policy {name!r}"
    # the semantic-cache leg served and printed its hit/miss summary
    assert "semcache: hits=" in out.stdout
    # the chaos leg injected faults yet ended with every breaker CLOSED
    assert "chaos: seed=7" in out.stdout
    assert "breakers_closed=True" in out.stdout
    # the HTTP leg booted, streamed over the wire and shut down cleanly
    assert "serve http: listening on http://127.0.0.1:" in out.stdout
    assert "serve http: shutdown clean" in out.stdout
    assert "smoke: OK" in out.stdout
