"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes kernel bodies on CPU), plus the ops-layer chunked
fallbacks against the same oracles, plus hypothesis property sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st   # property tests skip w/o hypothesis

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas

RNG = np.random.default_rng(0)


def arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hk,D,causal,win,qb,kb", [
    (2, 128, 4, 2, 32, True, None, 32, 32),
    (1, 64, 8, 8, 16, True, 16, 16, 16),
    (2, 96, 4, 1, 32, True, None, 32, 16),     # MQA, uneven blocks
    (1, 128, 2, 2, 64, False, None, 64, 32),   # bidirectional (encoder)
])
def test_flash_attention_vs_oracle(dtype, B, S, H, Hk, D, causal, win, qb, kb):
    q, k, v = (arr(B, S, H, D, dtype=dtype), arr(B, S, Hk, D, dtype=dtype),
               arr(B, S, Hk, D, dtype=dtype))
    want = ref.mha_ref(q, k, v, causal=causal, window=win)
    got = flash_attention_pallas(q, k, v, causal=causal, window=win,
                                 q_block=qb, kv_block=kb, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 8), st.integers(0, 2), st.booleans())
def test_flash_attention_property(B, sblocks, hk_pow, causal):
    """Random (shape, GQA ratio) sweep at block granularity."""
    S = 16 * sblocks
    Hk = 2 ** hk_pow
    H = Hk * 2
    D = 16
    q, k, v = arr(B, S, H, D), arr(B, S, Hk, D), arr(B, S, Hk, D)
    want = ref.mha_ref(q, k, v, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, q_block=16, kv_block=16,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_ops_flash_matches_oracle_uneven_and_offset():
    """ops fallback covers decode-style q/kv offset the kernel does not."""
    q, k, v = arr(2, 17, 4, 8), arr(2, 33, 2, 8), arr(2, 33, 2, 8)
    want = ref.mha_ref(q, k, v, causal=True)
    got = ops.flash_attention(q, k, v, causal=True, q_block=8, kv_block=8, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hk,D,kb", [
    (3, 40, 8, 2, 16, 16),
    (2, 128, 4, 4, 32, 32),
    (1, 100, 8, 1, 64, 32),
])
def test_decode_attention_vs_oracle(dtype, B, S, H, Hk, D, kb):
    q = arr(B, 1, H, D, dtype=dtype)
    k, v = arr(B, S, Hk, D, dtype=dtype), arr(B, S, Hk, D, dtype=dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    want = ref.decode_attention_ref(q, k, v, lengths)
    got = decode_attention_pallas(q, k, v, lengths, kv_block=kb, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(2, 60), st.integers(0, 2))
def test_decode_attention_property(B, S, gq):
    Hk, D = 2, 16
    H = Hk * 2 ** gq
    q, k, v = arr(B, 1, H, D), arr(B, S, Hk, D), arr(B, S, Hk, D)
    lengths = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    want = ref.decode_attention_ref(q, k, v, lengths)
    got = decode_attention_pallas(q, k, v, lengths, kv_block=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

def _paginate(k, v, page_size, lengths, n_extra=3):
    """Scatter contiguous ``(B, S, Hk, D)`` K/V into a shuffled page pool.

    Returns ``(k_pool, v_pool, table)`` where ``table[b, j]`` is the physical
    page holding logical positions ``[j*ps, (j+1)*ps)`` of row ``b``.  Table
    entries for pages entirely past ``lengths[b]`` are the sentinel ``P``
    (matching the engine's unmapped-column convention), the pool carries
    ``n_extra`` unreferenced pages, and every out-of-range element is filled
    with large garbage so any leak through the length mask is loud.
    """
    B, S, Hk, D = k.shape
    n_tab = -(-S // page_size)
    kp = np.full((B, n_tab * page_size, Hk, D), 1e3, np.float32)
    vp = np.full_like(kp, 1e3)
    kp[:, :S] = np.asarray(k, np.float32)
    vp[:, :S] = np.asarray(v, np.float32)
    P = B * n_tab + n_extra
    phys = RNG.permutation(P)[: B * n_tab].reshape(B, n_tab)
    k_pool = np.full((P, page_size, Hk, D), 1e3, np.float32)
    v_pool = np.full_like(k_pool, 1e3)
    k_pool[phys.reshape(-1)] = kp.reshape(B * n_tab, page_size, Hk, D)
    v_pool[phys.reshape(-1)] = vp.reshape(B * n_tab, page_size, Hk, D)
    table = np.where(np.arange(n_tab)[None] * page_size < np.asarray(lengths)[:, None],
                     phys, P)
    return (jnp.asarray(k_pool, k.dtype), jnp.asarray(v_pool, v.dtype),
            jnp.asarray(table, jnp.int32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("page_size", [16, 64])
@pytest.mark.parametrize("B,S,H,Hk,D", [
    (3, 40, 8, 2, 16),
    (2, 130, 4, 4, 32),
    (1, 64, 8, 1, 64),
])
def test_paged_attention_vs_oracle(dtype, page_size, B, S, H, Hk, D):
    q = arr(B, 1, H, D, dtype=dtype)
    k, v = arr(B, S, Hk, D, dtype=dtype), arr(B, S, Hk, D, dtype=dtype)
    # ragged lengths, always including one full-length row
    lengths = np.append(RNG.integers(1, S + 1, B - 1), S).astype(np.int32)
    k_pool, v_pool, table = _paginate(k, v, page_size, lengths)
    want = ref.decode_attention_ref(q, k, v, jnp.asarray(lengths))
    got = paged_attention_pallas(q, k_pool, v_pool, table, jnp.asarray(lengths),
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_attention_shared_and_forked_pages():
    """Two slots alias the same physical prefix pages; slot 1's tail page is a
    CoW fork holding divergent tokens.  Each row must match the dense oracle
    on its own logical sequence — sharing is invisible to attention."""
    ps, H, Hk, D = 16, 4, 2, 16
    S = 3 * ps
    pre_k, pre_v = arr(1, 2 * ps, Hk, D), arr(1, 2 * ps, Hk, D)
    tails = [(arr(1, ps, Hk, D), arr(1, ps, Hk, D)) for _ in range(2)]
    k = jnp.concatenate([jnp.concatenate([pre_k, tk], 1) for tk, _ in tails], 0)
    v = jnp.concatenate([jnp.concatenate([pre_v, tv], 1) for _, tv in tails], 0)
    # pool: pages 0-1 = shared prefix, 2 = slot0 tail, 3 = slot1 fork, 4 = junk
    k_pool = jnp.concatenate([pre_k.reshape(2, ps, Hk, D), tails[0][0], tails[1][0],
                              jnp.full((1, ps, Hk, D), 1e3)], 0)
    v_pool = jnp.concatenate([pre_v.reshape(2, ps, Hk, D), tails[0][1], tails[1][1],
                              jnp.full((1, ps, Hk, D), 1e3)], 0)
    table = jnp.asarray([[0, 1, 2], [0, 1, 3]], jnp.int32)
    lengths = jnp.asarray([S, S - 5], jnp.int32)   # forked row mid-page
    q = arr(2, 1, H, D)
    want = ref.decode_attention_ref(q, k, v, lengths)
    got = paged_attention_pallas(q, k_pool, v_pool, table, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_ops_paged_matches_oracle_jnp_fallback():
    """The ops-layer gather fallback agrees with the dense oracle (and hence
    with the kernel) on the same shuffled, sentinel-bearing table."""
    B, S, H, Hk, D, ps = 3, 70, 4, 2, 16, 16
    q, k, v = arr(B, 1, H, D), arr(B, S, Hk, D), arr(B, S, Hk, D)
    lengths = np.asarray([1, 37, 70], np.int32)
    k_pool, v_pool, table = _paginate(k, v, ps, lengths)
    want = ref.decode_attention_ref(q, k, v, jnp.asarray(lengths))
    got = ops.paged_attention(q, k_pool, v_pool, table, jnp.asarray(lengths),
                              backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(2, 70), st.sampled_from([16, 32]), st.integers(0, 2))
def test_paged_attention_property(B, S, page_size, gq):
    """Random shapes / GQA ratios / page sizes: paged gather == dense oracle."""
    Hk, D = 2, 16
    H = Hk * 2 ** gq
    q, k, v = arr(B, 1, H, D), arr(B, S, Hk, D), arr(B, S, Hk, D)
    lengths = RNG.integers(1, S + 1, B).astype(np.int32)
    k_pool, v_pool, table = _paginate(k, v, page_size, lengths)
    want = ref.decode_attention_ref(q, k, v, jnp.asarray(lengths))
    got = paged_attention_pallas(q, k_pool, v_pool, table, jnp.asarray(lengths),
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,D,chunk", [
    (2, 50, 3, 8, 16),
    (1, 64, 2, 16, 32),
    (2, 33, 4, 8, 8),       # non-multiple T
])
def test_wkv6_vs_oracle(dtype, B, T, H, D, chunk):
    r, k, v = (arr(B, T, H, D, dtype=dtype) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.4, 0.999, size=(B, T, H, D)), dtype)
    u = arr(H, D, scale=0.5)
    st0 = arr(B, H, D, D, scale=0.1)
    want, s_want = ref.wkv6_ref(r, k, v, w, u, state=st0)
    got, s_got = wkv6_pallas(r, k, v, w, u, state=st0, chunk=chunk, interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want), atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(2, 40), st.floats(0.3, 0.99))
def test_wkv6_property_decay_sweep(B, T, wmin):
    H, D = 2, 8
    r, k, v = (arr(B, T, H, D) for _ in range(3))
    w = jnp.asarray(RNG.uniform(wmin, 0.999, size=(B, T, H, D)), jnp.float32)
    u = arr(H, D, scale=0.5)
    want, _ = ref.wkv6_ref(r, k, v, w, u)
    got, _ = wkv6_pallas(r, k, v, w, u, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=5e-4)


def test_wkv6_ops_chunk_invariance():
    """The chunked jnp fallback must be chunk-size invariant."""
    B, T, H, D = 1, 48, 2, 8
    r, k, v = (arr(B, T, H, D) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.5, 0.99, size=(B, T, H, D)), jnp.float32)
    u = arr(H, D, scale=0.5)
    o1, s1 = ops.wkv6(r, k, v, w, u, chunk=8, backend="jnp")
    o2, s2 = ops.wkv6(r, k, v, w, u, chunk=48, backend="jnp")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,W,chunk,wb", [
    (2, 33, 16, 8, 16),
    (1, 100, 64, 32, 32),
    (3, 17, 32, 256, 16),   # chunk > T
])
def test_rglru_vs_oracle(dtype, B, T, W, chunk, wb):
    x = arr(B, T, W, dtype=dtype)
    a_log = -jnp.abs(arr(B, T, W, scale=0.5)).astype(jnp.float32)
    st0 = arr(B, W)
    want, s_want = ref.rglru_ref(x, a_log, state=st0)
    got, s_got = rglru_pallas(x, a_log, state=st0, chunk=chunk, w_block=wb,
                              interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want), atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 50), st.floats(0.05, 3.0))
def test_rglru_property(B, T, decay_scale):
    W = 16
    x = arr(B, T, W)
    a_log = -jnp.abs(arr(B, T, W)) * decay_scale
    want, s_want = ref.rglru_ref(x, a_log)
    got, s_got = ops.rglru_scan(x, a_log, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want), atol=1e-4, rtol=1e-4)


def test_state_chaining_equals_full_run():
    """Running two halves with carried state == one full run (all kernels)."""
    B, T, H, D = 1, 32, 2, 8
    r, k, v = (arr(B, T, H, D) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.5, 0.99, size=(B, T, H, D)), jnp.float32)
    u = arr(H, D, scale=0.5)
    full, s_full = wkv6_pallas(r, k, v, w, u, chunk=8, interpret=True)
    h1, s1 = wkv6_pallas(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, chunk=8, interpret=True)
    h2, s2 = wkv6_pallas(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, state=s1,
                         chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4, rtol=1e-4)
