"""Fused serving-engine generation path: greedy parity of the K-step
scan decode + batched bucket-grouped prefill against the per-token reference
driver, donation safety of the cache-carrying jits, and the host-dispatch
accounting the fusion exists to shrink."""
import jax
import numpy as np
import pytest

from repro.config import ShardingConfig, get_arch
from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = get_arch("tiny-s")
    model = Model(cfg, ShardingConfig(remat="none"))
    return model, model.init(jax.random.PRNGKey(3))


TOK = ByteTokenizer()
MAX_LEN = 160


def _requests():
    """Mixed-retirement workload: varying prompt lengths (spanning length
    buckets), varying max_new (max_new retirement at 1, 3, …), and one prompt
    long enough to hit the max_len−1 total-length ceiling."""
    prompts = [f"query number {i} " + "abc" * (7 * i) for i in range(6)]
    prompts.append("z" * (MAX_LEN - 8))            # total-length retirement
    max_news = (3, 1, 17, 40, 8, 25, 32)
    return [Request(rid=i, tokens=TOK.encode(p), max_new=m)
            for i, (p, m) in enumerate(zip(prompts, max_news))]


@pytest.fixture(scope="module")
def eos_id(tiny):
    """An eos id the untrained model actually emits mid-stream, so the parity
    sweep exercises genuine EOS retirement (not just max_new/max_len).
    Depends only on (model, params) — probed once for the whole module."""
    model, params = tiny
    probe = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN, eos_id=-1)
    reqs = _requests()
    probe.serve_stepwise(reqs)
    counts: dict[int, int] = {}
    for r in reqs:
        for t in r.out_tokens[1:]:
            counts[t] = counts.get(t, 0) + 1
    return max(counts, key=counts.get)


@pytest.mark.parametrize("slots", [1, 8])
@pytest.mark.parametrize("k", [1, 4, 8])
def test_fused_decode_parity_with_stepwise(tiny, eos_id, slots, k):
    model, params = tiny
    eos = eos_id
    ref = ServingEngine(model, params, max_slots=slots, max_len=MAX_LEN, eos_id=eos)
    r_ref = _requests()
    ref.serve_stepwise(r_ref)
    # the workload genuinely mixes retirement causes
    by_eos = [r for r in r_ref if eos in r.out_tokens]
    by_len = [r for r in r_ref if eos not in r.out_tokens]
    assert by_eos and by_len, "workload must retire by EOS and by max_new/max_len"
    assert all(r.done for r in r_ref)

    eng = ServingEngine(model, params, max_slots=slots, max_len=MAX_LEN,
                        decode_block=k, eos_id=eos)
    r_fused = _requests()
    eng.serve(r_fused)
    for a, b in zip(r_ref, r_fused):
        assert a.out_tokens == b.out_tokens, f"rid {a.rid} diverged"
        assert b.done
    # the fusion's point: K tokens per host dispatch, not one
    assert eng.n_decode_steps == eng.n_decode_calls * k
    if k > 1:
        assert eng.n_decode_calls < ref.n_decode_calls
    # batched admission: never more prefill dispatches than serving ticks
    assert eng.n_prefill_calls <= ref.n_prefill_calls


def test_generate_text_roundtrip_unchanged(tiny):
    # generate_text rides the fused path; sequential-vs-batched equality is
    # the legacy engine invariant and must survive the rewrite
    model, params = tiny
    prompts = [f"query number {i}" for i in range(5)]
    eng = ServingEngine(model, params, max_slots=2, max_len=128)
    batched = eng.generate_text(prompts, max_new=8)
    seq = []
    for p in prompts:
        e1 = ServingEngine(model, params, max_slots=1, max_len=128)
        seq.append(e1.generate_text([p], max_new=8)[0])
    assert batched == seq


def test_readmission_clears_stale_lifecycle_fields(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=2, max_len=128, eos_id=-1)
    req = Request(rid=0, tokens=TOK.encode("hello"), max_new=4)
    req.done = True                   # stale state from a failed prior attempt
    req.finished_at = 123.0
    eng.serve([req])
    assert req.done and req.finished_at != 123.0
    assert req.started_at is not None and req.finished_at >= req.started_at
    assert len(req.out_tokens) <= 4 + 1


# ---------------------------------------------------------------------------
# donation safety
# ---------------------------------------------------------------------------

def _first_kv_leaf(cache):
    return jax.tree.leaves(cache)[0]


def test_decode_k_donates_cache_in_place(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=4, max_len=128,
                        decode_block=4, eos_id=-1)
    reqs = [Request(rid=i, tokens=TOK.encode(f"donate {i}"), max_new=64)
            for i in range(4)]
    eng._admit_free(list(reqs))
    import jax.numpy as jnp

    last, act, n_out, limit = eng._slot_state()
    args = (jnp.asarray(last), jnp.asarray(act), jnp.asarray(n_out),
            jnp.asarray(limit))
    horizon = eng.max_len
    old = eng.cache
    p0 = _first_kv_leaf(old).unsafe_buffer_pointer()
    cache1, _act, _t, _v = eng._decode_k(horizon, eng.params, old, *args)
    donated = _first_kv_leaf(cache1).unsafe_buffer_pointer() == p0
    if donated:   # backend honors donation (CPU does on current jax)
        # use-after-donate must be impossible: the donated input is dead
        with pytest.raises(RuntimeError):
            _ = _first_kv_leaf(old) + 0
        # and the buffer identity stays stable across further fused steps
        cache2, *_ = eng._decode_k(horizon, eng.params, cache1, *args)
        assert _first_kv_leaf(cache2).unsafe_buffer_pointer() == p0
        eng.cache = cache2
    else:
        eng.cache = cache1
    # either way the engine state is live — no use-after-donate anywhere
    more = [Request(rid=9, tokens=TOK.encode("after"), max_new=3)]
    eng.serve(more)
    assert more[0].done


def test_insert_donates_and_engine_survives_interleaving(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=4, max_len=128, decode_block=8)
    p0 = _first_kv_leaf(eng.cache).unsafe_buffer_pointer()
    eng.serve([Request(rid=0, tokens=TOK.encode("first"), max_new=6)])
    ptr = _first_kv_leaf(eng.cache).unsafe_buffer_pointer()
    # serve again on the same engine: donated buffers were rewired, not leaked
    out = eng.generate_text(["second prompt"], max_new=6)
    assert len(out) == 1
    if ptr == p0:        # donation honored end-to-end: still the same buffer
        assert _first_kv_leaf(eng.cache).unsafe_buffer_pointer() == p0
