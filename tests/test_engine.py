"""Fused serving-engine generation path: greedy parity of the K-step
scan decode + batched bucket-grouped prefill against the per-token reference
driver, donation safety of the cache-carrying jits, and the host-dispatch
accounting the fusion exists to shrink."""
import jax
import numpy as np
import pytest

from repro.config import ShardingConfig, get_arch
from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = get_arch("tiny-s")
    model = Model(cfg, ShardingConfig(remat="none"))
    return model, model.init(jax.random.PRNGKey(3))


TOK = ByteTokenizer()
MAX_LEN = 160


def _requests():
    """Mixed-retirement workload: varying prompt lengths (spanning length
    buckets), varying max_new (max_new retirement at 1, 3, …), and one prompt
    long enough to hit the max_len−1 total-length ceiling."""
    prompts = [f"query number {i} " + "abc" * (7 * i) for i in range(6)]
    prompts.append("z" * (MAX_LEN - 8))            # total-length retirement
    max_news = (3, 1, 17, 40, 8, 25, 32)
    return [Request(rid=i, tokens=TOK.encode(p), max_new=m)
            for i, (p, m) in enumerate(zip(prompts, max_news))]


@pytest.fixture(scope="module")
def eos_id(tiny):
    """An eos id the untrained model actually emits mid-stream, so the parity
    sweep exercises genuine EOS retirement (not just max_new/max_len).
    Depends only on (model, params) — probed once for the whole module."""
    model, params = tiny
    probe = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN, eos_id=-1)
    reqs = _requests()
    probe.serve_stepwise(reqs)
    counts: dict[int, int] = {}
    for r in reqs:
        for t in r.out_tokens[1:]:
            counts[t] = counts.get(t, 0) + 1
    return max(counts, key=counts.get)


@pytest.mark.parametrize("slots", [1, 8])
@pytest.mark.parametrize("k", [1, 4, 8])
def test_fused_decode_parity_with_stepwise(tiny, eos_id, slots, k):
    model, params = tiny
    eos = eos_id
    ref = ServingEngine(model, params, max_slots=slots, max_len=MAX_LEN, eos_id=eos)
    r_ref = _requests()
    ref.serve_stepwise(r_ref)
    # the workload genuinely mixes retirement causes
    by_eos = [r for r in r_ref if eos in r.out_tokens]
    by_len = [r for r in r_ref if eos not in r.out_tokens]
    assert by_eos and by_len, "workload must retire by EOS and by max_new/max_len"
    assert all(r.done for r in r_ref)

    eng = ServingEngine(model, params, max_slots=slots, max_len=MAX_LEN,
                        decode_block=k, eos_id=eos)
    r_fused = _requests()
    eng.serve(r_fused)
    for a, b in zip(r_ref, r_fused):
        assert a.out_tokens == b.out_tokens, f"rid {a.rid} diverged"
        assert b.done
    # the fusion's point: K tokens per host dispatch, not one
    assert eng.n_decode_steps == eng.n_decode_calls * k
    if k > 1:
        assert eng.n_decode_calls < ref.n_decode_calls
    # batched admission: never more prefill dispatches than serving ticks
    assert eng.n_prefill_calls <= ref.n_prefill_calls


def test_generate_text_roundtrip_unchanged(tiny):
    # generate_text rides the fused path; sequential-vs-batched equality is
    # the legacy engine invariant and must survive the rewrite
    model, params = tiny
    prompts = [f"query number {i}" for i in range(5)]
    eng = ServingEngine(model, params, max_slots=2, max_len=128)
    batched = eng.generate_text(prompts, max_new=8)
    seq = []
    for p in prompts:
        e1 = ServingEngine(model, params, max_slots=1, max_len=128)
        seq.append(e1.generate_text([p], max_new=8)[0])
    assert batched == seq


def test_readmission_clears_stale_lifecycle_fields(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=2, max_len=128, eos_id=-1)
    req = Request(rid=0, tokens=TOK.encode("hello"), max_new=4)
    req.done = True                   # stale state from a failed prior attempt
    req.finished_at = 123.0
    eng.serve([req])
    assert req.done and req.finished_at != 123.0
    assert req.started_at is not None and req.finished_at >= req.started_at
    assert len(req.out_tokens) <= 4 + 1


# ---------------------------------------------------------------------------
# donation safety
# ---------------------------------------------------------------------------

def _first_kv_leaf(cache):
    return jax.tree.leaves(cache)[0]


def test_decode_k_donates_cache_in_place(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=4, max_len=128,
                        decode_block=4, eos_id=-1)
    reqs = [Request(rid=i, tokens=TOK.encode(f"donate {i}"), max_new=64)
            for i in range(4)]
    eng._admit_free(list(reqs))
    import jax.numpy as jnp

    last, act, n_out, limit = eng._slot_state()
    args = (jnp.asarray(last), jnp.asarray(act), jnp.asarray(n_out),
            jnp.asarray(limit))
    horizon = eng.max_len
    old = eng.cache
    p0 = _first_kv_leaf(old).unsafe_buffer_pointer()
    cache1, _act, _t, _v = eng._decode_k(horizon, eng.params, old, *args)
    donated = _first_kv_leaf(cache1).unsafe_buffer_pointer() == p0
    if donated:   # backend honors donation (CPU does on current jax)
        # use-after-donate must be impossible: the donated input is dead
        with pytest.raises(RuntimeError):
            _ = _first_kv_leaf(old) + 0
        # and the buffer identity stays stable across further fused steps
        cache2, *_ = eng._decode_k(horizon, eng.params, cache1, *args)
        assert _first_kv_leaf(cache2).unsafe_buffer_pointer() == p0
        eng.cache = cache2
    else:
        eng.cache = cache1
    # either way the engine state is live — no use-after-donate anywhere
    more = [Request(rid=9, tokens=TOK.encode("after"), max_new=3)]
    eng.serve(more)
    assert more[0].done


def test_insert_donates_and_engine_survives_interleaving(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=4, max_len=128, decode_block=8)
    p0 = _first_kv_leaf(eng.cache).unsafe_buffer_pointer()
    eng.serve([Request(rid=0, tokens=TOK.encode("first"), max_new=6)])
    ptr = _first_kv_leaf(eng.cache).unsafe_buffer_pointer()
    # serve again on the same engine: donated buffers were rewired, not leaked
    out = eng.generate_text(["second prompt"], max_new=6)
    assert len(out) == 1
    if ptr == p0:        # donation honored end-to-end: still the same buffer
        assert _first_kv_leaf(eng.cache).unsafe_buffer_pointer() == p0


# ---------------------------------------------------------------------------
# paged KV cache: parity, prefix sharing, CoW, donation
# ---------------------------------------------------------------------------

# a batch-prompt-style shared system prefix, long enough to span whole pages
SYS = "system: you are a terse assistant; answer every query in order. "


def _shared_requests():
    """The batch-prompting shape: every prompt opens with the same system
    prefix (several full pages at page_size=16), then diverges; retirement
    still mixes max_new sizes and a total-length ceiling."""
    prompts = [SYS + f"query number {i} " + "abc" * (5 * i) for i in range(5)]
    prompts.append(SYS)                            # prompt == the bare prefix
    prompts.append("z" * (MAX_LEN - 8))            # no shared prefix at all
    max_news = (3, 1, 17, 40, 8, 25, 32)
    return [Request(rid=i, tokens=TOK.encode(p), max_new=m)
            for i, (p, m) in enumerate(zip(prompts, max_news))]


@pytest.fixture(scope="module")
def stepwise_outputs(tiny, eos_id):
    """Greedy reference streams from the contiguous per-token driver — the
    fixed point every paged configuration must reproduce bit-for-bit."""
    model, params = tiny
    outs = {}
    for maker in (_requests, _shared_requests):
        eng = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN,
                            eos_id=eos_id)
        rs = maker()
        eng.serve_stepwise(rs)
        outs[maker.__name__] = [list(r.out_tokens) for r in rs]
    return outs


@pytest.mark.parametrize("slots", [1, 8])
@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("share", [True, False])
def test_paged_parity_with_contiguous(tiny, eos_id, stepwise_outputs, slots,
                                      k, share):
    """Paged serve() is bit-identical to the contiguous stepwise reference
    (and, by the fused-parity test above, to contiguous serve()) across
    K × slots × share-prefix; every page returns to the pool at drain."""
    model, params = tiny
    maker = _shared_requests if share else _requests
    eng = ServingEngine(model, params, max_slots=slots, max_len=MAX_LEN,
                        decode_block=k, eos_id=eos_id, paged=True,
                        page_size=16, share_prefix=share)
    rs = maker()
    eng.serve(rs)
    for r, want in zip(rs, stepwise_outputs[maker.__name__]):
        assert r.out_tokens == want, f"rid {r.rid} diverged"
        assert r.done
    eng.kv.alloc.check(tables=eng.kv.slot_pages)
    assert eng.kv.alloc.pages_in_use == 0          # fully drained
    if share and slots > 1:
        assert eng.kv.alloc.n_shares > 0           # sharing actually engaged


def test_paged_identical_prompts_fork_on_first_write(tiny, stepwise_outputs,
                                                     eos_id):
    """Identical prompts share ALL prompt pages (partial tail included);
    the first decode append then CoW-forks the boundary page — outputs must
    still match the contiguous reference exactly."""
    model, params = tiny

    def run(paged):
        eng = ServingEngine(model, params, max_slots=8, max_len=MAX_LEN,
                            decode_block=8, eos_id=eos_id, paged=paged,
                            page_size=16)
        rs = [Request(rid=i, tokens=TOK.encode(SYS), max_new=6 + i)
              for i in range(6)]
        (eng.serve if paged else eng.serve_stepwise)(rs)
        return [r.out_tokens for r in rs], eng

    want, _ = run(False)
    got, eng = run(True)
    assert got == want
    a = eng.kv.alloc
    assert a.n_forks > 0, "CoW never fired on a shared boundary page"
    assert a.pages_in_use == 0
    a.check(tables=eng.kv.slot_pages)


def test_paged_shared_admission_allocates_prompt_pages_once(tiny):
    """Admitting B siblings with one shared prompt stores the prompt pages
    ONCE: the owner allocates them, every sibling only bumps refcounts."""
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=8, max_len=MAX_LEN,
                        paged=True, page_size=16, eos_id=-1)
    toks = TOK.encode(SYS)                          # identical prompts
    n_pages = -(-len(toks) // 16)
    reqs = [Request(rid=i, tokens=list(toks), max_new=4) for i in range(4)]
    eng._admit_batch(reqs, [0, 1, 2, 3])
    a = eng.kv.alloc
    assert a.n_allocs == n_pages                    # owner's pages, once
    assert a.n_shares == 3 * n_pages                # 3 siblings, all refs
    assert a.pages_in_use == n_pages                # B× tables, 1× storage
    for s in (1, 2, 3):
        assert eng.kv.slot_pages[s] == eng.kv.slot_pages[0]
    a.check(tables=eng.kv.slot_pages)

    # share_prefix=False: same workload, every slot pays full storage
    eng2 = ServingEngine(model, params, max_slots=8, max_len=MAX_LEN,
                         paged=True, page_size=16, share_prefix=False,
                         eos_id=-1)
    reqs2 = [Request(rid=i, tokens=list(toks), max_new=4) for i in range(4)]
    eng2._admit_batch(reqs2, [0, 1, 2, 3])
    assert eng2.kv.alloc.pages_in_use == 4 * n_pages
    assert eng2.kv.alloc.n_shares == 0


def test_paged_decode_donates_cache_in_place(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=4, max_len=128,
                        decode_block=4, paged=True, page_size=16, eos_id=-1)
    reqs = [Request(rid=i, tokens=TOK.encode(f"donate {i}"), max_new=64)
            for i in range(4)]
    eng._admit_free(list(reqs))
    import jax.numpy as jnp

    last, act, n_out, limit = eng._slot_state()
    args = (jnp.asarray(last), jnp.asarray(act), jnp.asarray(n_out),
            jnp.asarray(limit))
    table = eng._prepare_paged(eng._active_slots(), eng.max_len)
    old = eng.cache
    p0 = _first_kv_leaf(old).unsafe_buffer_pointer()
    cache1, _act, _t, _v = eng._decode_k_paged(eng.params, old, table, *args)
    donated = _first_kv_leaf(cache1).unsafe_buffer_pointer() == p0
    if donated:   # backend honors donation (CPU does on current jax)
        with pytest.raises(RuntimeError):
            _ = _first_kv_leaf(old) + 0             # donated input is dead
        cache2, *_ = eng._decode_k_paged(eng.params, cache1, table, *args)
        assert _first_kv_leaf(cache2).unsafe_buffer_pointer() == p0
        eng.cache = cache2
    else:
        eng.cache = cache1
    # the engine state stays live through further paged serving either way
    more = [Request(rid=9, tokens=TOK.encode("after"), max_new=3)]
    eng.serve(more)
    assert more[0].done


def test_paged_stepwise_is_refused(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=2, max_len=128, paged=True)
    with pytest.raises(RuntimeError, match="contiguous parity reference"):
        eng.serve_stepwise([Request(rid=0, tokens=TOK.encode("x"), max_new=2)])


def test_paged_kv_occupancy_reports_pool_state(tiny):
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=4, max_len=128, paged=True,
                        page_size=16, eos_id=-1)
    occ0 = eng.kv_occupancy()
    assert occ0["paged"] and occ0["pages_used"] == 0 and occ0["page_bytes"] > 0
    eng.serve([Request(rid=0, tokens=TOK.encode(SYS), max_new=4)])
    occ = eng.kv_occupancy()
    assert occ["pages_used"] == 0                   # drained after retirement
    assert occ["peak_pages"] > 0
    assert occ["peak_kv_bytes"] == occ["peak_pages"] * occ["page_bytes"]
    # contiguous engines report committed bytes, no page counters
    eng_c = ServingEngine(model, params, max_slots=4, max_len=128)
    occ_c = eng_c.kv_occupancy()
    assert not occ_c["paged"] and occ_c["kv_bytes"] > 0
