"""BlockAllocator / PagedCacheManager invariants.

Property-based: hypothesis drives random alloc / share / CoW-fork / free
sequences against a model of the pool and asserts the allocator's invariants
after every step — refcounts equal table references, pages are never both
free and referenced, a fork never aliases, pages-in-use never exceeds the
pool, and freeing a retired slot returns exactly its non-shared pages.

Example-based twins of each property run without hypothesis (hypcompat skips
only the ``@given`` tests), so the allocator keeps real coverage even where
hypothesis is absent.
"""
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.serving.kvpool import BlockAllocator, OutOfPages, PagedCacheManager


# ---------------------------------------------------------------------------
# BlockAllocator — example tests
# ---------------------------------------------------------------------------

def test_alloc_free_roundtrip():
    a = BlockAllocator(4, 16)
    pages = a.alloc_n(4)
    assert sorted(pages) == [0, 1, 2, 3]
    assert a.pages_in_use == 4 and a.pages_free == 0
    with pytest.raises(OutOfPages):
        a.alloc()
    for p in pages:
        assert a.release(p) is True
    assert a.pages_in_use == 0 and a.n_frees == 4
    a.check()


def test_share_and_release_order():
    a = BlockAllocator(4, 16)
    p = a.alloc()
    a.share(p)
    a.share(p)
    assert a.refcount(p) == 3 and a.pages_shared == 1
    assert a.release(p) is False           # two references remain
    assert a.release(p) is False
    assert a.release(p) is True            # last reference frees
    with pytest.raises(ValueError):
        a.release(p)                       # double free is a hard error
    a.check()


def test_fork_gives_private_nonaliased_page():
    a = BlockAllocator(4, 16)
    p = a.alloc()
    a.share(p)
    q = a.fork(p)
    assert q != p                          # CoW never aliases
    assert a.refcount(p) == 1 and a.refcount(q) == 1
    assert a.n_forks == 1
    with pytest.raises(ValueError):
        a.fork(p)                          # forking a private page is a bug
    a.check()


def test_share_unreferenced_is_error():
    a = BlockAllocator(2, 16)
    with pytest.raises(ValueError):
        a.share(0)


def test_peak_tracks_high_water():
    a = BlockAllocator(8, 16)
    pages = a.alloc_n(5)
    for p in pages:
        a.release(p)
    a.alloc()
    assert a.peak_pages == 5 and a.pages_in_use == 1


# ---------------------------------------------------------------------------
# BlockAllocator — hypothesis property sweep
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 12),
       st.lists(st.tuples(st.sampled_from(["alloc", "share", "fork", "free"]),
                          st.integers(0, 10**6)), max_size=60))
def test_allocator_invariants_under_random_ops(n_pages, ops):
    """Drive random op sequences against a reference model (a list of held
    references per page); the allocator must agree with the model and pass
    ``check`` after every single transition."""
    a = BlockAllocator(n_pages, 16)
    held: list[int] = []                   # one entry per outstanding reference
    for op, pick in ops:
        if op == "alloc":
            if len(set(held)) < n_pages:
                held.append(a.alloc())
            else:
                with pytest.raises(OutOfPages):
                    a.alloc()
        elif op == "share" and held:
            p = held[pick % len(held)]
            a.share(p)
            held.append(p)
        elif op == "fork" and held:
            p = held[pick % len(held)]
            if held.count(p) >= 2:
                q = a.fork(p)
                assert q not in held       # fresh page, never aliased
                held.remove(p)
                held.append(q)
            else:
                with pytest.raises(ValueError):
                    a.fork(p)
        elif op == "free" and held:
            p = held.pop(pick % len(held))
            assert a.release(p) is (p not in held)
        # allocator state == model state, every step
        assert a.pages_in_use == len(set(held))
        assert a.pages_in_use <= n_pages
        assert a.pages_shared == sum(1 for p in set(held) if held.count(p) > 1)
        for p in set(held):
            assert a.refcount(p) == held.count(p)
        a.check()


# ---------------------------------------------------------------------------
# PagedCacheManager
# ---------------------------------------------------------------------------

def test_manager_default_sizing_never_oom():
    m = PagedCacheManager(max_slots=4, max_len=100, page_size=16)
    assert m.pages_per_slot == 7           # ceil(100/16)
    assert m.alloc.n_pages == 28
    for s in range(4):                     # every slot filled to the brim
        m.map_slot(s, m.alloc.alloc_n(m.pages_per_slot))
    assert m.alloc.pages_free == 0
    m.alloc.check(tables=m.slot_pages)


def test_release_slot_returns_only_unshared_pages():
    m = PagedCacheManager(max_slots=3, max_len=64, page_size=16)
    owner = m.alloc.alloc_n(3)
    m.map_slot(0, owner)
    # sibling shares the first 2 pages, owns 1 private
    sib = [m.alloc.share(owner[0]), m.alloc.share(owner[1]), m.alloc.alloc()]
    m.map_slot(1, sib)
    # retiring the sibling frees exactly its private page
    assert m.release_slot(1) == 1
    assert (m.table[1] == m.alloc.n_pages).all()
    # now the owner's pages are all private again; retiring frees all 3
    assert m.release_slot(0) == 3
    assert m.alloc.pages_in_use == 0
    m.alloc.check(tables=m.slot_pages)


def test_extend_and_fork_for_write():
    m = PagedCacheManager(max_slots=2, max_len=64, page_size=16)
    owner = m.alloc.alloc_n(2)             # positions [0, 32)
    m.map_slot(0, owner)
    m.map_slot(1, [m.alloc.share(p) for p in owner])
    # slot 1 appends at position 30: page 1 (shared) must fork, page 0 must not
    src, dst = m.fork_for_write(1, 30, 34)
    assert src == [owner[1]] and len(dst) == 1 and dst[0] != owner[1]
    assert m.slot_pages[1][1] == dst[0] and m.table[1, 1] == dst[0]
    assert m.alloc.refcount(owner[1]) == 1  # owner keeps the original
    # growing to cover position 34 allocates exactly one fresh private page
    new = m.extend_slot(1, 3)
    assert len(new) == 1 and m.table[1, 2] == new[0]
    # idempotent: already covered
    assert m.extend_slot(1, 3) == []
    m.alloc.check(tables=m.slot_pages)
    # the write range [30, 34) is now fully private to slot 1
    assert m.fork_for_write(1, 30, 34) == ([], [])


def test_table_sentinel_marks_unmapped():
    m = PagedCacheManager(max_slots=2, max_len=64, page_size=16)
    m.map_slot(0, m.alloc.alloc_n(2))
    assert (m.table[0, 2:] == m.alloc.n_pages).all()
    assert (m.table[1] == m.alloc.n_pages).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.sampled_from(["admit", "grow", "retire"]),
                          st.integers(1, 96), st.integers(0, 3)), max_size=40))
def test_manager_invariants_under_slot_churn(ops):
    """Random admit(share-with)/grow/retire slot lifecycles: table references
    and refcounts must agree after every step, and the pool can never run
    out under default sizing."""
    m = PagedCacheManager(max_slots=4, max_len=96, page_size=16)
    lens = [0, 0, 0, 0]
    for slot, op, ln, other in ops:
        if op == "admit":
            if lens[slot]:
                m.release_slot(slot)
            n_need = -(-ln // 16)
            donor = m.slot_pages[other] if other != slot else []
            n_sh = min(len(donor), n_need)
            pages = [m.alloc.share(p) for p in donor[:n_sh]]
            pages += m.alloc.alloc_n(n_need - n_sh)
            m.map_slot(slot, pages)
            lens[slot] = ln
        elif op == "grow" and lens[slot]:
            end = min(lens[slot] + 8, 96)
            m.extend_slot(slot, -(-end // 16))
            m.fork_for_write(slot, lens[slot], end)
            lens[slot] = end
        elif op == "retire" and lens[slot]:
            m.release_slot(slot)
            lens[slot] = 0
        live = [p for pages in m.slot_pages for p in pages]
        assert m.alloc.pages_in_use == len(set(live)) <= m.alloc.n_pages
        m.alloc.check(tables=m.slot_pages)
        for s in range(4):
            np.testing.assert_array_equal(
                m.table[s, :len(m.slot_pages[s])], m.slot_pages[s])
            assert (m.table[s, len(m.slot_pages[s]):] == m.alloc.n_pages).all()
    # draining every slot returns the pool to empty
    for s in range(4):
        m.release_slot(s)
    assert m.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# truncate_slot — the speculative-decode rollback primitive
# ---------------------------------------------------------------------------

def test_truncate_slot_releases_only_past_keep_point():
    m = PagedCacheManager(max_slots=2, max_len=96, page_size=16)
    m.map_slot(0, m.alloc.alloc_n(4))            # covers positions [0, 64)
    # keep 33 tokens → ceil(33/16) = 3 pages stay mapped, 1 freed
    assert m.truncate_slot(0, 33) == 1
    assert len(m.slot_pages[0]) == 3
    assert (m.table[0, 3:] == m.alloc.n_pages).all()
    # already covered: truncating to the same (or a longer) point is a no-op
    assert m.truncate_slot(0, 33) == 0
    assert m.truncate_slot(0, 48) == 0
    m.alloc.check(tables=m.slot_pages)


def test_truncate_shared_suffix_drops_reference_not_page():
    """A rejected draft suffix on a COW-shared page must only drop this
    slot's reference — the sibling keeps its KV; refcounts step down by
    exactly one."""
    m = PagedCacheManager(max_slots=2, max_len=96, page_size=16)
    owner = m.alloc.alloc_n(3)
    m.map_slot(0, owner)
    m.map_slot(1, [m.alloc.share(p) for p in owner])
    assert m.alloc.refcount(owner[2]) == 2
    # slot 1 rolls back past the last shared page: 0 pages actually freed
    assert m.truncate_slot(1, 32) == 0
    assert m.alloc.refcount(owner[2]) == 1       # owner keeps the page
    assert len(m.slot_pages[1]) == 2
    # the owner's rollback of the now-private page really frees it
    assert m.truncate_slot(0, 32) == 1
    m.alloc.check(tables=m.slot_pages)
    assert m.release_slot(0) + m.release_slot(1) == 2  # shared pair remains


def test_truncate_to_zero_empties_slot():
    m = PagedCacheManager(max_slots=1, max_len=64, page_size=16)
    m.map_slot(0, m.alloc.alloc_n(4))
    assert m.truncate_slot(0, 0) == 4
    assert m.slot_pages[0] == [] and m.alloc.pages_in_use == 0
    assert (m.table[0] == m.alloc.n_pages).all()


def test_truncate_then_extend_reuses_pool():
    """Rollback → re-grow cycles (every speculative round) must not leak:
    the free list absorbs truncated pages and hands them back on extend."""
    m = PagedCacheManager(max_slots=1, max_len=64, page_size=16)
    m.map_slot(0, m.alloc.alloc_n(2))
    for _ in range(8):
        assert m.truncate_slot(0, 16) == 1       # roll back to one page
        assert len(m.extend_slot(0, 2)) == 1     # grow to 2 pages again
        m.alloc.check(tables=m.slot_pages)
    assert m.alloc.pages_in_use == 2
    assert m.alloc.peak_pages == 2               # reuse, not fresh allocation
