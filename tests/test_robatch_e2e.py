"""End-to-end Robatch behaviour + baselines + ablations on the simulated pool."""
import numpy as np
import pytest

from repro.core import Robatch, execute, execute_plan
from repro.core.baselines import (
    batch_only,
    batcher_assignment_plan,
    frugalgpt_execute,
    obp_plan,
    routellm_assignment,
    router_only,
    single_model_assignment,
)


@pytest.fixture(scope="module")
def budgets(fitted_rb, agnews):
    cm = fitted_rb.cost_model
    test = agnews.subset_indices("test")
    cheap = cm.single_model_cost(0, test, 1)
    exp = cm.single_model_cost(2, test, 1)
    return cheap, (cheap + exp) / 2, exp


def test_fit_produces_calibrations(fitted_rb, pool):
    assert len(fitted_rb.calibrations) == len(pool)
    for cal in fitted_rb.calibrations:
        assert cal.b_effect >= 1
        assert cal.u_mean_at[1] > 0.3


def test_resilience_ordering(fitted_rb):
    """Larger models tolerate larger batches (Fig. 3) — b_effect ordering."""
    effs = [c.b_effect for c in fitted_rb.calibrations]
    assert effs[0] <= effs[-1]


def test_accuracy_increases_with_budget(fitted_rb, agnews, pool, budgets):
    test = agnews.subset_indices("test")
    accs = []
    for b in budgets:
        res = fitted_rb.schedule(test, b)
        accs.append(execute(pool, agnews, res.assignment).accuracy)
    assert accs[0] <= accs[1] + 0.02 and accs[1] <= accs[2] + 0.02
    assert accs[2] > accs[0]


def test_robatch_beats_single_model_frontier(fitted_rb, agnews, pool, budgets):
    """At the mid budget Robatch should dominate serving everything on the
    mid model at b=1 (the paper's headline claim, qualitatively).

    Accuracy tolerance is small-sample noise scale: 256 test queries on the
    shrunken fixture workload put ~0.004 per query, and the knn router on 512
    train points is noisier than the paper's full setup.  (The workload draw
    is deterministic since make_workload stopped seeding from the salted
    built-in hash(); the old 0.01 tolerance was a per-process coin flip.)"""
    test = agnews.subset_indices("test")
    cm = fitted_rb.cost_model
    mid_cost = cm.single_model_cost(1, test, 1)
    res = fitted_rb.schedule(test, mid_cost)
    ours = execute(pool, agnews, res.assignment)
    mid = execute(pool, agnews, single_model_assignment(test, 1, 1))
    assert ours.exact_cost <= mid.exact_cost * 1.05
    assert ours.accuracy >= mid.accuracy - 0.03


def test_schedule_timed_breakdown(fitted_rb, agnews, budgets):
    test = agnews.subset_indices("test")
    res, t = fitted_rb.schedule_timed(test, budgets[1])
    assert set(t) == {"router", "proxy", "greedy", "total"}
    assert t["total"] >= t["greedy"]


def test_router_only_ablation(fitted_rb, agnews, pool, budgets):
    ro = router_only(fitted_rb)
    test = agnews.subset_indices("test")
    res = ro.schedule(test, budgets[1])
    assert np.all(res.assignment.batch == 1)
    out = execute(pool, agnews, res.assignment)
    # full Robatch at the same budget is at least as good (joint optimization)
    full = execute(pool, agnews, fitted_rb.schedule(test, budgets[1]).assignment)
    assert full.accuracy >= out.accuracy - 0.03


def test_batch_only_ablation(fitted_rb, agnews, pool, budgets):
    bo = batch_only(fitted_rb, k=0)
    test = agnews.subset_indices("test")
    res = bo.schedule(test, budgets[0])
    assert np.all(res.assignment.model == 0)
    out = execute(pool, agnews, res.assignment)
    assert 0.0 <= out.accuracy <= 1.0


def test_routellm_baseline(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    a = routellm_assignment(fitted_rb, test, tau=0.6, b=8)
    assert set(np.unique(a.model)) <= {0, len(pool) - 1}
    out = execute(pool, agnews, a)
    assert 0.3 <= out.accuracy <= 1.0


def test_frugalgpt_cascade_bills_every_level(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:64]
    out_low = frugalgpt_execute(fitted_rb, test, tau=0.05, b=8)
    out_high = frugalgpt_execute(fitted_rb, test, tau=0.9, b=8)
    # more escalation => strictly more cost
    assert out_high.exact_cost > out_low.exact_cost * 0.99


def test_batcher_sim_div_plans_cover_queries(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")[:128]
    for mode in ["sim", "div"]:
        a, plan = batcher_assignment_plan(fitted_rb, test, tau=0.5, b=8, mode=mode)
        seen = np.concatenate([m for _, m in plan])
        assert sorted(seen.tolist()) == sorted(test.tolist())
        out = execute_plan(pool, agnews, plan, test)
        assert 0.3 <= out.accuracy <= 1.0


def test_obp_respects_context_window(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")[:128]
    a, plan = obp_plan(fitted_rb, test, tau=0.5, target_b=8)
    for st, members in plan:
        total = agnews.sys_tokens + agnews.in_tokens[members].sum()
        assert total <= pool[st.model].context_len


def test_profile_save_load_roundtrip(fitted_rb, agnews, pool, tmp_path, budgets):
    p = str(tmp_path / "profile.pkl")
    fitted_rb.save_profile(p)
    rb2 = Robatch(pool, agnews, router_kind=fitted_rb.router_kind).load_profile(p)
    test = agnews.subset_indices("test")
    r1 = fitted_rb.schedule(test, budgets[1])
    r2 = rb2.schedule(test, budgets[1])
    np.testing.assert_array_equal(r1.assignment.model, r2.assignment.model)
    np.testing.assert_array_equal(r1.assignment.batch, r2.assignment.batch)
