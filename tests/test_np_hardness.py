"""Thm. 3.2: the Maximum-Coverage reduction.

We build the paper's reduction instance from random MC instances and check
that the optimal Route-with-Batching objective equals the optimal coverage
(both solved by brute force on micro instances) — validating that the
constructed routing instance is exactly as hard as MC.
"""
import itertools

import numpy as np
import pytest
from hypcompat import given, settings, st   # property tests skip w/o hypothesis

from repro.core.pareto import CandidateSpace
from repro.core.problem import State


def mc_brute_force(sets: list[set], budget: int) -> int:
    n_elems = len(set().union(*sets)) if sets else 0
    best = 0
    for chosen in itertools.combinations(range(len(sets)), min(budget, len(sets))):
        covered = set().union(*(sets[k] for k in chosen)) if chosen else set()
        best = max(best, len(covered))
    return best


def reduction_space(sets: list[set], n: int) -> CandidateSpace:
    """The Thm. 3.2 construction: B_k = {n}, C_sys = 1, C_q = 0,
    u_{i,k,n} = 1 iff e_i ∈ T_k."""
    K = len(sets)
    states = [State(k, n) for k in range(K)]
    cost = np.zeros((n, K))     # per-query amortized cost = C_sys/n; see below
    util = np.zeros((n, K))
    for k, T in enumerate(sets):
        cost[:, k] = 1.0 / n     # C_sys(m_k)/b with C_sys=1, b=n
        for e in T:
            util[e, k] = 1.0
    return CandidateSpace(states=states, cost=cost, util=util, initial_state=0)


def routing_brute_force(space: CandidateSpace, n: int, budget: float) -> float:
    """Exact optimum of the constructed instance under Eq. 4 accounting:
    cost = number of *used* models (each used model serves ≤ n queries in one
    invocation of batch size n)."""
    K = len(space.states)
    best = 0.0
    for r in range(0, min(K, int(budget)) + 1):
        for used in itertools.combinations(range(K), r):
            u = space.util[:, list(used)].max(axis=1).sum() if used else 0.0
            best = max(best, u)
    return best


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 5), st.integers(2, 5), st.integers(1, 3), st.integers(0, 10_000))
def test_reduction_equivalence(K, n, B, seed):
    rng = np.random.default_rng(seed)
    sets = []
    for _ in range(K):
        members = set(int(i) for i in np.where(rng.uniform(size=n) < 0.5)[0])
        if not members:
            members = {int(rng.integers(n))}
        sets.append(members)
    covered_all = set().union(*sets)
    # restrict universe to covered elements (paper: elements = ∪ T_k)
    mc_opt = mc_brute_force(sets, B)
    space = reduction_space(sets, n)
    route_opt = routing_brute_force(space, n, float(B))
    assert route_opt == pytest.approx(mc_opt)


def test_reduction_cost_counts_used_models():
    """C(m_k, n) = ceil(N_k / n) = 1 iff the model is used: total cost equals
    the number of used models, as the proof sketch argues."""
    sets = [{0, 1}, {2}]
    n = 3
    # model 0 serves {0,1}: ceil(2/3)=1; model 1 serves {2}: ceil(1/3)=1
    assert int(np.ceil(2 / n)) == 1 and int(np.ceil(1 / n)) == 1
