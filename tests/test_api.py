"""Unified control-plane API: registry round-trip, declarative specs, and
bit-identical parity of every ported policy against its legacy entry point."""
import json

import numpy as np
import pytest

from repro.api import (
    Gateway,
    PolicySpec,
    PoolSpec,
    RunSpec,
    SchedulingPolicy,
    UnknownPolicyError,
    get_policy,
    list_policies,
    register_policy,
)
from repro.core import execute, execute_plan
from repro.core.baselines import (
    batch_only,
    batcher_assignment_plan,
    frugalgpt_execute,
    obp_plan,
    routellm_assignment,
    router_only,
)
from repro.serving.online import OnlineConfig, OnlineRobatchServer, poisson_arrivals

EXPECTED = ["batch-only", "batcher-div", "batcher-sim", "frugalgpt", "obp",
            "robatch", "robatch-vec", "routellm", "router-only"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_builtin_policies():
    assert set(EXPECTED) <= set(list_policies())


def test_get_policy_roundtrip():
    for name in list_policies():
        cls = get_policy(name)
        assert issubclass(cls, SchedulingPolicy)
        assert cls.name == name


def test_unknown_policy_raises_with_known_names():
    with pytest.raises(UnknownPolicyError, match="robatch"):
        get_policy("definitely-not-registered")


def test_register_policy_rejects_non_policies():
    with pytest.raises(TypeError):
        register_policy("bad")(object)


def test_register_policy_makes_custom_strategy_available():
    @register_policy("test-custom")
    class Custom(SchedulingPolicy):
        def plan(self, query_idx, budget=None, timings=None):
            raise NotImplementedError

    try:
        assert get_policy("test-custom") is Custom
        assert "test-custom" in list_policies()
    finally:
        from repro.api.policy import _REGISTRY

        _REGISTRY.pop("test-custom", None)


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def test_runspec_dict_roundtrip():
    spec = RunSpec(pool=PoolSpec(task="gsm8k", family="gemma3", n_train=64,
                                 replicas=3),
                   policy=PolicySpec("routellm", {"tau": 0.6, "b": 4}),
                   router="knn", coreset_size=32)
    assert RunSpec.from_dict(spec.to_dict()) == spec
    assert RunSpec.from_dict(spec.to_dict()).pool.replicas == 3


def test_poolspec_replicas_build_replicated_members():
    wl, pool = PoolSpec(n_train=32, n_val=8, n_test=16, replicas=2).build()
    assert all(m.n_replicas == 2 for m in pool)
    with pytest.raises(ValueError, match="replicas"):
        PoolSpec(replicas=0).build()


def test_runspec_json_roundtrip():
    spec = RunSpec(pool=PoolSpec(kind="tiny", steps=10),
                   policy=PolicySpec("obp", {"b": 4}))
    text = spec.to_json()
    json.loads(text)                     # valid JSON
    assert RunSpec.from_json(text) == spec


def test_runspec_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown spec keys"):
        RunSpec.from_dict({"routerr": "knn"})
    with pytest.raises(ValueError, match="unknown spec keys"):
        PoolSpec.from_dict({"kind": "simulated", "famly": "qwen3"})


def test_poolspec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        PoolSpec(kind="quantum").build()


# ---------------------------------------------------------------------------
# parity: each ported policy == its legacy entry point, bit for bit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gateway(fitted_rb, agnews, pool):
    return Gateway(pool, agnews, artifacts=fitted_rb)


@pytest.fixture(scope="module")
def mid_budget(fitted_rb, agnews):
    test = agnews.subset_indices("test")
    return float(fitted_rb.cost_model.single_model_cost(1, test, 1))


def _legacy(name, rb, pool, wl, test, budget):
    if name == "robatch":
        return execute(pool, wl, rb.schedule(test, budget).assignment)
    if name == "robatch-vec":
        return execute(pool, wl,
                       rb.schedule(test, budget, scheduler="vectorized").assignment)
    if name == "routellm":
        return execute(pool, wl, routellm_assignment(rb, test, tau=0.5, b=8))
    if name == "frugalgpt":
        return frugalgpt_execute(rb, test, tau=0.5, b=8)
    if name == "batcher-sim":
        _, plan = batcher_assignment_plan(rb, test, tau=0.5, b=8, mode="sim")
        return execute_plan(pool, wl, plan, test)
    if name == "batcher-div":
        _, plan = batcher_assignment_plan(rb, test, tau=0.5, b=8, mode="div")
        return execute_plan(pool, wl, plan, test)
    if name == "obp":
        _, plan = obp_plan(rb, test, tau=0.5, target_b=8)
        return execute_plan(pool, wl, plan, test)
    if name == "router-only":
        return execute(pool, wl, router_only(rb).schedule(test, budget).assignment)
    if name == "batch-only":
        variant = batch_only(rb, 1)
        return execute(variant.pool, wl, variant.schedule(test, budget).assignment)
    raise AssertionError(name)


PARAMS = {"routellm": dict(tau=0.5, b=8), "frugalgpt": dict(tau=0.5, b=8),
          "batcher-sim": dict(tau=0.5, b=8), "batcher-div": dict(tau=0.5, b=8),
          "obp": dict(tau=0.5, b=8), "batch-only": dict(model=1)}


@pytest.mark.parametrize("name", EXPECTED)
def test_policy_parity_with_legacy_entry_point(name, gateway, fitted_rb,
                                               agnews, pool, mid_budget):
    test = agnews.subset_indices("test")
    legacy = _legacy(name, fitted_rb, pool, agnews, test, mid_budget)
    ours = gateway.submit(test, budget=mid_budget, policy=name,
                          **PARAMS.get(name, {}))
    assert ours.accuracy == legacy.accuracy
    assert ours.exact_cost == legacy.exact_cost
    assert ours.n_invocations == legacy.n_invocations
    assert np.array_equal(ours.per_query_utility, legacy.per_query_utility)


def test_gateway_shares_one_artifact_bundle(gateway):
    p1 = gateway.policy("routellm", tau=0.5, b=8)
    p2 = gateway.policy("obp", tau=0.5, b=8)
    assert p1.rb is gateway.robatch and p2.rb is gateway.robatch
    assert gateway.policy("routellm", tau=0.5, b=8) is p1   # cached


def test_plan_carries_schedule_and_costs(gateway, agnews, mid_budget):
    test = agnews.subset_indices("test")[:64]
    plan = gateway.plan(test, budget=mid_budget, policy="robatch")
    assert plan.schedule is not None and not plan.schedule.infeasible
    assert len(plan.group_costs) == len(plan.groups)
    assert plan.est_cost == pytest.approx(sum(plan.group_costs))


def test_plan_timed_covers_any_policy(gateway, agnews, mid_budget):
    test = agnews.subset_indices("test")[:64]
    for name, params in [("robatch", {}), ("routellm", dict(tau=0.5, b=8))]:
        _, timings = gateway.policy(name, **params).plan_timed(test, mid_budget)
        assert timings["total"] > 0
    # the Alg.-1 family refines the §6.5 breakdown
    _, timings = gateway.policy("robatch").plan_timed(test, mid_budget)
    assert {"router", "proxy", "greedy", "total"} <= set(timings)


# ---------------------------------------------------------------------------
# gateway from a spec (small instance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,params", [("robatch", {}),
                                           ("routellm", {"tau": 0.5, "b": 4})])
def test_gateway_from_spec_end_to_end(policy, params):
    spec = RunSpec(pool=PoolSpec(task="agnews", n_train=96, n_val=24, n_test=48,
                                 seed=3),
                   policy=PolicySpec(policy, params),
                   router="knn", coreset_size=16)
    gw = Gateway.from_spec(spec).fit()
    test = gw.wl.subset_indices("test")
    budget = float(gw.robatch.cost_model.single_model_cost(1, test, 1))
    out = gw.submit(budget=budget)       # defaults: test split + spec policy
    assert 0.0 <= out.accuracy <= 1.0 and out.exact_cost > 0


# ---------------------------------------------------------------------------
# online serving is policy-pluggable
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,params", [("routellm", dict(tau=0.5, b=8)),
                                         ("batcher-sim", dict(tau=0.5, b=8)),
                                         ("frugalgpt", dict(tau=0.5, b=8))])
def test_online_server_accepts_registered_policies(name, params, gateway,
                                                   agnews, pool):
    pol = gateway.policy(name, **params)
    test = agnews.subset_indices("test")
    base = float(pol.window_space(test).cost.min())
    cfg = OnlineConfig(budget_per_s=20.0 * base * 4.0, window_s=0.25)
    srv = OnlineRobatchServer(pol, pool, agnews, cfg)
    arrivals = poisson_arrivals(np.random.default_rng(7), 20.0, 5.0, test)
    stats = srv.run(arrivals)
    srv.close()
    assert stats.n_completed == stats.n_submitted
    assert stats.total_cost <= stats.budget_allowance * 1.05 + 1e-9
    for w in stats.windows:              # committed cost within the balance
        if w.n_admitted:
            assert w.est_cost <= w.avail + 1e-9
