"""On-device sampling + the unified GenerationConfig API: determinism of the
PRNG-in-carry sampled decode (position-folded keys ⇒ streams invariant to
decode_block, slot placement and paging), the temperature=0 bit-identity
deprecation shim, config round-trip/validation, and the spec/gateway
threading that carries one GenerationConfig from the declarative layer down
to the engine."""
import jax
import numpy as np
import pytest

from repro.config import ShardingConfig, get_arch
from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.generation import GenerationConfig

TOK = ByteTokenizer()
MAX_LEN = 160


@pytest.fixture(scope="module")
def tiny():
    cfg = get_arch("tiny-s")
    model = Model(cfg, ShardingConfig(remat="none"))
    return model, model.init(jax.random.PRNGKey(3))


def _requests(sampled=True):
    """Mixed batch: varying lengths/budgets, per-request seeds, and one
    greedy row inside an otherwise-sampled batch."""
    out = []
    for i in range(6):
        p = f"query number {i} " + "abc" * (5 * i)
        g = None
        if sampled:
            g = GenerationConfig(max_new=9 + 3 * i, temperature=0.9, top_k=40,
                                 top_p=0.95, seed=100 + i)
            if i == 2:                       # mixed batch: one greedy row
                g = GenerationConfig(max_new=9 + 3 * i)
        out.append(Request(rid=i, tokens=TOK.encode(p),
                           max_new=9 + 3 * i, gen=g))
    return out


@pytest.fixture(scope="module")
def stepwise_sampled(tiny):
    """The per-token reference driver is the sampling oracle: one decode
    step per token, keys folded by stream position."""
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN, eos_id=-1)
    reqs = _requests()
    eng.serve_stepwise(reqs)
    return [r.out_tokens for r in reqs]


@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("slots", [1, 4])
@pytest.mark.parametrize("paged", [False, True])
def test_sampled_fused_parity_with_stepwise(tiny, stepwise_sampled, k, slots,
                                            paged):
    """The determinism contract: token t is a pure function of (seed, t), so
    the fused K-step scan — any K, any slot count, either KV layout — emits
    the stepwise driver's exact stream."""
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=slots, max_len=MAX_LEN,
                        decode_block=k, eos_id=-1, paged=paged, page_size=16)
    reqs = _requests()
    eng.serve(reqs)
    assert [r.out_tokens for r in reqs] == stepwise_sampled


def test_replica_placement_invariance(tiny, stepwise_sampled):
    """A request's stream must not depend on which replica/slot serves it:
    the same six requests squeezed through a single slot (every admission
    lands on slot 0, positions shift across ticks) reproduce the
    concurrently-batched streams bit for bit."""
    model, params = tiny
    eng = ServingEngine(model, params, max_slots=1, max_len=MAX_LEN, eos_id=-1)
    reqs = _requests()
    eng.serve(reqs)
    assert [r.out_tokens for r in reqs] == stepwise_sampled


def test_temperature_zero_is_bitwise_greedy(tiny):
    """The deprecation shim's contract at the engine: requests carrying an
    explicit greedy GenerationConfig are bit-identical to legacy bare-kwarg
    requests (gen=None), fused and stepwise."""
    model, params = tiny
    legacy_eng = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN,
                               eos_id=-1, decode_block=4)
    legacy = _requests(sampled=False)
    legacy_eng.serve(legacy)
    shim_eng = ServingEngine(model, params, max_slots=4, max_len=MAX_LEN,
                             eos_id=-1, decode_block=4)
    shim = [Request(rid=r.rid, tokens=list(r.tokens), max_new=r.max_new,
                    gen=GenerationConfig(max_new=r.max_new)) for r in legacy]
    shim_eng.serve(shim)
    assert [r.out_tokens for r in legacy] == [r.out_tokens for r in shim]


def test_sampling_actually_samples(tiny):
    """Different seeds diverge and nonzero temperature departs from greedy —
    guards against a silently-greedy sampler passing every parity test."""
    model, params = tiny

    def run(seed, temp):
        eng = ServingEngine(model, params, max_slots=2, max_len=MAX_LEN,
                            eos_id=-1, decode_block=4)
        reqs = [Request(rid=i, tokens=TOK.encode(f"prompt {i} xyzw"),
                        max_new=24,
                        gen=GenerationConfig(max_new=24, temperature=temp,
                                             seed=seed + i))
                for i in range(2)]
        eng.serve(reqs)
        return [r.out_tokens for r in reqs]

    hot_a, hot_b = run(0, 1.5), run(50, 1.5)
    assert hot_a == run(0, 1.5)              # same seed reproduces exactly
    assert hot_a != hot_b                    # different seed diverges
    assert hot_a != run(0, 0.0)              # temperature moves the stream


# ---------------------------------------------------------------------------
# GenerationConfig: round-trip + validation
# ---------------------------------------------------------------------------

def test_generation_config_roundtrip():
    g = GenerationConfig(max_new=48, temperature=0.7, top_k=40, top_p=0.9,
                         seed=11, decode_block=4)
    assert GenerationConfig.from_dict(g.to_dict()) == g
    assert GenerationConfig.from_json(g.to_json()) == g
    assert g.with_(temperature=0.0).greedy and not g.greedy


def test_generation_config_rejects_unknown_and_invalid():
    with pytest.raises(ValueError, match="unknown field"):
        GenerationConfig.from_dict({"max_new": 8, "temprature": 1.0})
    for bad in (dict(max_new=0), dict(temperature=-0.1), dict(top_k=-1),
                dict(top_p=0.0), dict(top_p=1.5), dict(decode_block=-2)):
        with pytest.raises(ValueError):
            GenerationConfig(**bad)


# ---------------------------------------------------------------------------
# spec threading: PoolSpec sampling fields → Gateway → OnlineConfig
# ---------------------------------------------------------------------------

def test_poolspec_generation_fields_roundtrip():
    from repro.api import PoolSpec, RunSpec

    spec = RunSpec(pool=PoolSpec(kind="tiny", temperature=0.8, top_k=50,
                                 top_p=0.9, gen_seed=7, draft_member="tiny-s",
                                 spec_k=6))
    assert RunSpec.from_json(spec.to_json()) == spec
    gen = spec.pool.generation_config()
    assert gen == GenerationConfig(temperature=0.8, top_k=50, top_p=0.9,
                                   seed=7)
    # all-default sampling fields mean "no config" — the legacy greedy path
    assert PoolSpec().generation_config() is None
    assert PoolSpec().generation_config(temperature=0.5).temperature == 0.5


def test_poolspec_draft_member_needs_tiny_pool():
    from repro.api import PoolSpec

    with pytest.raises(ValueError, match="draft_member"):
        PoolSpec(kind="simulated", draft_member="tiny-s").build()


# ---------------------------------------------------------------------------
# deprecation-shim parity: an explicit greedy GenerationConfig threaded
# through the online plane changes nothing, for every registered policy
# ---------------------------------------------------------------------------

POLICY_PARAMS = {"routellm": dict(tau=0.5, b=8), "frugalgpt": dict(tau=0.5, b=8),
                 "batcher-sim": dict(tau=0.5, b=8),
                 "batcher-div": dict(tau=0.5, b=8),
                 "obp": dict(tau=0.5, b=8), "batch-only": dict(model=1)}


def _policy_names():
    from repro.api.policy import list_policies

    return list_policies()


@pytest.mark.parametrize("name", _policy_names())
def test_online_greedy_shim_parity_per_policy(name, fitted_rb, agnews, pool):
    """Serving one seeded stream with OnlineConfig(generation=greedy) must
    reproduce the legacy generation=None run bit for bit — across all nine
    registered policies, so no scheduling path reads the config where it
    shouldn't (cache keys, coalescing, billing)."""
    from repro.api import Gateway
    from repro.serving.online import (OnlineConfig, OnlineRobatchServer,
                                      poisson_arrivals)

    gw = Gateway(pool, agnews, artifacts=fitted_rb)
    pol = gw.policy(name, **POLICY_PARAMS.get(name, {}))
    test = agnews.subset_indices("test")
    base = float(pol.window_space(test).cost.min())
    arrivals = poisson_arrivals(np.random.default_rng(7), 20.0, 3.0, test)

    def run(generation):
        cfg = OnlineConfig(budget_per_s=20.0 * base * 4.0, window_s=0.25,
                           generation=generation)
        # exec_pool, not pool: batch-only narrows the plan's member view
        srv = OnlineRobatchServer(pol, pol.exec_pool, agnews, cfg)
        stats = srv.run(list(arrivals))
        srv.close()
        return stats

    legacy, shim = run(None), run(GenerationConfig())
    for f in ("n_submitted", "n_completed", "n_cache_hits", "n_coalesced",
              "n_dropped", "n_reroutes", "total_cost", "mean_utility"):
        assert getattr(shim, f) == getattr(legacy, f), f"{name}: {f} drifted"


def test_gateway_resolves_spec_generation_into_config():
    from repro.api import Gateway, PoolSpec, RunSpec
    from repro.serving.online import OnlineConfig

    gw = Gateway([], None, spec=RunSpec(pool=PoolSpec(temperature=0.6,
                                                      gen_seed=3)))
    cfg = gw._resolve_generation(OnlineConfig(budget_per_s=1.0))
    assert cfg.generation == GenerationConfig(temperature=0.6, seed=3)
    # an explicit config wins over the spec default
    explicit = OnlineConfig(budget_per_s=1.0,
                            generation=GenerationConfig(temperature=0.1))
    assert gw._resolve_generation(explicit) is explicit
    # a greedy spec leaves the config untouched (legacy path)
    gw2 = Gateway([], None, spec=RunSpec())
    base = OnlineConfig(budget_per_s=1.0)
    assert gw2._resolve_generation(base) is base
