"""Data pipeline (prefetch, placement) and launcher CLIs (subprocess smoke)."""
import os
import subprocess
import sys

import numpy as np

from repro.data.pipeline import ShardedPipeline, synthetic_lm_stream


def test_synthetic_stream_shapes_and_structure():
    it = synthetic_lm_stream(vocab=128, batch=4, seq=16, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    # next-token relationship holds
    b2 = next(it)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # learnable structure: token marginal is far from uniform
    flat = np.concatenate([b["tokens"].ravel(), b2["tokens"].ravel()])
    assert len(np.unique(flat)) < 100


def test_pipeline_prefetch_and_close():
    it = (dict(x=np.full((2, 2), i)) for i in range(5))
    pipe = ShardedPipeline(it, prefetch=2)
    got = [int(b["x"][0, 0]) for b in pipe]
    assert got == list(range(5))
    pipe.close()


def _run_cli(mod, *args, timeout=300):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-m", mod, *args],
                         capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-1500:]
    return out.stdout


def test_train_cli_local():
    out = _run_cli("repro.launch.train", "--arch", "tiny-s", "--steps", "12",
                   "--batch", "4", "--seq", "32")
    assert "params" in out and "loss" in out


def test_serve_cli():
    out = _run_cli("repro.launch.serve", "--arch", "tiny-s", "--requests", "3",
                   "--max-new", "4")
    assert "served 3/3" in out
