"""Dry-run machinery on a small (2×4) mesh in a subprocess (its own
XLA_FLAGS device count — never pollutes the test process)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.config import MeshConfig, SHAPE_SUITE, ShapeConfig, get_arch
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh_from_config

mesh_cfg = MeshConfig(shape=(2, 4), axes=("data", "model"))
mesh = make_mesh_from_config(mesh_cfg)
cfg = get_arch(sys.argv[1]).reduced()
shape = ShapeConfig(sys.argv[2], sys.argv[3], int(sys.argv[4]), int(sys.argv[5]))
res = lower_cell(cfg, shape, mesh, mesh_cfg, verbose=False)
print("RESULT:" + json.dumps({k: res[k] for k in ("status", "useful_ratio")}))
"""


def _run(arch, name, kind, seq, batch):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # pin the subprocess to the host CPU backend: with a bundled libtpu,
    # default backend discovery probes for TPU hardware and can block
    # indefinitely in containers; XLA_FLAGS fake-device counts work the same
    # either way (verified: 8 cpu devices under JAX_PLATFORMS=cpu)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _SCRIPT, arch, name, kind,
                          str(seq), str(batch)],
                         capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.parametrize("arch,kind", [
    ("qwen1.5-0.5b", "train"),
    ("qwen3-moe-30b-a3b", "train"),
    ("rwkv6-3b", "decode"),
    ("recurrentgemma-9b", "prefill"),
])
def test_lower_cell_small_mesh(arch, kind):
    res = _run(arch, f"small_{kind}", kind, 64, 8)
    assert res["status"] == "ok"
    assert res["useful_ratio"] is None or res["useful_ratio"] > 0
