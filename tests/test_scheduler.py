"""Scheduler properties: Eq. 6/7 constraints, dominance-pruning losslessness
(Thm. 5.3), greedy vs brute-force, budget monotonicity (hypothesis-driven)."""
import numpy as np
import pytest
from hypcompat import given, settings, st   # property tests skip w/o hypothesis

from repro.core.pareto import CandidateSpace, pareto_frontier
from repro.core.problem import State
from repro.core.scheduler import brute_force_schedule, greedy_schedule


# ---------------------------------------------------------------------------
# synthetic candidate spaces (no pool needed: scheduler is pure)
# ---------------------------------------------------------------------------

def random_space(rng: np.random.Generator, n: int, n_models: int, n_batches: int) -> CandidateSpace:
    """States (k, b) with cost increasing in k and decreasing in b; utilities
    arbitrary in [0,1] — the scheduler must cope with any proxy model."""
    batches = [1, 2, 4][:n_batches]
    states, cost_cols, util_cols = [], [], []
    base = rng.uniform(0.5, 2.0, size=(n, n_models)).cumsum(axis=1)  # asc in k
    sys_c = rng.uniform(0.5, 3.0, size=n_models).cumsum()            # asc in k
    for k in range(n_models):
        for b in batches:
            states.append(State(k, b))
            cost_cols.append(base[:, k] + sys_c[k] / b)
            util_cols.append(rng.uniform(0, 1, size=n))
    init = states.index(State(0, batches[-1]))
    return CandidateSpace(states=states, cost=np.stack(cost_cols, 1),
                          util=np.stack(util_cols, 1), initial_state=init)


space_params = st.tuples(
    st.integers(1, 6),       # queries
    st.integers(1, 3),       # models
    st.integers(1, 3),       # batch sizes
    st.integers(0, 10_000),  # seed
    st.floats(0.0, 3.0),     # budget slack multiplier
)


def _budget_for(space, slack):
    init = space.cost[:, space.initial_state].sum()
    max_c = space.cost.max(axis=1).sum()
    return init + slack * (max_c - init)


@settings(max_examples=120, deadline=None)
@given(space_params)
def test_each_query_exactly_one_state(params):
    n, k, nb, seed, slack = params
    space = random_space(np.random.default_rng(seed), n, k, nb)
    res = greedy_schedule(space, np.arange(n), _budget_for(space, slack))
    assert len(res.assignment.model) == n          # Eq. 6
    for s in res.assignment.states():
        assert s in space.states


@settings(max_examples=120, deadline=None)
@given(space_params)
def test_budget_respected(params):
    n, k, nb, seed, slack = params
    space = random_space(np.random.default_rng(seed), n, k, nb)
    budget = _budget_for(space, slack)
    res = greedy_schedule(space, np.arange(n), budget)
    if not res.infeasible:
        assert res.amortized_cost <= budget + 1e-9  # Eq. 7 (amortized accounting)


@settings(max_examples=120, deadline=None)
@given(space_params)
def test_utility_at_least_initial(params):
    n, k, nb, seed, slack = params
    space = random_space(np.random.default_rng(seed), n, k, nb)
    res = greedy_schedule(space, np.arange(n), _budget_for(space, slack))
    init_u = space.util[:, space.initial_state].sum()
    assert res.est_utility >= init_u - 1e-9


@settings(max_examples=60, deadline=None)
@given(st.tuples(st.integers(1, 5), st.integers(1, 3), st.integers(0, 5_000)))
def test_budget_monotonicity_endpoints(params):
    """Hypothesis finding: Alg. 1 is NOT pointwise budget-monotone — a larger
    budget can afford an early high-Δ expensive upgrade that crowds out
    several cheaper ones.  What IS guaranteed: an all-affordable budget yields
    the frontier maximum (≥ any intermediate outcome), and the minimum budget
    yields the initial assignment (≤ any other)."""
    n, k, seed = params
    space = random_space(np.random.default_rng(seed), n, k, 2)
    budgets = np.linspace(_budget_for(space, 0), _budget_for(space, 1.2), 6)
    utils = [greedy_schedule(space, np.arange(n), b).est_utility for b in budgets]
    assert utils[-1] >= max(utils) - 1e-9     # saturated budget = frontier max
    assert utils[0] <= min(utils) + 1e-9      # starved budget = initial only


@settings(max_examples=60, deadline=None)
@given(st.tuples(st.integers(1, 5), st.integers(1, 2), st.integers(0, 5_000),
                 st.floats(0.1, 1.5)))
def test_greedy_never_exceeds_optimum(params):
    """Sanity: greedy ≤ brute-force optimum, ≥ the initial assignment."""
    n, k, seed, slack = params
    space = random_space(np.random.default_rng(seed), n, k, 2)
    budget = _budget_for(space, slack)
    g = greedy_schedule(space, np.arange(n), budget)
    bf = brute_force_schedule(space, np.arange(n), budget)
    assert g.est_utility <= bf.est_utility + 1e-9
    assert g.est_utility >= space.util[:, space.initial_state].sum() - 1e-9


def test_greedy_quality_statistical():
    """Δ-ratio greedy has NO adversarial constant-factor guarantee (hypothesis
    finds <0.5× instances: a high-Δ unaffordable transition is dropped, Alg. 1
    line 11-12).  The paper's quality claim is empirical — check it
    statistically: mean ≥ 90% of optimal over random micro instances."""
    rng = np.random.default_rng(0)
    ratios = []
    for seed in range(60):
        space = random_space(np.random.default_rng(seed), 5, 2, 2)
        budget = _budget_for(space, float(rng.uniform(0.2, 1.2)))
        g = greedy_schedule(space, np.arange(5), budget)
        bf = brute_force_schedule(space, np.arange(5), budget)
        if bf.est_utility > 0:
            ratios.append(g.est_utility / bf.est_utility)
    assert np.mean(ratios) >= 0.90, np.mean(ratios)
    assert np.min(ratios) >= 0.40, np.min(ratios)


def test_pareto_pruning_lossless():
    """Thm. 5.3: scheduling over pruned frontiers equals scheduling over the
    frontier plus dominated states (we add dominated states and check the
    greedy objective is unchanged)."""
    rng = np.random.default_rng(7)
    n = 6
    space = random_space(rng, n, 3, 3)
    budget = _budget_for(space, 0.7)
    base = greedy_schedule(space, np.arange(n), budget)

    # append strictly dominated copies of every state (more cost, less utility)
    states2 = space.states + [State(s.model, s.batch) for s in space.states]
    cost2 = np.concatenate([space.cost, space.cost + 1.0], axis=1)
    util2 = np.concatenate([space.util, np.clip(space.util - 0.1, 0, 1)], axis=1)
    space2 = CandidateSpace(states=states2, cost=cost2, util=util2,
                            initial_state=space.initial_state)
    withdom = greedy_schedule(space2, np.arange(n), budget)
    assert withdom.est_utility == pytest.approx(base.est_utility)


def test_pareto_frontier_sorted_and_nondominated():
    rng = np.random.default_rng(3)
    cost = rng.uniform(0, 1, 50)
    util = rng.uniform(0, 1, 50)
    fr = pareto_frontier(cost, util)
    assert np.all(np.diff(cost[fr]) >= 0)
    assert np.all(np.diff(util[fr]) > 0)
    # no dominating pair outside the frontier
    for j in range(50):
        dominated = ((cost[fr] <= cost[j]) & (util[fr] >= util[j])).any()
        assert dominated or j in fr


def test_unaffordable_upgrade_dropped_not_fatal():
    """Alg. 1 line 11–12: a too-expensive top-Δ upgrade is skipped and the
    scheduler keeps upgrading other queries."""
    states = [State(0, 2), State(0, 1), State(1, 1)]
    cost = np.array([[1.0, 2.0, 100.0],     # q0: huge second upgrade
                     [1.0, 1.5, 2.0]])
    util = np.array([[0.1, 0.2, 1.0],
                     [0.1, 0.3, 0.9]])
    space = CandidateSpace(states=states, cost=cost, util=util, initial_state=0)
    res = greedy_schedule(space, np.arange(2), budget=2.0 + 4.0)
    # q0 can afford (0,1)->cost2; q1 can reach (1,1)->cost2
    assert res.est_utility >= 0.2 + 0.9 - 1e-9


# ---------------------------------------------------------------------------
# vectorized scheduler (beyond-paper): parity + constraints
# ---------------------------------------------------------------------------

from repro.core.scheduler import greedy_schedule_vectorized


@settings(max_examples=60, deadline=None)
@given(space_params)
def test_vectorized_matches_heap_objective(params):
    n, k, nb, seed, slack = params
    space = random_space(np.random.default_rng(seed), n, k, nb)
    budget = _budget_for(space, slack)
    heap = greedy_schedule(space, np.arange(n), budget)
    vec = greedy_schedule_vectorized(space, np.arange(n), budget)
    if not vec.infeasible:
        assert vec.amortized_cost <= budget + 1e-9
    # round-commit ordering can differ from the global heap on adversarial
    # micro instances; require ≥85% of the heap objective and never below the
    # initial assignment (empirical parity on real workloads is measured in
    # benchmarks/fig11 and is ≈1.0)
    init_u = space.util[:, space.initial_state].sum()
    assert vec.est_utility >= max(0.85 * heap.est_utility, init_u) - 1e-9


def test_vectorized_each_query_one_state():
    rng = np.random.default_rng(11)
    space = random_space(rng, 20, 3, 3)
    res = greedy_schedule_vectorized(space, np.arange(20), _budget_for(space, 0.8))
    assert len(res.assignment.model) == 20
    for s in res.assignment.states():
        assert s in space.states


# ---------------------------------------------------------------------------
# uncertainty-robust walk: λ·σ-penalized gains, worst-case budget margin
# ---------------------------------------------------------------------------

from repro.core.scheduler import (  # noqa: E402
    greedy_schedule_window,
    restrict_space,
    take_rows,
)


def random_space_with_sigma(rng, n, n_models, n_batches):
    space = random_space(rng, n, n_models, n_batches)
    return CandidateSpace(states=space.states, cost=space.cost,
                          util=space.util, initial_state=space.initial_state,
                          sigma=rng.uniform(0.0, 0.4, size=space.util.shape))


@settings(max_examples=80, deadline=None)
@given(space_params)
def test_robust_at_zero_is_bit_identical(params):
    # the λ=0 / margin=0 path must return EXACTLY the point-estimate walk —
    # same assignment, same floats — even when sigma is present
    n, k, nb, seed, slack = params
    rng = np.random.default_rng(seed)
    space = random_space_with_sigma(rng, n, k, nb)
    budget = _budget_for(space, slack)
    base = greedy_schedule(space, np.arange(n), budget)
    zero = greedy_schedule(space, np.arange(n), budget,
                           robust_lambda=0.0, cost_margin=0.0)
    assert np.array_equal(zero.assignment.model, base.assignment.model)
    assert np.array_equal(zero.assignment.batch, base.assignment.batch)
    assert zero.est_utility == base.est_utility
    assert zero.amortized_cost == base.amortized_cost
    assert zero.spent_budget == base.spent_budget
    caps = {m: n for m in range(k)}
    wbase = greedy_schedule_window(space, np.arange(n), budget, group_caps=caps)
    wzero = greedy_schedule_window(space, np.arange(n), budget, group_caps=caps,
                                   robust_lambda=0.0, cost_margin=0.0)
    assert np.array_equal(wzero.assignment.model, wbase.assignment.model)
    assert wzero.est_utility == wbase.est_utility
    assert wzero.spent_budget == wbase.spent_budget


def _three_state_space(sigma):
    # one query; an expensive high-û/high-σ upgrade vs an equally priced
    # lower-û/zero-σ one — Pareto pruning keeps only the better walk-utility
    states = [State(0, 1), State(1, 1), State(2, 1)]
    return CandidateSpace(states=states,
                          cost=np.array([[1.0, 2.0, 2.0]]),
                          util=np.array([[0.5, 0.9, 0.85]]),
                          initial_state=0,
                          sigma=np.array([sigma]))


def test_robust_lambda_switches_to_low_sigma_upgrade():
    space = _three_state_space([0.0, 0.3, 0.0])
    idx = np.arange(1)
    base = greedy_schedule(space, idx, budget=2.5)
    assert int(base.assignment.model[0]) == 1          # û says model 1
    rob = greedy_schedule(space, idx, budget=2.5, robust_lambda=1.0)
    assert int(rob.assignment.model[0]) == 2           # û−λσ says model 2
    # accounting stays in raw point-estimate currency
    assert rob.est_utility == pytest.approx(0.85)
    assert rob.amortized_cost == pytest.approx(2.0)


def test_cost_margin_blocks_worst_case_budget_overrun():
    space = _three_state_space([0.0, 0.0, 0.0])
    idx = np.arange(1)
    base = greedy_schedule(space, idx, budget=2.8)
    assert int(base.assignment.model[0]) == 1          # affordable point-est.
    marg = greedy_schedule(space, idx, budget=2.8, cost_margin=0.5)
    assert int(marg.assignment.model[0]) == 0          # 2.0·1.5 > 2.8: held
    # the walk drew the worst-case price of what it DID commit
    assert marg.spent_budget == pytest.approx(1.0 * 1.5)
    assert marg.amortized_cost == pytest.approx(1.0)


def test_robust_schedule_fits_worst_case_inside_budget():
    rng = np.random.default_rng(7)
    space = random_space_with_sigma(rng, 24, 3, 3)
    budget = _budget_for(space, 0.6)
    for margin in (0.1, 0.25, 0.5):
        res = greedy_schedule(space, np.arange(24), budget, cost_margin=margin)
        if not res.infeasible:
            assert res.amortized_cost * (1 + margin) <= budget + 1e-9
            assert res.spent_budget == pytest.approx(
                res.amortized_cost * (1 + margin))


def test_sigma_survives_restrict_and_take_rows():
    rng = np.random.default_rng(3)
    space = random_space_with_sigma(rng, 8, 3, 2)
    sub = restrict_space(space, {0, 2})
    assert sub.sigma is not None and sub.sigma.shape == sub.util.shape
    assert all(s.model != 1 for s in sub.states)
    rows = take_rows(sub, np.array([1, 3, 5]))
    assert rows.sigma is not None and rows.sigma.shape == rows.util.shape
    np.testing.assert_array_equal(rows.sigma, sub.sigma[[1, 3, 5]])


def test_fitted_candidate_space_carries_calibration_sigma(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:16]
    space = fitted_rb.candidate_space(test)
    assert space.sigma is not None
    assert space.sigma.shape == space.util.shape
    assert np.all(space.sigma >= 0)
    assert float(space.sigma.max()) > 0          # residual spread is real
    # sigma is constant per (model, batch) column: it comes from the
    # calibration's per-batch residual std, not per-query noise
    assert np.allclose(space.sigma, space.sigma[:1, :])


def test_robust_policy_params_flow_and_validate(fitted_rb, agnews, pool):
    from repro.api.policies import RobatchPolicy

    with pytest.raises(ValueError, match="robust"):
        RobatchPolicy(robust=-0.1)
    with pytest.raises(ValueError, match="cost_margin"):
        RobatchPolicy(cost_margin=-1.0)
    test = agnews.subset_indices("test")[:32]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost[:, space.initial_state].sum()) * 2.0
    plain = RobatchPolicy().fit(pool, agnews, artifacts=fitted_rb)
    robust = RobatchPolicy(robust=0.0, cost_margin=0.0).fit(
        pool, agnews, artifacts=fitted_rb)
    a = plain.plan_window(space, test, budget)
    b = robust.plan_window(space, test, budget)
    assert np.array_equal(a.schedule.assignment.model,
                          b.schedule.assignment.model)
    assert a.est_utility == b.est_utility
    # a margin policy never schedules past its worst-case budget
    guarded = RobatchPolicy(cost_margin=0.25).fit(pool, agnews,
                                                  artifacts=fitted_rb)
    c = guarded.plan_window(space, test, budget)
    assert c.est_cost * 1.25 <= budget + 1e-9
