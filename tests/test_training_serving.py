"""Training loop (grad accumulation, resume), checkpointing, serving engine
(continuous batching, prefill/decode consistency), fault handling."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.config import ShardingConfig, get_arch
from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.fault import FaultTolerantInvoker, StragglerPolicy
from repro.training.optimizer import adamw, clip_by_global_norm, cosine_schedule
from repro.training.train_loop import Trainer, make_train_step


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_arch("tiny-s")
    return Model(cfg, ShardingConfig(remat="none"))


def _batches(cfg, n, B=4, S=16, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        t = rng.integers(0, cfg.vocab_size, (B, S + 1))
        yield {"tokens": jnp.asarray(t[:, :-1], jnp.int32),
               "labels": jnp.asarray(t[:, 1:], jnp.int32)}


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_and_schedule():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(5)) < 1e-3 and float(lr(10)) == pytest.approx(1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)


# ---------------------------------------------------------------------------
# train step & accumulation
# ---------------------------------------------------------------------------

def test_grad_accumulation_matches_full_batch(tiny_model):
    opt = adamw(1e-3)
    params = tiny_model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    batch = next(_batches(tiny_model.cfg, 1, B=8))
    step_full = make_train_step(tiny_model, opt, ShardingConfig(microbatches=1, remat="none"))
    step_acc = make_train_step(tiny_model, opt, ShardingConfig(microbatches=4, remat="none"))
    p1, _, m1 = step_full(params, state, batch)
    p2, _, m2 = step_acc(params, state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-5   # accumulation in fp32 ≈ full batch


def test_trainer_loss_decreases_and_resumes(tiny_model, tmp_path):
    opt = adamw(3e-3)
    tr = Trainer(tiny_model, opt, ShardingConfig(remat="none"),
                 ckpt_dir=str(tmp_path / "ck"), ckpt_every=5)
    params, state, start = tr.restore_or_init(jax.random.PRNGKey(0))
    assert start == 0
    params, state, hist = tr.fit(params, state, _batches(tiny_model.cfg, 30, seed=1),
                                 log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"]
    # simulate crash: new trainer picks up the checkpoint
    tr2 = Trainer(tiny_model, opt, ShardingConfig(remat="none"),
                  ckpt_dir=str(tmp_path / "ck"))
    p2, s2, start2 = tr2.restore_or_init(jax.random.PRNGKey(0))
    assert start2 == 30
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# checkpoint machinery
# ---------------------------------------------------------------------------

def test_checkpoint_atomic_and_keep_n(tmp_path):
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(tree, s)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("4".zfill(12))
    restored, step = mgr.restore(tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_ignores_partial_writes(tmp_path):
    tree = {"a": jnp.arange(3)}
    save_pytree(tree, str(tmp_path), 7)
    os.makedirs(tmp_path / "tmp.9.123", exist_ok=True)   # simulated torn write
    restored, step = load_pytree(tree, str(tmp_path))
    assert step == 7


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_continuous_batching_matches_sequential(tiny_model):
    params = tiny_model.init(jax.random.PRNGKey(3))
    tok = ByteTokenizer()
    prompts = [f"query number {i}" for i in range(7)]   # 7 requests, 3 slots
    eng = ServingEngine(tiny_model, params, max_slots=3, max_len=128)
    out_batched = eng.generate_text(prompts, max_new=8)
    # sequential reference: one request at a time, fresh engine
    outs_seq = []
    for p in prompts:
        e = ServingEngine(tiny_model, params, max_slots=1, max_len=128)
        outs_seq.append(e.generate_text([p], max_new=8)[0])
    assert out_batched == outs_seq


def test_engine_respects_max_new(tiny_model):
    params = tiny_model.init(jax.random.PRNGKey(3))
    eng = ServingEngine(tiny_model, params, max_slots=2, max_len=64)
    reqs = [Request(rid=0, tokens=ByteTokenizer().encode("hi"), max_new=5)]
    eng.serve(reqs)
    assert reqs[0].done and len(reqs[0].out_tokens) <= 5


# ---------------------------------------------------------------------------
# fault handling
# ---------------------------------------------------------------------------

def test_straggler_redispatch():
    calls = []

    def slow_fn():
        calls.append("slow")
        return {"latency": 100.0}

    inv = FaultTolerantInvoker(2, StragglerPolicy(min_deadline_s=1.0, deadline_factor=1.0),
                               backup_of=lambda k: 1 if k == 0 else None)
    inv.health[0].latencies.extend([0.1] * 10)   # p50 = 0.1 → deadline 1.0
    res = inv.invoke(0, slow_fn, latency_of=lambda r: r["latency"])
    assert inv.n_redispatched == 1
    assert res["latency"] == 100.0               # backup also ran the fn


def test_failure_ejection_and_backup():
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] <= 3:
            raise RuntimeError("replica down")
        return "ok"

    inv = FaultTolerantInvoker(2, StragglerPolicy(eject_after=3, max_retries=3),
                               backup_of=lambda k: 1 if k == 0 else None)
    out = inv.invoke(0, flaky)
    assert out == "ok"
    assert not inv.healthy(0)                    # member 0 ejected
    assert inv.inflight() == []                  # journal fully settled
