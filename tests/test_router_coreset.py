"""Routers (MLP/KNN) and coreset selection algorithms."""
import numpy as np
import pytest

from repro.core.coreset import facility_location, herding, kcenter_greedy, select_coreset
from repro.core.robatch import collect_router_labels
from repro.core.router import KNNRouter, train_mlp_router


def _labels(pool, wl, idx):
    return collect_router_labels(pool, wl, idx)


def test_mlp_router_learns_signal(agnews, pool):
    tr = agnews.subset_indices("train")
    te = agnews.subset_indices("test")
    y_tr = _labels(pool, agnews, tr)
    router = train_mlp_router(agnews.embeddings[tr], y_tr, epochs=60, seed=0)
    pred = router.predict(agnews.embeddings[te])
    y_te = _labels(pool, agnews, te)
    acc = ((pred > 0.5) == (y_te > 0.5)).mean()
    base = max(y_te.mean(), 1 - y_te.mean())  # majority-class baseline
    assert pred.shape == (len(te), len(pool))
    assert np.all((pred >= 0) & (pred <= 1))
    assert acc > base - 0.02  # at least matches majority; signal check below
    # labels are Bernoulli draws: even the Bayes-optimal predictor's
    # correlation is bounded (~0.2 here), so compare against that reference
    # rather than an absolute bar (XLA-CPU thread scheduling makes training
    # non-bitwise-reproducible; absolute thresholds near the ceiling flake)
    p_true = np.stack([m.base_prob(agnews, te) for m in pool], axis=1)
    bayes = np.corrcoef(p_true.ravel(), y_te.ravel())[0, 1]
    corr = np.corrcoef(pred.ravel(), y_te.ravel())[0, 1]
    assert corr > 0.15 * bayes, (corr, bayes)   # >2σ above the null for n=768


def test_knn_router_predicts_probabilities(agnews, pool):
    tr = agnews.subset_indices("train")
    y_tr = _labels(pool, agnews, tr)
    router = KNNRouter(agnews.embeddings[tr].astype(np.float32), y_tr, k=8)
    pred = router.predict(agnews.embeddings[agnews.subset_indices("test")[:50]])
    assert pred.shape == (50, len(pool))
    assert np.all((pred >= 0) & (pred <= 1))
    # k=8 neighbours -> predictions quantized to eighths
    assert np.allclose((pred * 8) % 1, 0, atol=1e-6)


@pytest.mark.parametrize("method", ["kcenter", "fl", "herding"])
def test_coreset_valid_selection(method, agnews):
    emb = agnews.embeddings[agnews.subset_indices("train")]
    sel = select_coreset(emb, 32, method=method)
    assert len(sel) == 32
    assert len(np.unique(sel)) == 32
    assert sel.min() >= 0 and sel.max() < len(emb)


def test_kcenter_covers_space_better_than_random():
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(500, 8))
    sel = kcenter_greedy(emb, 25, seed=0)
    rnd = rng.choice(500, 25, replace=False)

    def cover_radius(chosen):
        d = ((emb[:, None, :] - emb[chosen][None, :, :]) ** 2).sum(-1)
        return np.sqrt(d.min(1)).max()

    assert cover_radius(sel) <= cover_radius(rnd)


def test_facility_location_covers_both_directions():
    """FL (cosine similarity) picks one representative per angular cluster."""
    rng = np.random.default_rng(1)
    c1 = np.array([1.0, 0, 0, 0]) + rng.normal(0, 0.05, size=(90, 4))
    c2 = np.array([0, 1.0, 0, 0]) + rng.normal(0, 0.05, size=(10, 4))
    emb = np.concatenate([c1, c2])
    sel = facility_location(emb, 2, seed=0)
    regions = {int(s >= 90) for s in sel}
    assert regions == {0, 1}


def test_herding_matches_mean():
    rng = np.random.default_rng(2)
    emb = rng.normal(size=(300, 6))
    sel = herding(emb, 64)
    # herding subset mean approximates the full mean
    err_h = np.linalg.norm(emb[sel].mean(0) - emb.mean(0))
    err_r = np.linalg.norm(emb[rng.choice(300, 64, replace=False)].mean(0) - emb.mean(0))
    assert err_h <= err_r + 0.05
