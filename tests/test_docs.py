"""Documentation stays truthful: every file path and module reference in
README.md and docs/*.md must resolve to something in the repo."""
import glob
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = [os.path.join(ROOT, "README.md")] + \
    sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))

# backticked repo-relative paths like `src/repro/serving/online.py`
PATH_RE = re.compile(r"`((?:src|tests|examples|benchmarks|tools|docs|configs)"
                     r"/[\w\-/\.]+\.(?:py|sh|md|ini))`")
# backticked dotted module refs like `repro.serving.online`
MOD_RE = re.compile(r"`(repro(?:\.\w+)+)`")


def _doc_ids():
    return [os.path.relpath(p, ROOT) for p in DOC_FILES]


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids())
def test_referenced_paths_resolve(doc):
    assert os.path.exists(doc), f"{doc} missing"
    text = open(doc).read()
    missing = [p for p in PATH_RE.findall(text)
               if not os.path.exists(os.path.join(ROOT, p))]
    assert not missing, f"{os.path.basename(doc)} references missing paths: {missing}"


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids())
def test_referenced_modules_resolve(doc):
    text = open(doc).read()
    missing = []
    for mod in MOD_RE.findall(text):
        rel = mod.replace(".", "/")
        if not (os.path.exists(os.path.join(ROOT, "src", rel + ".py"))
                or os.path.isdir(os.path.join(ROOT, "src", rel))):
            missing.append(mod)
    assert not missing, f"{os.path.basename(doc)} references missing modules: {missing}"


def test_readme_and_architecture_exist():
    assert os.path.exists(os.path.join(ROOT, "README.md"))
    assert os.path.exists(os.path.join(ROOT, "docs", "architecture.md"))
    assert os.path.exists(os.path.join(ROOT, "docs", "batch_format.md"))


def test_ci_workflow_is_valid():
    yaml = pytest.importorskip("yaml")
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        wf = yaml.safe_load(f)
    # env pinning mirrors tools/smoke.sh: CPU backend, src-relative imports
    assert wf["env"]["JAX_PLATFORMS"] == "cpu"
    assert wf["env"]["PYTHONPATH"] == "src"
    assert set(wf["jobs"]) == {"lint", "tier1", "smoke", "bench"}
    for name, job in wf["jobs"].items():
        assert "runs-on" in job and job["steps"], name
    # superseded runs cancel instead of queueing
    assert wf["concurrency"]["cancel-in-progress"] is True
    # the bench regression gate BLOCKS (tolerances absorb runner noise;
    # bench_check annotates regression vs mismatch vs missing baseline)
    assert "continue-on-error" not in wf["jobs"]["bench"]
    # ...and gates the engine decode + HTTP front-end benchmarks alongside
    # the online run
    bench_runs = [s.get("run") or "" for s in wf["jobs"]["bench"]["steps"]]
    assert any("engine_decode.py" in r for r in bench_runs)
    assert any("http_serving.py" in r for r in bench_runs)
    assert any("robustness.py" in r for r in bench_runs)
    assert any("bench_check.py" in r for r in bench_runs)
    # tier1 runs on a python matrix with a non-blocking coverage report
    matrix = wf["jobs"]["tier1"]["strategy"]["matrix"]["python-version"]
    assert {"3.10", "3.12"} <= set(matrix)
    steps = wf["jobs"]["tier1"]["steps"]
    assert any("--cov=repro" in (s.get("run") or "") for s in steps)
    cov = [s for s in steps if "coverage report" in (s.get("run") or "")]
    assert cov and cov[0]["continue-on-error"] is True
    assert os.path.exists(os.path.join(ROOT, "requirements-ci.txt"))
    reqs = open(os.path.join(ROOT, "requirements-ci.txt")).read()
    assert "pytest-cov" in reqs and "coverage" in reqs
    assert os.path.exists(os.path.join(ROOT, "ruff.toml"))
    assert os.path.exists(os.path.join(ROOT, "benchmarks", "baselines",
                                       "BENCH_online.json"))


def test_http_surface_contract():
    """The HTTP front-end's workflow contract: the launcher exposes the
    documented mode and flags, the smoke script drives the wire end-to-end,
    and README + architecture document the endpoints."""
    serve_src = open(os.path.join(ROOT, "src", "repro", "launch",
                                  "serve.py")).read()
    for flag in ("--host", "--port", "--policy", "--replicas", "--autoscale",
                 "--max-seconds"):
        assert flag in serve_src, f"serve.py lost the {flag} flag"
    assert '"http"' in serve_src and "serve_http" in serve_src
    for marker in ("listening on http://", "shutdown clean"):
        assert marker in serve_src, f"serve.py lost the {marker!r} marker"

    smoke = open(os.path.join(ROOT, "tools", "smoke.sh")).read()
    assert "serve http --port 0" in smoke or "serve http --port 0" in \
        smoke.replace("\\\n    ", " "), "smoke.sh lost the http leg"
    for needle in ('"stream":true', "/metrics", "SIGTERM",
                   "shutdown clean"):
        assert needle in smoke, f"smoke.sh http leg lost {needle!r}"

    endpoints = ("/v1/chat/completions", "/v1/models", "/healthz", "/metrics")
    readme = open(os.path.join(ROOT, "README.md")).read()
    arch = open(os.path.join(ROOT, "docs", "architecture.md")).read()
    for ep in endpoints:
        assert ep in readme, f"README.md does not document {ep}"
        assert ep in arch, f"architecture.md does not document {ep}"
    # the streaming story: the decode_block-cadence hook and the ingress
    # bridge are load-bearing design points, not implementation trivia
    assert "decode_block" in arch and "submit_request" in arch
    assert "curl" in readme and "stream" in readme


def test_caching_doc_contract():
    """The caching guide's workflow contract: docs/caching.md exists, its
    CLI flags exist on the serve launcher, the smoke script drives a
    semantic-cache leg, and README + architecture cross-link the guide."""
    caching_path = os.path.join(ROOT, "docs", "caching.md")
    assert os.path.exists(caching_path), "docs/caching.md missing"
    caching = open(caching_path).read()
    serve_src = open(os.path.join(ROOT, "src", "repro", "launch",
                                  "serve.py")).read()
    for flag in ("--semantic-cache", "--sim-threshold"):
        assert flag in caching, f"caching.md does not document {flag}"
        assert flag in serve_src, f"serve.py lost the {flag} flag"
    # the guide covers both cache layers and the calibration/eviction story
    for needle in ("serve online", "ResponseCache", "SemanticCache",
                   "ε(sim)", "TTL", "LRU"):
        assert needle in caching, f"caching.md lost the {needle!r} story"

    smoke = open(os.path.join(ROOT, "tools", "smoke.sh")).read()
    assert "--semantic-cache" in smoke, "smoke.sh lost the semantic-cache leg"
    assert "semcache: hits=" in smoke, "smoke.sh no longer asserts the summary"

    readme = open(os.path.join(ROOT, "README.md")).read()
    arch = open(os.path.join(ROOT, "docs", "architecture.md")).read()
    assert "docs/caching.md" in readme, "README does not link docs/caching.md"
    assert "caching.md" in arch, "architecture.md does not link caching.md"


def test_robustness_doc_contract():
    """The robustness guide's workflow contract: docs/robustness.md exists,
    its CLI knobs exist on the serve launcher, the smoke script drives the
    chaos leg, the bench gate carries the robustness section, and README +
    architecture cross-link the guide."""
    doc_path = os.path.join(ROOT, "docs", "robustness.md")
    assert os.path.exists(doc_path), "docs/robustness.md missing"
    doc = open(doc_path).read()
    serve_src = open(os.path.join(ROOT, "src", "repro", "launch",
                                  "serve.py")).read()
    for flag in ("--chaos", "--robust-lambda", "--cost-margin"):
        assert flag in doc, f"robustness.md does not document {flag}"
        assert flag in serve_src, f"serve.py lost the {flag} flag"
    # the guide covers all three axes: faults, uncertainty, bottlenecks
    for needle in ("ChaosMember", "DispatchTimeout", "dispatch_timeout_s",
                   "robust_lambda", "cost_margin", "pressure_by_member",
                   "events_by_member", "scale_events",
                   "robatch_scale_events_total"):
        assert needle in doc, f"robustness.md lost the {needle!r} story"

    smoke = open(os.path.join(ROOT, "tools", "smoke.sh")).read()
    assert "--chaos" in smoke, "smoke.sh lost the chaos leg"
    assert "breakers_closed=True" in smoke, \
        "smoke.sh no longer asserts the chaos marker"

    baseline = open(os.path.join(ROOT, "benchmarks", "baselines",
                                 "BENCH_online.json")).read()
    assert '"robustness"' in baseline, \
        "bench baseline lost the robustness section"

    readme = open(os.path.join(ROOT, "README.md")).read()
    arch = open(os.path.join(ROOT, "docs", "architecture.md")).read()
    assert "docs/robustness.md" in readme, \
        "README does not link docs/robustness.md"
    assert "robustness.md" in arch, \
        "architecture.md does not link robustness.md"


FENCE_RE = re.compile(r"```(?:python|py)\n(.*?)```", re.S)
FROM_RE = re.compile(r"^\s*from\s+(repro[\w\.]*)\s+import\s+"
                     r"\(?([\w,\s]+?)\)?\s*$", re.M)
IMPORT_RE = re.compile(r"^\s*import\s+(repro[\w\.]*)\s*$", re.M)


@pytest.mark.parametrize("doc", DOC_FILES, ids=_doc_ids())
def test_code_fence_imports_resolve(doc):
    """Every python code fence in the docs that names a repro symbol must
    actually import — stale example code fails the suite, not the reader."""
    import importlib

    text = open(doc).read()
    problems = []
    for block in FENCE_RE.findall(text):
        for mod_name in IMPORT_RE.findall(block):
            try:
                importlib.import_module(mod_name)
            except ImportError as e:
                problems.append(f"import {mod_name}: {e}")
        for mod_name, names in FROM_RE.findall(block):
            try:
                mod = importlib.import_module(mod_name)
            except ImportError as e:
                problems.append(f"from {mod_name} import ...: {e}")
                continue
            for name in (n.strip() for n in names.split(",") if n.strip()):
                if not hasattr(mod, name):
                    problems.append(f"{mod_name} has no symbol {name!r}")
    assert not problems, f"{os.path.basename(doc)}: {problems}"
