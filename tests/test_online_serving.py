"""Online serving layer: windowed scheduling under a rolling budget, circuit
breaking + rescheduling, response caching, duplicate coalescing, replica
failover, capacity caps, and real-time pacing."""
import itertools
import threading
import time

import numpy as np
import pytest

from repro.core.problem import group_into_batches
from repro.core.scheduler import greedy_schedule, greedy_schedule_window, restrict_space
from repro.data.simulator import BatchResult
from repro.serving.fault import BreakerPolicy, CircuitState, FlakyMember, ReplicaPolicy
from repro.serving.online import (
    FakeClock,
    OnlineConfig,
    OnlineRobatchServer,
    arrival_stream,
    poisson_arrivals,
)
from repro.serving.pool import ReplicaSet, replicate_simulated


def _rate(rb, test_idx, qps, budget_x=3.0):
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect,
                                          test_idx).mean())
    return qps * base * budget_x


def _server(rb, pool, wl, *, qps=40.0, budget_x=3.0, window_s=0.25,
            threshold=1, recovery_s=1e9):
    test = wl.subset_indices("test")
    cfg = OnlineConfig(
        budget_per_s=_rate(rb, test, qps, budget_x), window_s=window_s,
        breaker=BreakerPolicy(failure_threshold=threshold,
                              recovery_time_s=recovery_s))
    return OnlineRobatchServer(rb, pool, wl, cfg)


# ---------------------------------------------------------------------------
# windowed scheduler
# ---------------------------------------------------------------------------

def test_windowed_scheduler_restricts_to_allowed_models(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:32]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost[:, space.initial_state].sum()) * 4
    res = greedy_schedule_window(space, test, budget, allowed_models={1, 2})
    assert set(np.unique(res.assignment.model)) <= {1, 2}
    assert res.amortized_cost <= budget + 1e-9
    # the unrestricted schedule can only do at least as well
    full = greedy_schedule(space, test, budget)
    assert full.est_utility >= res.est_utility - 1e-9


def test_restrict_space_reanchors_when_anchor_model_trips(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:16]
    space = fitted_rb.candidate_space(test)
    assert space.states[space.initial_state].model == 0
    sub = restrict_space(space, {1, 2})
    assert sub.states[sub.initial_state].model in {1, 2}
    # re-anchored initial state is the cheapest surviving column
    totals = sub.cost.sum(axis=0)
    assert np.argmin(totals) == sub.initial_state
    with pytest.raises(ValueError):
        restrict_space(space, set())


# ---------------------------------------------------------------------------
# rolling budget
# ---------------------------------------------------------------------------

def test_window_scheduling_respects_rolling_budget(fitted_rb, agnews, pool):
    srv = _server(fitted_rb, pool, agnews, qps=40.0, budget_x=2.0)
    rng = np.random.default_rng(0)
    arrivals = poisson_arrivals(rng, 40.0, 10.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert stats.n_completed == stats.n_submitted
    # every round's committed (amortized) cost stayed within the bucket balance
    for w in stats.windows:
        if w.n_admitted:
            assert w.est_cost <= w.avail + 1e-9
    # realized total stays within the rolling allowance (small drift tolerance
    # for exact-vs-amortized partial batches)
    assert stats.total_cost <= stats.budget_allowance * 1.05 + 1e-9


def test_tight_budget_defers_instead_of_overspending(fitted_rb, agnews, pool):
    # a rate 10× lower must not spend more than its own allowance
    srv = _server(fitted_rb, pool, agnews, qps=40.0, budget_x=0.2)
    rng = np.random.default_rng(1)
    arrivals = poisson_arrivals(rng, 40.0, 10.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert stats.total_cost <= stats.budget_allowance * 1.05 + 1e-9
    assert sum(w.n_deferred for w in stats.windows) > 0   # backpressure engaged


def test_zero_budget_sheds_all_queries(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    cfg = OnlineConfig(budget_per_s=0.0, window_s=0.25)
    srv = OnlineRobatchServer(fitted_rb, pool, agnews, cfg)
    stats = srv.run([(0.1 * i, int(q)) for i, q in enumerate(test[:8])])
    srv.close()
    assert stats.n_completed == stats.n_submitted
    assert stats.n_dropped == stats.n_submitted
    assert stats.total_cost == 0.0


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_reschedules_to_surviving_models(fitted_rb, agnews, pool):
    flaky_k = 2
    pool_f = [FlakyMember(m, fail_from=0) if k == flaky_k else m
              for k, m in enumerate(pool)]
    srv = _server(fitted_rb, pool_f, agnews, qps=40.0, budget_x=4.0)
    rng = np.random.default_rng(2)
    arrivals = poisson_arrivals(rng, 40.0, 10.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert srv.breakers[flaky_k].state == CircuitState.OPEN
    assert stats.n_reroutes > 0
    assert stats.n_completed == stats.n_submitted
    assert stats.n_dropped == 0                      # survivors absorbed everything
    served_on = {r.model for r in srv.completed if not r.dropped}
    assert flaky_k not in served_on


def test_anchor_model_outage_reanchors_and_survives(fitted_rb, agnews, pool):
    # model 0 anchors the upgrade chain; its outage exercises re-anchoring
    pool_f = [FlakyMember(pool[0], fail_from=2)] + list(pool[1:])
    srv = _server(fitted_rb, pool_f, agnews, qps=30.0, budget_x=6.0)
    rng = np.random.default_rng(3)
    arrivals = poisson_arrivals(rng, 30.0, 8.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert srv.breakers[0].state == CircuitState.OPEN
    assert stats.n_completed == stats.n_submitted
    late = [r for r in srv.completed
            if not r.dropped and not r.cache_hit and r.n_reroutes > 0]
    assert late and all(r.model in {1, 2} for r in late)


def test_half_open_breaker_recovers_after_outage_ends(fitted_rb, agnews, pool):
    # outage spans calls [0, 3); the half-open probe after recovery_time
    # succeeds and the breaker closes, readmitting the model
    flaky_k = 0
    flaky = FlakyMember(pool[0], fail_from=0, fail_until=3)
    pool_f = [flaky] + list(pool[1:])
    srv = _server(fitted_rb, pool_f, agnews, qps=30.0, budget_x=4.0,
                  threshold=1, recovery_s=2.0)
    rng = np.random.default_rng(4)
    arrivals = poisson_arrivals(rng, 30.0, 12.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert srv.breakers[flaky_k].state == CircuitState.CLOSED
    assert stats.n_completed == stats.n_submitted and stats.n_dropped == 0
    # the model serves real traffic again after recovery
    late = [r for r in srv.completed
            if r.model == flaky_k and not r.cache_hit and r.completed_at > 4.0]
    assert late


def test_half_open_probe_is_one_group_and_burns_no_reroute_budget(
        fitted_rb, agnews, pool):
    # permanently-down member with fast recovery probes: invocation count must
    # stay ~one per recovery period (no probe storms), and probe failures must
    # not drop queries through reroute exhaustion
    flaky = FlakyMember(pool[0], fail_from=0)        # never recovers
    pool_f = [flaky] + list(pool[1:])
    srv = _server(fitted_rb, pool_f, agnews, qps=30.0, budget_x=4.0,
                  threshold=1, recovery_s=1.0)
    rng = np.random.default_rng(5)
    arrivals = poisson_arrivals(rng, 30.0, 12.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert stats.n_dropped == 0
    assert stats.n_completed == stats.n_submitted
    # 12s stream, 1s recovery: ≲ 1 initial failure + ~1 probe per period
    assert flaky.n_calls <= 16


# ---------------------------------------------------------------------------
# response cache + coalescing
# ---------------------------------------------------------------------------

def test_cache_hits_bill_zero_cost(fitted_rb, agnews, pool):
    srv = _server(fitted_rb, pool, agnews, qps=10.0, budget_x=5.0)
    q = int(agnews.subset_indices("test")[0])
    first = srv.submit(q, at=0.0)
    srv.step(1.0)
    assert first.completed_at is not None and not first.cache_hit
    spent_before = srv.bucket.total_spent
    assert spent_before > 0
    second = srv.submit(q, at=1.0)
    srv.step(2.0)
    srv.close()
    assert second.cache_hit and second.cost == 0.0
    assert second.utility == first.utility
    assert srv.bucket.total_spent == spent_before    # nothing new billed


def test_duplicates_coalesce_within_a_window(fitted_rb, agnews, pool):
    srv = _server(fitted_rb, pool, agnews, qps=10.0, budget_x=5.0)
    q = int(agnews.subset_indices("test")[1])
    r1, r2 = srv.submit(q, at=0.0), srv.submit(q, at=0.1)
    rep = srv.step(1.0)
    srv.close()
    assert rep.n_coalesced == 1 and rep.n_groups == 1
    assert r1.completed_at is not None and r2.completed_at is not None
    assert r1.utility == r2.utility
    assert r1.cost == r2.cost                        # same share of one bill


def test_poisson_arrivals_sorted_and_in_universe(agnews):
    rng = np.random.default_rng(0)
    test = agnews.subset_indices("test")
    arr = poisson_arrivals(rng, 25.0, 5.0, test, repeat_frac=0.5)
    ts = [t for t, _ in arr]
    assert ts == sorted(ts) and all(0 <= t < 5.0 for t in ts)
    assert all(int(q) in set(test.tolist()) for _, q in arr)


def test_arrival_generation_is_decoupled_from_run_length(agnews):
    # generation is pure in the rng: the bounded list is a prefix of the
    # unbounded stream, and the same seed replays the same stream
    test = agnews.subset_indices("test")
    bounded = poisson_arrivals(np.random.default_rng(9), 20.0, 4.0, test,
                               repeat_frac=0.3)
    unbounded = list(itertools.islice(
        arrival_stream(np.random.default_rng(9), 20.0, test, repeat_frac=0.3),
        len(bounded) + 10))
    assert bounded == unbounded[:len(bounded)]
    assert all(t >= 4.0 for t, _ in unbounded[len(bounded):len(bounded) + 1])
    again = poisson_arrivals(np.random.default_rng(9), 20.0, 4.0, test,
                             repeat_frac=0.3)
    assert bounded == again


# ---------------------------------------------------------------------------
# replica sets: least-loaded dispatch, failover, probe re-admission
# ---------------------------------------------------------------------------

class _FakeMember:
    """Pool member stub whose utilities identify which replica served."""

    def __init__(self, tag: float, block: threading.Event = None):
        self.name = "fake"
        self.c_in, self.c_out, self.context_len = 1.0, 2.0, 512
        self.tag = tag
        self.block = block
        self.n_calls = 0

    def invoke_batch(self, wl, batch_idx):
        self.n_calls += 1
        if self.block is not None:
            assert self.block.wait(timeout=10.0)
        return BatchResult(utilities=np.full(len(batch_idx), self.tag),
                           in_tokens=10, out_tokens=2, latency_s=0.01)


def test_replica_set_dispatches_to_least_loaded_replica():
    release = threading.Event()
    rs = ReplicaSet([_FakeMember(0.0, block=release), _FakeMember(1.0)],
                    name="m")
    first: dict = {}
    th = threading.Thread(
        target=lambda: first.setdefault("out", rs.invoke_batch(None, np.arange(2))))
    th.start()
    for _ in range(500):                      # replica 0 (index tie-break) busy
        if rs.loads() == [1, 0]:
            break
        time.sleep(0.005)
    assert rs.loads() == [1, 0]
    second = rs.invoke_batch(None, np.arange(2))   # least-loaded → replica 1
    assert float(second.utilities[0]) == 1.0
    release.set()
    th.join(timeout=10.0)
    assert float(first["out"].utilities[0]) == 0.0
    assert rs.loads() == [0, 0]


def test_replica_failure_retries_sibling_then_ejects():
    flaky = FlakyMember(_FakeMember(0.0), fail_from=0)   # replica 0 always dies
    rs = ReplicaSet([flaky, _FakeMember(1.0)], name="m")
    out = rs.invoke_batch(None, np.arange(3))            # retried on replica 1
    assert float(out.utilities[0]) == 1.0
    assert rs.tracker.replicas[0].n_failures == 1 and rs.tracker.healthy(0)
    out = rs.invoke_batch(None, np.arange(3))            # second strike ejects
    assert float(out.utilities[0]) == 1.0
    assert not rs.tracker.healthy(0) and rs.n_available() == 1
    n_flaky = flaky.n_calls
    rs.invoke_batch(None, np.arange(3))                  # ejected → not retried
    assert flaky.n_calls == n_flaky


def test_replica_set_raises_only_when_every_replica_fails():
    rs = ReplicaSet([FlakyMember(_FakeMember(0.0), fail_from=0),
                     FlakyMember(_FakeMember(1.0), fail_from=0)], name="m")
    with pytest.raises(RuntimeError, match="all 2 replicas"):
        rs.invoke_batch(None, np.arange(2))
    assert rs.tracker.replicas[0].n_failures == 1
    assert rs.tracker.replicas[1].n_failures == 1


def test_replica_probe_readmission_after_cooldown():
    now = [0.0]
    rs = ReplicaSet([FlakyMember(_FakeMember(0.0), fail_from=0, fail_until=1),
                     _FakeMember(1.0)],
                    name="m", policy=ReplicaPolicy(eject_after=1, cooldown_s=5.0),
                    clock=lambda: now[0])
    out = rs.invoke_batch(None, np.arange(2))     # replica 0 faults → ejected
    assert float(out.utilities[0]) == 1.0 and not rs.tracker.healthy(0)
    out = rs.invoke_batch(None, np.arange(2))     # cooldown pending → sibling
    assert float(out.utilities[0]) == 1.0
    now[0] = 6.0                                  # cooldown elapsed: one probe
    out = rs.invoke_batch(None, np.arange(2))
    assert float(out.utilities[0]) == 0.0         # probe succeeded on replica 0
    assert rs.tracker.healthy(0) and rs.n_available() == 2


def test_one_replica_outage_degrades_set_without_tripping_breaker(
        fitted_rb, agnews, pool):
    sets = [replicate_simulated(m, 2) for m in pool]
    sets[0].replicas[0] = FlakyMember(sets[0].replicas[0], fail_from=0)
    srv = _server(fitted_rb, sets, agnews, qps=30.0, budget_x=4.0)
    arrivals = poisson_arrivals(np.random.default_rng(6), 30.0, 8.0,
                                agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert all(br.state == CircuitState.CLOSED for br in srv.breakers)
    assert stats.n_completed == stats.n_submitted and stats.n_dropped == 0
    assert stats.n_reroutes == 0                  # absorbed inside the set
    assert sets[0].tracker.replicas[0].n_failures > 0
    assert not sets[0].tracker.healthy(0)         # dead replica ejected
    served_on = {r.model for r in srv.completed if not r.cache_hit}
    assert 0 in served_on                         # the member kept serving


# ---------------------------------------------------------------------------
# replica capacity caps
# ---------------------------------------------------------------------------

def test_greedy_schedule_window_respects_group_caps(fitted_rb, agnews):
    # the legacy safety-net semantics (cap_mode="defer"): over-cap groups are
    # deferred wholesale — what the server still applies to caps-unaware plans
    test = agnews.subset_indices("test")[:24]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost.max(axis=1).sum())  # rich: upgrades to b=1 states
    caps = {0: 1, 1: 1, 2: 1}
    res = greedy_schedule_window(space, test, budget, group_caps=caps,
                                 cap_mode="defer")
    per_model: dict = {}
    for state, _members in group_into_batches(res.assignment):
        per_model[state.model] = per_model.get(state.model, 0) + 1
    assert per_model and all(n <= caps[k] for k, n in per_model.items())
    assert len(res.deferred_idx) > 0              # the caps actually bound
    scheduled = set(res.assignment.query_idx.tolist())
    assert scheduled | set(res.deferred_idx.tolist()) == set(test.tolist())
    assert scheduled.isdisjoint(res.deferred_idx.tolist())
    # the capacity-aware walk (default cap_mode="pack") keeps the same cap
    # invariant but packs into wider batches, deferring strictly less
    packed = greedy_schedule_window(space, test, budget, group_caps=caps)
    per_model = {}
    for state, _members in group_into_batches(packed.assignment):
        per_model[state.model] = per_model.get(state.model, 0) + 1
    assert per_model and all(n <= caps[k] for k, n in per_model.items())
    assert len(packed.deferred_idx) < len(res.deferred_idx)
    assert packed.n_packed > 0
    sched = set(packed.assignment.query_idx.tolist())
    assert sched | set(packed.deferred_idx.tolist()) == set(test.tolist())
    assert sched.isdisjoint(packed.deferred_idx.tolist())


def test_group_cap_zero_removes_model_from_window_space(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:16]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost[:, space.initial_state].sum()) * 4
    res = greedy_schedule_window(space, test, budget, group_caps={0: 0})
    assert 0 not in set(np.unique(res.assignment.model))
    # every member saturated: the window defers wholesale instead of crashing
    res = greedy_schedule_window(space, test, budget,
                                 group_caps={0: 0, 1: 0, 2: 0})
    assert len(res.assignment.query_idx) == 0
    assert res.deferred_idx.tolist() == test.tolist()


def test_server_never_dispatches_more_groups_than_replicas(
        fitted_rb, agnews, pool):
    sets = [replicate_simulated(m, 2) for m in pool]
    srv = _server(fitted_rb, sets, agnews, qps=40.0, budget_x=20.0,
                  window_s=0.5)
    arrivals = poisson_arrivals(np.random.default_rng(7), 40.0, 8.0,
                                agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert stats.n_completed == stats.n_submitted and stats.n_dropped == 0
    for w in stats.windows:
        for k in set(w.group_models):
            assert w.group_models.count(k) <= 2
    # capacity pressure engaged: the Δ-heap packed work into wider batches
    # (and/or held the unpackable remainder) instead of over-dispatching
    assert sum(w.n_capacity_held + w.n_cap_packed for w in stats.windows) > 0


# ---------------------------------------------------------------------------
# real-time pacing
# ---------------------------------------------------------------------------

def test_realtime_mode_paces_windows_on_a_fake_clock(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    clk = FakeClock()
    cfg = OnlineConfig(budget_per_s=_rate(fitted_rb, test, 20.0), window_s=0.25,
                       realtime=True)
    srv = OnlineRobatchServer(fitted_rb, pool, agnews, cfg, clock=clk)
    arrivals = poisson_arrivals(np.random.default_rng(8), 20.0, 3.0, test)
    stats = srv.run(arrivals)
    srv.close()
    assert stats.n_completed == stats.n_submitted
    # windows fired exactly on the boundaries: t = k·window_s, never late
    for k, w in enumerate(stats.windows, start=1):
        assert w.t == pytest.approx(k * 0.25)
        assert w.late_s == 0.0
    assert clk.t == pytest.approx(len(stats.windows) * 0.25)
    assert clk.n_sleeps >= len(stats.windows)


def test_realtime_run_tracks_wall_clock_duration(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    cfg = OnlineConfig(budget_per_s=_rate(fitted_rb, test, 20.0), window_s=0.1,
                       realtime=True)
    srv = OnlineRobatchServer(fitted_rb, pool, agnews, cfg)  # monotonic clock
    arrivals = poisson_arrivals(np.random.default_rng(8), 20.0, 0.5, test)
    t0 = time.monotonic()
    stats = srv.run(arrivals)
    wall = time.monotonic() - t0
    srv.close()
    assert stats.n_completed == stats.n_submitted
    assert 0.4 <= wall <= 3.0          # paced: neither instant nor runaway


def test_virtual_and_realtime_replay_one_seeded_stream_identically(
        fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    arrivals = poisson_arrivals(np.random.default_rng(11), 25.0, 5.0, test,
                                repeat_frac=0.3)

    def serve(realtime):
        cfg = OnlineConfig(budget_per_s=_rate(fitted_rb, test, 25.0),
                           window_s=0.25, realtime=realtime)
        srv = OnlineRobatchServer(fitted_rb, pool, agnews, cfg,
                                  clock=FakeClock() if realtime else None)
        stats = srv.run(arrivals)
        srv.close()
        trace = sorted((r.rid, r.query_idx, r.model, r.batch, r.cache_hit,
                        round(r.cost, 12), round(r.completed_at, 9))
                       for r in srv.completed)
        return stats, trace

    v_stats, v_trace = serve(realtime=False)
    r_stats, r_trace = serve(realtime=True)
    assert v_trace == r_trace
    assert v_stats.total_cost == pytest.approx(r_stats.total_cost)
    assert v_stats.qps == pytest.approx(r_stats.qps)


def test_run_live_submits_the_stream_from_a_pacer_thread(
        fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    cfg = OnlineConfig(budget_per_s=_rate(fitted_rb, test, 30.0), window_s=0.1,
                       realtime=True)
    srv = OnlineRobatchServer(fitted_rb, pool, agnews, cfg)
    arrivals = poisson_arrivals(np.random.default_rng(12), 30.0, 0.5, test)
    stats = srv.run_live(arrivals, duration_s=0.5)
    srv.close()
    assert stats.n_submitted == len(arrivals)
    assert stats.n_completed == stats.n_submitted
    # the pacer stamped each request with its generated arrival time
    assert sorted(r.arrived_at for r in srv.completed) == \
        pytest.approx(sorted(t for t, _ in arrivals))


def test_run_live_flags_a_leaked_pacer_thread(fitted_rb, agnews, pool):
    # a pacer that ignores stop() past the join timeout must be surfaced as
    # pacer_leaked=True (and warned), not silently abandoned via the daemon
    # flag; a clean shutdown reads False
    import repro.serving.online as online_mod

    test = agnews.subset_indices("test")
    cfg = OnlineConfig(budget_per_s=_rate(fitted_rb, test, 30.0), window_s=0.1,
                       realtime=True)
    srv = OnlineRobatchServer(fitted_rb, pool, agnews, cfg)
    arrivals = [(0.05, int(test[0]))]
    srv.run_live(arrivals, duration_s=0.1)
    assert srv.pacer_leaked is False

    class _Stubborn(online_mod.LiveArrivalSource):
        def join(self, timeout=None):
            return None                      # never actually exits

        def is_alive(self):
            # alive only to the shutdown path: the serving loop's drain
            # check (pre-stop) must still see the stream as finished
            return self._stop_requested.is_set()

    srv2 = OnlineRobatchServer(fitted_rb, pool, agnews, cfg)
    real = online_mod.LiveArrivalSource
    online_mod.LiveArrivalSource = _Stubborn
    try:
        srv2.run_live(arrivals, duration_s=0.1, join_timeout_s=0.05)
    finally:
        online_mod.LiveArrivalSource = real
        srv2.close()
    srv.close()
    assert srv2.pacer_leaked is True


# ---------------------------------------------------------------------------
# chaos injection: seeded determinism + the dispatch-hardening ladder
# ---------------------------------------------------------------------------

from repro.serving.fault import ChaosMember, CircuitBreaker, ReplicaTracker  # noqa: E402
from repro.serving.pool import DispatchTimeout  # noqa: E402


def test_chaos_member_is_deterministic_and_counts_faults():
    def mk():
        return ChaosMember(_FakeMember(1.0), seed=42, latency_noise_s=0.05,
                           fail_from=2, fail_until=4, error_rate=1.0)

    traces = []
    for c in (mk(), mk()):
        lats = []
        for _ in range(6):
            try:
                lats.append(c.invoke_batch(None, np.arange(2)).latency_s)
            except RuntimeError:
                lats.append(None)
        traces.append(lats)
        assert c.n_calls == 6 and c.n_faults == 2 and c.n_hangs == 0
    assert traces[0] == traces[1]            # bit-identical given the seed
    assert traces[0][2] is None and traces[0][3] is None
    assert all(lat > 0.01 for i, lat in enumerate(traces[0])
               if i not in (2, 3))           # noise added on surviving calls


def test_chaos_member_slow_degrade_grows_latency():
    c = ChaosMember(_FakeMember(1.0), seed=0, degrade_s=0.1)
    lats = [c.invoke_batch(None, np.arange(1)).latency_s for _ in range(4)]
    assert lats == sorted(lats)
    assert lats[3] - lats[0] == pytest.approx(0.3)


def test_chaos_member_proxies_the_member_protocol():
    inner = _FakeMember(1.0)
    c = ChaosMember(inner, seed=0)
    assert (c.name, c.c_in, c.c_out, c.context_len) == \
        (inner.name, inner.c_in, inner.c_out, inner.context_len)
    assert c.supports_streams is False and c.supports_generation is False


def test_dispatch_timeout_fails_over_from_hung_replica():
    hung = ChaosMember(_FakeMember(0.0), seed=1, hang_from=0, hang_until=1,
                       hang_s=5.0)
    rs = ReplicaSet([hung, _FakeMember(1.0)], name="m", dispatch_timeout_s=0.2)
    t0 = time.perf_counter()
    out = rs.invoke_batch(None, np.arange(2))
    wall = time.perf_counter() - t0
    assert float(out.utilities[0]) == 1.0    # sibling served the batch
    assert wall < 4.0                        # did not wait out the hang
    assert rs.n_timeouts == 1 and hung.n_hangs == 1
    assert rs.tracker.replicas[0].n_failures == 1
    assert rs.loads() == [0, 0]              # in-flight slots fully released


def test_dispatch_timeout_raises_when_every_replica_hangs():
    rs = ReplicaSet([ChaosMember(_FakeMember(0.0), seed=2, hang_from=0,
                                 hang_s=5.0)],
                    name="m", dispatch_timeout_s=0.1)
    with pytest.raises(RuntimeError, match="all 1 replicas"):
        rs.invoke_batch(None, np.arange(2))
    assert rs.n_timeouts == 1


def test_dispatch_retry_ladder_recovers_transient_fault():
    flaky = FlakyMember(_FakeMember(1.0), fail_from=0, fail_until=2)
    rs = ReplicaSet([flaky], name="m", max_dispatch_retries=2,
                    backoff_base_s=0.01, backoff_cap_s=0.02)
    t0 = time.perf_counter()
    out = rs.invoke_batch(None, np.arange(2))
    assert float(out.utilities[0]) == 1.0    # 3rd attempt, SAME replica
    assert time.perf_counter() - t0 >= 0.02  # 0.01 + 0.02 backoff slept
    assert rs.n_dispatch_retries == 2
    assert rs.tracker.replicas[0].n_failures == 2
    assert rs.tracker.healthy(0)             # success reset the streak
    assert rs.loads() == [0]


def test_timeouts_never_burn_same_replica_retries():
    hung = ChaosMember(_FakeMember(0.0), seed=3, hang_from=0, hang_until=10,
                       hang_s=5.0)
    rs = ReplicaSet([hung, _FakeMember(1.0)], name="m",
                    dispatch_timeout_s=0.1, max_dispatch_retries=3)
    out = rs.invoke_batch(None, np.arange(2))
    assert float(out.utilities[0]) == 1.0
    assert hung.n_calls == 1                 # one dispatch, zero retries on it
    assert rs.n_dispatch_retries == 0


def test_dispatch_timeout_error_is_typed():
    with pytest.raises(DispatchTimeout):
        rs = ReplicaSet([ChaosMember(_FakeMember(0.0), seed=4, hang_from=0,
                                     hang_s=5.0)],
                        name="m", dispatch_timeout_s=0.05)
        try:
            rs.invoke_batch(None, np.arange(1))
        except RuntimeError as e:
            raise e.__cause__                # the failover chain keeps it


# ---------------------------------------------------------------------------
# concurrency: breaker half-open probes and tracker ejection under racing
# dispatch threads
# ---------------------------------------------------------------------------

def test_breaker_half_open_concurrent_probes_and_single_retrip():
    now = [0.0]
    br = CircuitBreaker(BreakerPolicy(failure_threshold=1, recovery_time_s=1.0),
                        clock=lambda: now[0])
    br.record_failure()
    assert br.state is CircuitState.OPEN and br.n_trips == 1
    assert not br.allow_request()            # cooling down
    now[0] = 2.0
    barrier = threading.Barrier(8)
    got = []

    def probe():
        barrier.wait()
        got.append(br.allow_request())

    ths = [threading.Thread(target=probe) for _ in range(8)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(timeout=10.0)
    assert got == [True] * 8                 # racing probes all admitted
    assert br.state is CircuitState.HALF_OPEN
    br.record_failure()                      # the probe failed
    assert br.state is CircuitState.OPEN and br.n_trips == 2
    now[0] = 4.0
    assert br.allow_request()
    br.record_success()
    assert br.state is CircuitState.CLOSED and br.failure_count == 0


def test_concurrent_dispatch_ejects_dead_replica_and_drains_slots():
    dead = FlakyMember(_FakeMember(0.0), fail_from=0)    # always faults
    rs = ReplicaSet([dead, _FakeMember(1.0), _FakeMember(2.0)], name="m")
    outs: list = []
    barrier = threading.Barrier(12)

    def work():
        barrier.wait()
        outs.append(float(rs.invoke_batch(None, np.arange(2)).utilities[0]))

    ths = [threading.Thread(target=work) for _ in range(12)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(timeout=10.0)
    assert len(outs) == 12
    assert all(u in (1.0, 2.0) for u in outs)   # nothing served by the corpse
    assert not rs.tracker.healthy(0)            # racing failures ejected it
    assert rs.tracker.replicas[0].n_ejections >= 1
    assert rs.loads() == [0, 0, 0]              # every in-flight slot released


def test_tracker_concurrent_failures_eject_exactly_not_forever():
    trk = ReplicaTracker(2, ReplicaPolicy(eject_after=4, cooldown_s=30.0),
                         clock=lambda: 0.0)
    barrier = threading.Barrier(8)

    def fail():
        barrier.wait()
        trk.record_failure(0)

    ths = [threading.Thread(target=fail) for _ in range(8)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(timeout=10.0)
    assert not trk.healthy(0) and trk.healthy(1)
    assert trk.replicas[0].n_failures >= trk.policy.eject_after
    trk.record_success(0)                       # re-admission clears the slate
    assert trk.healthy(0) and trk.replicas[0].consecutive_failures == 0
