"""Online serving layer: windowed scheduling under a rolling budget, circuit
breaking + rescheduling, response caching, duplicate coalescing."""
import numpy as np
import pytest

from repro.core.scheduler import greedy_schedule, greedy_schedule_window, restrict_space
from repro.serving.fault import BreakerPolicy, CircuitState, FlakyMember
from repro.serving.online import (OnlineConfig, OnlineRobatchServer,
                                  poisson_arrivals)


def _rate(rb, test_idx, qps, budget_x=3.0):
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect,
                                          test_idx).mean())
    return qps * base * budget_x


def _server(rb, pool, wl, *, qps=40.0, budget_x=3.0, window_s=0.25,
            threshold=1, recovery_s=1e9):
    test = wl.subset_indices("test")
    cfg = OnlineConfig(
        budget_per_s=_rate(rb, test, qps, budget_x), window_s=window_s,
        breaker=BreakerPolicy(failure_threshold=threshold,
                              recovery_time_s=recovery_s))
    return OnlineRobatchServer(rb, pool, wl, cfg)


# ---------------------------------------------------------------------------
# windowed scheduler
# ---------------------------------------------------------------------------

def test_windowed_scheduler_restricts_to_allowed_models(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:32]
    space = fitted_rb.candidate_space(test)
    budget = float(space.cost[:, space.initial_state].sum()) * 4
    res = greedy_schedule_window(space, test, budget, allowed_models={1, 2})
    assert set(np.unique(res.assignment.model)) <= {1, 2}
    assert res.amortized_cost <= budget + 1e-9
    # the unrestricted schedule can only do at least as well
    full = greedy_schedule(space, test, budget)
    assert full.est_utility >= res.est_utility - 1e-9


def test_restrict_space_reanchors_when_anchor_model_trips(fitted_rb, agnews):
    test = agnews.subset_indices("test")[:16]
    space = fitted_rb.candidate_space(test)
    assert space.states[space.initial_state].model == 0
    sub = restrict_space(space, {1, 2})
    assert sub.states[sub.initial_state].model in {1, 2}
    # re-anchored initial state is the cheapest surviving column
    totals = sub.cost.sum(axis=0)
    assert np.argmin(totals) == sub.initial_state
    with pytest.raises(ValueError):
        restrict_space(space, set())


# ---------------------------------------------------------------------------
# rolling budget
# ---------------------------------------------------------------------------

def test_window_scheduling_respects_rolling_budget(fitted_rb, agnews, pool):
    srv = _server(fitted_rb, pool, agnews, qps=40.0, budget_x=2.0)
    rng = np.random.default_rng(0)
    arrivals = poisson_arrivals(rng, 40.0, 10.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert stats.n_completed == stats.n_submitted
    # every round's committed (amortized) cost stayed within the bucket balance
    for w in stats.windows:
        if w.n_admitted:
            assert w.est_cost <= w.avail + 1e-9
    # realized total stays within the rolling allowance (small drift tolerance
    # for exact-vs-amortized partial batches)
    assert stats.total_cost <= stats.budget_allowance * 1.05 + 1e-9


def test_tight_budget_defers_instead_of_overspending(fitted_rb, agnews, pool):
    # a rate 10× lower must not spend more than its own allowance
    srv = _server(fitted_rb, pool, agnews, qps=40.0, budget_x=0.2)
    rng = np.random.default_rng(1)
    arrivals = poisson_arrivals(rng, 40.0, 10.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert stats.total_cost <= stats.budget_allowance * 1.05 + 1e-9
    assert sum(w.n_deferred for w in stats.windows) > 0   # backpressure engaged


def test_zero_budget_sheds_all_queries(fitted_rb, agnews, pool):
    test = agnews.subset_indices("test")
    cfg = OnlineConfig(budget_per_s=0.0, window_s=0.25)
    srv = OnlineRobatchServer(fitted_rb, pool, agnews, cfg)
    stats = srv.run([(0.1 * i, int(q)) for i, q in enumerate(test[:8])])
    srv.close()
    assert stats.n_completed == stats.n_submitted
    assert stats.n_dropped == stats.n_submitted
    assert stats.total_cost == 0.0


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_reschedules_to_surviving_models(fitted_rb, agnews, pool):
    flaky_k = 2
    pool_f = [FlakyMember(m, fail_from=0) if k == flaky_k else m
              for k, m in enumerate(pool)]
    srv = _server(fitted_rb, pool_f, agnews, qps=40.0, budget_x=4.0)
    rng = np.random.default_rng(2)
    arrivals = poisson_arrivals(rng, 40.0, 10.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert srv.breakers[flaky_k].state == CircuitState.OPEN
    assert stats.n_reroutes > 0
    assert stats.n_completed == stats.n_submitted
    assert stats.n_dropped == 0                      # survivors absorbed everything
    served_on = {r.model for r in srv.completed if not r.dropped}
    assert flaky_k not in served_on


def test_anchor_model_outage_reanchors_and_survives(fitted_rb, agnews, pool):
    # model 0 anchors the upgrade chain; its outage exercises re-anchoring
    pool_f = [FlakyMember(pool[0], fail_from=2)] + list(pool[1:])
    srv = _server(fitted_rb, pool_f, agnews, qps=30.0, budget_x=6.0)
    rng = np.random.default_rng(3)
    arrivals = poisson_arrivals(rng, 30.0, 8.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert srv.breakers[0].state == CircuitState.OPEN
    assert stats.n_completed == stats.n_submitted
    late = [r for r in srv.completed
            if not r.dropped and not r.cache_hit and r.n_reroutes > 0]
    assert late and all(r.model in {1, 2} for r in late)


def test_half_open_breaker_recovers_after_outage_ends(fitted_rb, agnews, pool):
    # outage spans calls [0, 3); the half-open probe after recovery_time
    # succeeds and the breaker closes, readmitting the model
    flaky_k = 0
    flaky = FlakyMember(pool[0], fail_from=0, fail_until=3)
    pool_f = [flaky] + list(pool[1:])
    srv = _server(fitted_rb, pool_f, agnews, qps=30.0, budget_x=4.0,
                  threshold=1, recovery_s=2.0)
    rng = np.random.default_rng(4)
    arrivals = poisson_arrivals(rng, 30.0, 12.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert srv.breakers[flaky_k].state == CircuitState.CLOSED
    assert stats.n_completed == stats.n_submitted and stats.n_dropped == 0
    # the model serves real traffic again after recovery
    late = [r for r in srv.completed
            if r.model == flaky_k and not r.cache_hit and r.completed_at > 4.0]
    assert late


def test_half_open_probe_is_one_group_and_burns_no_reroute_budget(
        fitted_rb, agnews, pool):
    # permanently-down member with fast recovery probes: invocation count must
    # stay ~one per recovery period (no probe storms), and probe failures must
    # not drop queries through reroute exhaustion
    flaky = FlakyMember(pool[0], fail_from=0)        # never recovers
    pool_f = [flaky] + list(pool[1:])
    srv = _server(fitted_rb, pool_f, agnews, qps=30.0, budget_x=4.0,
                  threshold=1, recovery_s=1.0)
    rng = np.random.default_rng(5)
    arrivals = poisson_arrivals(rng, 30.0, 12.0, agnews.subset_indices("test"))
    stats = srv.run(arrivals)
    srv.close()
    assert stats.n_dropped == 0
    assert stats.n_completed == stats.n_submitted
    # 12s stream, 1s recovery: ≲ 1 initial failure + ~1 probe per period
    assert flaky.n_calls <= 16


# ---------------------------------------------------------------------------
# response cache + coalescing
# ---------------------------------------------------------------------------

def test_cache_hits_bill_zero_cost(fitted_rb, agnews, pool):
    srv = _server(fitted_rb, pool, agnews, qps=10.0, budget_x=5.0)
    q = int(agnews.subset_indices("test")[0])
    first = srv.submit(q, at=0.0)
    srv.step(1.0)
    assert first.completed_at is not None and not first.cache_hit
    spent_before = srv.bucket.total_spent
    assert spent_before > 0
    second = srv.submit(q, at=1.0)
    srv.step(2.0)
    srv.close()
    assert second.cache_hit and second.cost == 0.0
    assert second.utility == first.utility
    assert srv.bucket.total_spent == spent_before    # nothing new billed


def test_duplicates_coalesce_within_a_window(fitted_rb, agnews, pool):
    srv = _server(fitted_rb, pool, agnews, qps=10.0, budget_x=5.0)
    q = int(agnews.subset_indices("test")[1])
    r1, r2 = srv.submit(q, at=0.0), srv.submit(q, at=0.1)
    rep = srv.step(1.0)
    srv.close()
    assert rep.n_coalesced == 1 and rep.n_groups == 1
    assert r1.completed_at is not None and r2.completed_at is not None
    assert r1.utility == r2.utility
    assert r1.cost == r2.cost                        # same share of one bill


def test_poisson_arrivals_sorted_and_in_universe(agnews):
    rng = np.random.default_rng(0)
    test = agnews.subset_indices("test")
    arr = poisson_arrivals(rng, 25.0, 5.0, test, repeat_frac=0.5)
    ts = [t for t, _ in arr]
    assert ts == sorted(ts) and all(0 <= t < 5.0 for t in ts)
    assert all(int(q) in set(test.tolist()) for _, q in arr)
