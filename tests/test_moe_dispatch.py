"""MoE dispatch equivalence: dense (one-hot oracle) vs gather vs EP, chunked
paths, capacity drops, vocab padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, ShardingConfig, get_arch
from repro.models import moe as moe_mod
from repro.models.layers import Builder
from repro.models.transformer import Model


def _cfg(E=8, k=2, cf=8.0):
    return ModelConfig(name="m", family="moe", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
                       moe=MoEConfig(n_experts=E, top_k=k, d_expert=16,
                                     capacity_factor=cf))


@pytest.fixture()
def setup_moe():
    cfg = _cfg()
    b = Builder("init", jax.random.PRNGKey(0))
    p = moe_mod.init_moe(b, cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32, 32)), jnp.float32)
    return cfg, p, x


def test_gather_matches_dense_oracle(setup_moe):
    cfg, p, x = setup_moe
    o_d, _ = moe_mod.apply_moe(p, cfg, x, "dense")
    o_g, _ = moe_mod.apply_moe(p, cfg, x, "gather")
    np.testing.assert_allclose(np.asarray(o_d), np.asarray(o_g), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dp", [1, 2, 4])
def test_ep_matches_gather(setup_moe, dp):
    cfg, p, x = setup_moe
    o_g, _ = moe_mod.apply_moe(p, cfg, x, "gather")
    o_e, _ = moe_mod.apply_moe(p, cfg, x, "ep", dp_size=dp)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_e), atol=1e-5, rtol=1e-5)


def test_ep_chunked_matches(setup_moe):
    cfg, p, x = setup_moe
    o_g, _ = moe_mod.apply_moe(p, cfg, x, "gather")
    o_c, _ = moe_mod.apply_moe(p, cfg, x, "ep", dp_size=4, chunk_tokens=32)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_c), atol=1e-5, rtol=1e-5)


def test_ep_grads_match(setup_moe):
    cfg, p, x = setup_moe

    def loss(p_, disp, dp):
        o, aux = moe_mod.apply_moe(p_, cfg, x, disp, dp_size=dp)
        return jnp.sum(o * o) + aux

    g1 = jax.grad(loss)(p, "gather", 1)
    g2 = jax.grad(loss)(p, "ep", 4)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens():
    """With cf tiny, overflow tokens are dropped (output contribution zero)."""
    cfg = _cfg(cf=0.25)
    b = Builder("init", jax.random.PRNGKey(1))
    p = moe_mod.init_moe(b, cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 16, 32)), jnp.float32)
    o_small, _ = moe_mod.apply_moe(p, cfg, x, "gather")
    o_exact, _ = moe_mod.apply_moe(p, cfg, x, "gather", exact=True)
    # exact capacity differs from dropped capacity
    assert float(jnp.abs(o_small - o_exact).max()) > 1e-4


def test_aux_loss_balanced_routing_lower():
    cfg = _cfg()
    b = Builder("init", jax.random.PRNGKey(2))
    p = moe_mod.init_moe(b, cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 64, 32)), jnp.float32)
    _, aux = moe_mod.apply_moe(p, cfg, x, "gather")
    # Switch aux for perfectly balanced routing is weight × 1.0
    assert float(aux) >= cfg.moe.router_aux_weight * 0.9


def test_vocab_padding_masks_logits():
    """Non-32-multiple vocab (seamless) pads internally; padded columns -inf."""
    cfg = get_arch("seamless-m4t-large-v2").reduced()
    object.__setattr__(cfg, "vocab_size", 510)   # force padding to 512
    model = Model(cfg, ShardingConfig(remat="none"))
    assert model.vocab_padded == 512
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 510, (2, 8)), jnp.int32)
    enc = jnp.asarray(rng.normal(size=(2, 6, cfg.d_model)), jnp.float32)
    logits, _ = model.forward(params, tokens, enc_inputs=enc)
    assert logits.shape[-1] == 512
    assert bool((logits[..., 510:] < -1e20).all())
    assert bool(jnp.isfinite(logits[..., :510]).all())
