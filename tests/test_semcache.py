"""Semantic response cache: thresholded NN lookup in the router's embedding
space, ε(sim) utility-loss calibration, TTL/LRU eviction under a byte budget,
and the online-plane wiring (zero-cost completions that reconcile with
``WindowReport`` telemetry and stay bit-identical when disabled)."""
import numpy as np
import pytest

from repro.core.scheduler import ScheduleResult, attach_free_assignments
from repro.serving.fault import BreakerPolicy
from repro.serving.online import OnlineConfig, OnlineRobatchServer
from repro.serving.semcache import (
    EpsilonModel,
    SemanticCache,
    SemanticCacheConfig,
)


def _cache(rb, **kw):
    return SemanticCache.from_artifacts(rb, SemanticCacheConfig(**kw))


def _nn_pairs(wl, min_sim, n=8):
    """(query, neighbor, sim) triples from the test split with sim >= min_sim,
    most-similar first."""
    test = wl.subset_indices("test")
    emb = wl.embeddings[test]
    sims = emb @ emb.T
    np.fill_diagonal(sims, -np.inf)
    nn = np.argmax(sims, axis=1)
    best = sims[np.arange(len(test)), nn]
    order = np.argsort(-best)
    out = []
    for p in order:
        if best[p] < min_sim or len(out) >= n:
            break
        out.append((int(test[p]), int(test[nn[p]]), float(best[p])))
    return out


def _server(rb, pool, wl, *, semcache=None, qps=40.0, budget_x=3.0,
            window_s=0.25):
    test = wl.subset_indices("test")
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect,
                                          test).mean())
    cfg = OnlineConfig(budget_per_s=qps * base * budget_x, window_s=window_s,
                       breaker=BreakerPolicy(failure_threshold=1,
                                             recovery_time_s=1e9),
                       semantic_cache=semcache)
    return OnlineRobatchServer(rb, pool, wl, cfg)


# ---------------------------------------------------------------------------
# ε(sim) calibration
# ---------------------------------------------------------------------------

def test_epsilon_model_monotone_nonincreasing_and_clipped(fitted_rb):
    eps = _cache(fitted_rb).eps_model
    assert np.all(np.diff(eps.eps_grid) <= 1e-12)
    sims = np.linspace(-1.0, 1.0, 101)
    vals = np.array([eps(s) for s in sims])
    assert np.all((0.0 <= vals) & (vals <= 1.0))
    # the property the bench loss bound leans on: sim >= tau => eps <= eps(tau)
    assert np.all(np.diff(vals) <= 1e-12)


def test_epsilon_model_degenerate_similarity_spread():
    emb = np.tile(np.array([[1.0, 0.0]]), (8, 1)).astype(np.float32)
    util = np.linspace(0, 1, 8)[:, None] * np.ones((8, 3))
    eps = EpsilonModel.fit(emb, util, n_pairs=64, n_bins=4, seed=0)
    assert 0.0 <= eps(1.0) <= 1.0


# ---------------------------------------------------------------------------
# cache mechanics: hit/miss/threshold boundary, TTL, LRU byte budget
# ---------------------------------------------------------------------------

def test_self_hit_is_priced_with_epsilon(fitted_rb):
    sc = _cache(fitted_rb, sim_threshold=0.9)
    q = int(fitted_rb.wl.subset_indices("test")[0])
    sc.insert(q, 0.8, 1, "answer")
    hit = sc.lookup(q)
    assert hit is not None and hit.source_idx == q
    assert hit.similarity == pytest.approx(1.0, abs=1e-5)
    eps = sc.eps_model(hit.similarity)
    assert hit.epsilon == pytest.approx(eps)
    assert hit.utility == pytest.approx(0.8 * (1 - eps))
    assert hit.utility_loss == pytest.approx(0.8 * eps)
    assert hit.model == 1 and hit.content == "answer"
    assert sc.hits == 1 and sc.utility_loss == pytest.approx(hit.utility_loss)


def test_threshold_boundary_straddles_measured_similarity(fitted_rb, agnews):
    (q, nn, sim), = _nn_pairs(agnews, 0.8, n=1)
    below = _cache(fitted_rb, sim_threshold=sim - 1e-4)
    above = _cache(fitted_rb, sim_threshold=sim + 1e-4)
    for sc in (below, above):
        sc.insert(nn, 0.7, 0, "cached")
    assert below.lookup(q) is not None
    assert above.lookup(q) is None
    assert below.hits == 1 and above.misses == 1


def test_inf_threshold_disables_lookup_and_insert(fitted_rb):
    sc = _cache(fitted_rb, sim_threshold=float("inf"))
    q = int(fitted_rb.wl.subset_indices("test")[0])
    sc.insert(q, 0.9, 0, "x")
    assert len(sc) == 0 and sc.insertions == 0
    assert sc.lookup(q) is None
    assert sc.hits == 0 and sc.misses == 0   # not even a counted miss


def test_ttl_expires_entries_on_the_serving_timeline(fitted_rb):
    sc = _cache(fitted_rb, sim_threshold=0.99, ttl_s=1.0)
    q = int(fitted_rb.wl.subset_indices("test")[0])
    sc.insert(q, 0.9, 0, "x", now=0.0)
    assert sc.lookup(q, now=0.5) is not None
    assert sc.lookup(q, now=1.5) is None
    assert sc.expirations == 1 and len(sc) == 0


def test_lru_eviction_under_byte_budget(fitted_rb):
    test = fitted_rb.wl.subset_indices("test")
    sc = _cache(fitted_rb, sim_threshold=2.0, max_bytes=3 * 200)
    for k in range(4):
        sc.insert(int(test[k]), 0.5, 0, "a" * (200 - 96))
    assert sc.evictions == 1 and len(sc) == 3
    assert int(test[0]) not in sc._entries          # oldest evicted first
    assert sc.total_bytes <= sc.cfg.max_bytes
    # a lookup hit refreshes recency: make test[1] most-recent, then insert —
    # test[2] (now the LRU entry) is the one evicted
    sc2 = _cache(fitted_rb, sim_threshold=0.0, max_bytes=2 * 200)
    sc2.insert(int(test[1]), 0.5, 0, "a" * 104)
    sc2.insert(int(test[2]), 0.5, 0, "a" * 104)
    assert sc2.lookup(int(test[1])).source_idx == int(test[1])
    sc2.insert(int(test[3]), 0.5, 0, "a" * 104)
    assert int(test[2]) not in sc2._entries
    assert int(test[1]) in sc2._entries


def test_oversize_entry_is_not_stored(fitted_rb):
    sc = _cache(fitted_rb, sim_threshold=0.9, max_bytes=128)
    q = int(fitted_rb.wl.subset_indices("test")[0])
    sc.insert(q, 0.9, 0, "a" * 4096)
    assert len(sc) == 0 and sc.total_bytes == 0


def test_lsh_index_hits_agree_with_brute_force(fitted_rb, agnews):
    pairs = _nn_pairs(agnews, 0.8)
    brute = _cache(fitted_rb, sim_threshold=0.8)
    lsh = _cache(fitted_rb, sim_threshold=0.8, index="lsh")
    for _q, nn, _s in pairs:
        brute.insert(nn, 0.6, 0, "c")
        lsh.insert(nn, 0.6, 0, "c")
    n_agree = 0
    for q, _nn, _s in pairs:
        b, l = brute.lookup(q), lsh.lookup(q)
        if l is not None:                    # LSH trades a little recall
            assert b is not None
            assert l.similarity <= b.similarity + 1e-6
            assert l.similarity >= lsh.cfg.sim_threshold
            n_agree += 1
    assert n_agree > 0, "LSH probe found no near-duplicates at all"


# ---------------------------------------------------------------------------
# scheduler accounting
# ---------------------------------------------------------------------------

def test_attach_free_assignments_accounting():
    res = ScheduleResult(assignment=None, est_utility=2.0, amortized_cost=0.5,
                         spent_budget=0.5, n_upgrades=0, infeasible=False)
    out = attach_free_assignments(res, [0.5, 0.25])
    assert out is res
    assert res.n_free == 2
    assert res.free_utility == pytest.approx(0.75)
    assert res.est_utility == pytest.approx(2.75)
    assert res.amortized_cost == pytest.approx(0.5)   # hits cost nothing


# ---------------------------------------------------------------------------
# online-plane wiring
# ---------------------------------------------------------------------------

def _neardup_arrivals(wl, min_sim=0.8, n=8):
    """Each neighbor arrives two windows after its source was served."""
    arr = []
    for k, (q, nn, _s) in enumerate(_nn_pairs(wl, min_sim, n=n)):
        arr.append((k * 2.0 + 0.1, nn))
        arr.append((k * 2.0 + 1.1, q))
    return sorted(arr)


def test_sem_hits_complete_at_zero_cost_and_reconcile(fitted_rb, agnews, pool):
    srv = _server(fitted_rb, pool, agnews,
                  semcache=SemanticCacheConfig(sim_threshold=0.8))
    stats = srv.run(_neardup_arrivals(agnews))
    srv.close()
    sem = [r for r in srv.completed if r.sem_hit]
    assert sem and stats.n_sem_hits == len(sem)
    for r in sem:
        assert r.cost == 0.0 and r.cache_hit and not r.dropped
        assert r.sem_sim >= 0.8
        assert r.content is not None
    assert sum(w.n_sem_hits for w in stats.windows) == len(sem)
    assert (sum(w.sem_utility_loss for w in stats.windows)
            == pytest.approx(sum(r.sem_loss for r in sem)))
    assert stats.sem_utility_loss == pytest.approx(sum(r.sem_loss for r in sem))
    # free assignments folded into the windows' schedule accounting
    assert srv.semcache.stats()["hits"] == len(sem)


def test_inf_threshold_server_is_bit_identical_to_no_cache(fitted_rb, agnews,
                                                           pool):
    arrivals = _neardup_arrivals(agnews)

    def record(semcache):
        srv = _server(fitted_rb, pool, agnews, semcache=semcache)
        srv.run(list(arrivals))
        srv.close()
        return [(r.rid, r.query_idx, r.completed_at, r.utility, r.model,
                 r.batch, r.cost, r.cache_hit) for r in srv.completed]

    off = record(None)
    inf = record(SemanticCacheConfig(sim_threshold=float("inf")))
    assert off == inf


def test_seeded_stream_serves_deterministically(fitted_rb, agnews, pool):
    arrivals = _neardup_arrivals(agnews)

    def run():
        srv = _server(fitted_rb, pool, agnews,
                      semcache=SemanticCacheConfig(sim_threshold=0.8))
        srv.run(list(arrivals))
        srv.close()
        return ([(r.rid, r.query_idx, r.completed_at, r.utility, r.model,
                  r.cost, r.sem_hit, r.sem_sim, r.sem_loss)
                 for r in srv.completed], srv.semcache.stats())

    a, b = run(), run()
    assert a == b


# ---------------------------------------------------------------------------
# spec / gateway plumbing
# ---------------------------------------------------------------------------

def test_poolspec_semcache_roundtrip_and_config():
    from repro.api import PoolSpec, RunSpec

    spec = RunSpec(pool=PoolSpec(semantic_cache=True, sim_threshold=0.88))
    back = RunSpec.from_json(spec.to_json())
    assert back.pool.semantic_cache is True
    assert back.pool.sim_threshold == 0.88
    cfg = back.pool.semcache_config()
    assert isinstance(cfg, SemanticCacheConfig)
    assert cfg.sim_threshold == 0.88
    assert PoolSpec().semcache_config() is None


def test_gateway_injects_spec_declared_semcache(fitted_rb, agnews, pool):
    from repro.api import Gateway, PoolSpec, RunSpec

    gw = Gateway.from_spec(RunSpec(pool=PoolSpec(
        task="agnews", n_train=192, n_val=48, n_test=96,
        semantic_cache=True, sim_threshold=0.8)))
    gw.fit()
    cfg = gw._resolve_semcache(OnlineConfig(budget_per_s=1.0))
    assert cfg.semantic_cache is not None
    assert cfg.semantic_cache.sim_threshold == 0.8
    # an explicit config wins over the spec's declaration
    explicit = OnlineConfig(budget_per_s=1.0,
                            semantic_cache=SemanticCacheConfig(
                                sim_threshold=0.95))
    assert gw._resolve_semcache(explicit).semantic_cache.sim_threshold == 0.95
