"""Hypothesis import shim: real hypothesis when installed, inert stand-ins
otherwise — so ONLY the property-based tests skip when it is missing.

The seed used ``pytest.importorskip("hypothesis")`` at module level in four
test modules, silently skipping every test in them (including plain example
tests — ``test_moe_dispatch.py`` contained no property tests at all).  Test
modules now do ``from hypcompat import given, settings, st`` instead: with
hypothesis absent, ``@given`` marks just that test skipped, strategy
construction is a no-op, and everything else in the module still runs.

CI installs hypothesis (``requirements-ci.txt``) and exports
``REQUIRE_HYPOTHESIS=1``, which turns a broken install into a hard import
error here — the formerly-skipped modules can never silently skip again
(the workflow additionally greps the pytest summary for skips).
"""
import os

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert strategy: every combinator returns another inert strategy
        (decoration-time expressions like ``st.lists(st.integers(), ...)``
        must evaluate; the decorated test is skipped before drawing)."""

        def __call__(self, *a, **k):
            return _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    class _St:
        def __getattr__(self, name):
            return _Strategy()

    st = _St()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(property test; examples still run)")

    def settings(*_a, **_k):
        return lambda fn: fn

    settings.register_profile = lambda *a, **k: None
    settings.load_profile = lambda *a, **k: None
