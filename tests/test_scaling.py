"""Calibration: Eq. 10 b_max, RCU unimodality handling + ternary search,
scaling-function fits (Eq. 12 piecewise / power-law / KNN)."""
import numpy as np
import pytest

from repro.core.problem import CostModel
from repro.core.scaling import (
    ProfileCache,
    b_max_from_epsilon,
    batch_grid,
    calibrate_model,
    fit_scaling,
    rcu,
    ternary_search_rcu,
)


@pytest.fixture()
def setup(agnews, pool):
    cm = CostModel(pool, agnews)
    core = agnews.subset_indices("train")[:64]
    cache = ProfileCache(pool, agnews, core)
    return cm, cache


def test_b_max_eq10(setup, agnews, pool):
    cm, cache = setup
    eps = 0.01
    for k in range(len(pool)):
        b = b_max_from_epsilon(cm, k, cache.coreset_idx, eps)
        c_sys = cm.sys_cost(k)
        e_q = cm.expected_query_cost(k, cache.coreset_idx)
        # at b_max the sys-prompt share is still >= eps; at b_max+1 it drops below
        share = c_sys / (c_sys + b * e_q)
        assert share >= eps * 0.99  # ceiling keeps share at/above the threshold boundary
        assert b == int(np.ceil(c_sys * (1 - eps) / (eps * e_q)))


def test_batch_grid_multiples_of_four():
    g = batch_grid(20)
    assert g.tolist() == [1, 2, 4, 8, 12, 16, 20]
    assert batch_grid(1).tolist() == [1]


def test_profile_cache_no_rebilling(setup):
    cm, cache = setup
    cache.utilities(0, 4)
    n = cache.n_probes
    cache.utilities(0, 4)
    cache.mean_utility(0, 4)
    assert cache.n_probes == n


def test_rcu_infinite_when_collapsed(setup):
    cm, cache = setup
    # fabricate a collapsed profile
    cache._cache[(0, 64)] = np.zeros(len(cache.coreset_idx))
    assert rcu(cm, cache, 0, 64) == float("inf")


def test_ternary_search_finds_grid_minimum(setup, pool):
    cm, cache = setup
    for k in range(len(pool)):
        b_max = min(b_max_from_epsilon(cm, k, cache.coreset_idx, 0.01), len(cache.coreset_idx))
        grid = batch_grid(b_max)
        b_eff = ternary_search_rcu(cm, cache, k, grid)
        # compare against exhaustive scan (all probes now cached)
        vals = {int(b): rcu(cm, cache, k, int(b)) for b in grid}
        best = min(vals.values())
        # ternary search may land on a near-tie under profiling noise;
        # require within 10% of the exhaustive grid minimum
        assert vals[b_eff] <= best * 1.10 + 1e-12


def test_piecewise_fit_eq12():
    bs = np.array([1.0, 2.0, 4.0, 8.0])
    u = np.array([0.8, 0.78, 0.7, 0.4])
    f = fit_scaling("piecewise", bs, u)
    assert f(1) == pytest.approx(1.0)
    # interpolation between grid points is monotone here
    assert f(3) <= f(2) + 1e-9
    assert 0.0 <= f(8) <= 1.0


def test_powerlaw_fit_recovers_parameters():
    bs = np.arange(1, 33, dtype=float)
    alpha, beta = 0.005, 1.3    # utility stays positive over the whole grid
    u0 = 0.9
    u = u0 * (1 - alpha * (bs - 1) ** beta)
    f = fit_scaling("powerlaw", bs, u)
    assert f.alpha == pytest.approx(alpha, rel=0.15)
    assert f.beta == pytest.approx(beta, abs=0.15)
    np.testing.assert_allclose(f(bs), 1 - alpha * (bs - 1) ** beta, atol=0.02)


def test_knn_fit_query_specific(agnews):
    rngs = np.random.default_rng(0)
    m, d = 32, agnews.embed_dim
    emb = rngs.normal(size=(m, d)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    bs = np.array([1.0, 4.0, 8.0])
    table = np.clip(rngs.uniform(0.3, 1.0, size=(m, 3)), 0, 1)
    table[:, 1] = table[:, 0] * 0.9
    table[:, 2] = table[:, 0] * 0.7
    f = fit_scaling("knn", bs, table.mean(0), coreset_emb=emb, util_table=table)
    rho = f.per_query(emb[:5])
    np.testing.assert_allclose(rho(1.0), np.ones(5), atol=1e-6)
    assert np.all(rho(8.0) <= rho(4.0) + 1e-9)


def test_calibrate_model_end_to_end(setup, agnews):
    cm, cache = setup
    cal = calibrate_model(cm, cache, k=0)
    assert 1 <= cal.b_effect <= cal.b_max
    assert cal.grid[0] == 1 and cal.grid[-1] <= cal.b_effect
    assert cal.b_max <= len(cache.coreset_idx)
    rho = cal.scaling(cal.grid)
    assert rho[0] == pytest.approx(1.0)
