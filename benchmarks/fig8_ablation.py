"""Fig. 8 — ablations: full Robatch vs Router-Only vs Batch-Only (cheap /
middle / expensive model), on AGNews, GSM8K, IMDB — all as registered
policies through the shared Gateway."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save, setup_gateway


def run():
    rows = []
    t0 = time.perf_counter()
    for task in ["agnews", "gsm8k", "imdb"]:
        gw = setup_gateway(task)
        test = gw.wl.subset_indices("test")
        cm = gw.robatch.cost_model
        cheap = cm.single_model_cost(0, test, 1)
        exp = cm.single_model_cost(2, test, 1)
        budgets = np.linspace(cheap * 0.4, exp, 6)
        variants = [("Robatch", "robatch", {}),
                    ("Router-Only", "router-only", {})]
        for k, tag in [(0, "cheap"), (1, "mid"), (2, "expensive")]:
            variants.append((f"Batch-Only({tag})", "batch-only", dict(model=k)))
        for name, policy, params in variants:
            pol = gw.policy(policy, **params)
            for budget in budgets:
                plan = pol.plan(test, float(budget))
                out = pol.commit(plan)
                rows.append(dict(task=task, method=name, budget=float(budget),
                                 cost=out.exact_cost, acc=out.accuracy,
                                 infeasible=plan.schedule.infeasible))
    dt = time.perf_counter() - t0
    save("fig8_ablation", rows)
    for task in ["agnews", "gsm8k", "imdb"]:
        tr = [r for r in rows if r["task"] == task and not r["infeasible"]]
        by = lambda m: max((r["acc"] for r in tr if r["method"] == m), default=0)
        emit(f"fig8_{task}", dt / len(rows) * 1e6,
             f"robatch={by('Robatch'):.3f};router_only={by('Router-Only'):.3f};"
             f"batch_only_mid={by('Batch-Only(mid)'):.3f}")
    return rows


if __name__ == "__main__":
    run()
