"""Fig. 12 — latency breakdown of Robatch's routing stage: router prediction /
proxy-utility computation / greedy scheduling."""
from __future__ import annotations


from benchmarks.common import emit, save, setup


def run():
    rows = []
    for task in ["agnews", "imdb", "mmlu"]:
        wl, pool, rb = setup(task)
        test = wl.subset_indices("test")
        cm = rb.cost_model
        for level, budget in [("low", cm.single_model_cost(0, test, 1)),
                              ("mid", cm.single_model_cost(1, test, 1)),
                              ("high", cm.single_model_cost(2, test, 1))]:
            _, t = rb.schedule_timed(test, budget)
            total = max(t["total"], 1e-12)
            rows.append(dict(task=task, level=level,
                             router_pct=100 * t["router"] / total,
                             proxy_pct=100 * t["proxy"] / total,
                             greedy_pct=100 * t["greedy"] / total,
                             total_s=t["total"]))
        mid = next(r for r in rows if r["task"] == task and r["level"] == "mid")
        emit(f"fig12_{task}", mid["total_s"] * 1e6 / len(test),
             f"greedy={mid['greedy_pct']:.0f}%;proxy={mid['proxy_pct']:.0f}%;"
             f"router={mid['router_pct']:.0f}%")
    save("fig12_breakdown", rows)
    return rows


if __name__ == "__main__":
    run()
