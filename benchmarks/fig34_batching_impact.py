"""Fig. 3/4 — the impact of batch size on accuracy and cost composition.

Sweeps b from 1 to 64 per pool model; reports avg accuracy and the
system-prompt share of total cost (paper: 59.5% → 8.4% on AGNews b=1→16,
90.1% → 53.2% on GSM8K b=1→8)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save, setup
from repro.core import execute
from repro.core.baselines import single_model_assignment


def run():
    rows = []
    t0 = time.perf_counter()
    for task in ["agnews", "gsm8k"]:
        wl, pool, rb = setup(task)
        test = wl.subset_indices("test")
        cm = rb.cost_model
        for k, m in enumerate(pool):
            for b in [1, 2, 4, 8, 16, 24, 32, 48, 64]:
                out = execute(pool, wl, single_model_assignment(test, k, b))
                n_inv = int(np.ceil(len(test) / b))
                sys_cost = n_inv * cm.sys_cost(k)
                share = sys_cost / max(out.exact_cost, 1e-12)
                rows.append(dict(task=task, model=m.name, b=b, acc=out.accuracy,
                                 cost=out.exact_cost, sys_share=share))
    dt = time.perf_counter() - t0
    save("fig34_batching_impact", rows)
    for task in ["agnews", "gsm8k"]:
        tr = [r for r in rows if r["task"] == task and r["model"].endswith("4b")]
        b1 = next(r for r in tr if r["b"] == 1)
        bk = next(r for r in tr if r["b"] == (16 if task == "agnews" else 8))
        drop = next((r["b"] for r in tr if r["acc"] < 0.5 * b1["acc"]), ">64")
        emit(f"fig34_{task}_4b", dt / len(rows) * 1e6,
             f"sys_share_b1={b1['sys_share']:.2f};sys_share_amortized={bk['sys_share']:.2f};"
             f"collapse_b={drop}")
    return rows


if __name__ == "__main__":
    run()
