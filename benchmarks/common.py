"""Shared benchmark infrastructure: cached fitted gateways, budget levels,
CSV emission (`name,us_per_call,derived`).

Experiments are declared as :class:`repro.api.RunSpec`s and fitted through
the :class:`repro.api.Gateway`; ``setup`` keeps its legacy
``(wl, pool, rb)`` return shape for the figure scripts that still drive
``Robatch`` directly."""
from __future__ import annotations

import functools
import json
import os
import time

from repro.api import Gateway, PoolSpec, RunSpec
from repro.core import Robatch

RESULTS_DIR = os.environ.get("BENCH_RESULTS", "results/bench")
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
# schema of the shared BENCH_online.json gate file — bumped together by
# every writer (online_throughput.py, engine_decode.py, http_serving.py AND
# robustness.py merge into the same file; a per-script constant would make
# the schema order-dependent)
BENCH_SCHEMA = 8          # 8: robustness legs (per-member autoscale events,
#                              robust-λ sweep, hung-replica failover)


@functools.lru_cache(maxsize=32)
def setup_gateway(task: str, family: str = "qwen3", router: str = "mlp",
                  coreset: str = "kcenter", coreset_size: int = 256,
                  scaling_fit: str = "piecewise", seed: int = 0) -> Gateway:
    """Fitted Gateway over the simulated pool (cached across benchmarks);
    every policy requested from it shares one modeling stage."""
    n_train, n_val, n_test = (512, 128, 256) if QUICK else (2048, 512, 1024)
    spec = RunSpec(
        pool=PoolSpec(task=task, family=family, n_train=n_train, n_val=n_val,
                      n_test=n_test, seed=seed),
        router=router, coreset_method=coreset, coreset_size=coreset_size,
        scaling_fit=scaling_fit, seed=seed)
    return Gateway.from_spec(spec).fit()


def setup(task: str, family: str = "qwen3", router: str = "mlp",
          coreset: str = "kcenter", coreset_size: int = 256,
          scaling_fit: str = "piecewise", seed: int = 0):
    """Workload + pool + fitted Robatch (legacy shape, same cached gateway)."""
    gw = setup_gateway(task, family=family, router=router, coreset=coreset,
                       coreset_size=coreset_size, scaling_fit=scaling_fit,
                       seed=seed)
    return gw.wl, gw.pool, gw.robatch


def fixed_b_cost_levels(rb: Robatch, test_idx, bs=(16, 8, 4, 1)):
    """§6.2 protocol: each baseline fixed batch size defines a budget level
    (cost of the mid model at that batch size spans the realistic range)."""
    cm = rb.cost_model
    return {b: cm.single_model_cost(1, test_idx, b) for b in bs}


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def save(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
