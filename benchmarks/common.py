"""Shared benchmark infrastructure: cached fitted pipelines, budget levels,
CSV emission (`name,us_per_call,derived`)."""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

from repro.core import CostModel, Robatch, execute
from repro.data import make_simulated_pool, make_workload

RESULTS_DIR = os.environ.get("BENCH_RESULTS", "results/bench")
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


@functools.lru_cache(maxsize=32)
def setup(task: str, family: str = "qwen3", router: str = "mlp",
          coreset: str = "kcenter", coreset_size: int = 256,
          scaling_fit: str = "piecewise", seed: int = 0):
    """Workload + pool + fitted Robatch (cached across benchmarks)."""
    n_train, n_val, n_test = (512, 128, 256) if QUICK else (2048, 512, 1024)
    wl = make_workload(task, n_train=n_train, n_val=n_val, n_test=n_test, seed=seed)
    pool = make_simulated_pool(family)
    rb = Robatch(pool, wl, router_kind=router, coreset_method=coreset,
                 coreset_size=min(coreset_size, n_train // 2),
                 scaling_fit=scaling_fit, seed=seed).fit()
    return wl, pool, rb


def fixed_b_cost_levels(rb: Robatch, test_idx, bs=(16, 8, 4, 1)):
    """§6.2 protocol: each baseline fixed batch size defines a budget level
    (cost of the mid model at that batch size spans the realistic range)."""
    cm = rb.cost_model
    return {b: cm.single_model_cost(1, test_idx, b) for b in bs}


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def save(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)
