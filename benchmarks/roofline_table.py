"""Roofline table from the dry-run artifacts (results/dryrun.json) — the
§Roofline deliverable rendered as CSV lines."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, save


def run(path: str = "results/dryrun.json"):
    if os.path.exists("results/dryrun_final.json"):
        path = "results/dryrun_final.json"
    if not os.path.exists(path):
        emit("roofline", 0.0, f"missing={path};run_repro.launch.dryrun_first")
        return []
    rows = json.load(open(path))
    for r in rows:
        if r["status"] != "ok":
            emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0, r["status"])
            continue
        rf = r["roofline"]
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             rf["compute_s"] * 1e6,
             f"dominant={rf['dominant']};compute={rf['compute_s']:.3f}s;"
             f"mem={rf['memory_s']:.3f}s;coll={rf['collective_s']:.3f}s;"
             f"useful={r.get('useful_ratio') and round(r['useful_ratio'], 2)};"
             f"peak={r['mem']['peak_tpu_est_GB']:.1f}GB")
    save("roofline_table", rows)
    return rows


if __name__ == "__main__":
    run()
