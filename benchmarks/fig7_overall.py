"""Fig. 7 — overall cost-accuracy trade-off on six benchmarks × two families.

Protocol (§6.2): baselines run at fixed b ∈ {16, 8, 4, 1} (four cost levels);
Robatch is given the min and max actual baseline cost at each level as
budgets.  The x-axis is actual spent cost.

Every method is a registered policy invoked through the shared
:class:`repro.api.Gateway`, so the whole figure reuses one modeling stage per
(task, family) and adding a strategy to the comparison is one
``(name, params)`` row below."""
from __future__ import annotations

import time

from benchmarks.common import QUICK, emit, save, setup_gateway

TASKS = ["agnews", "gsm8k", "mmlu", "snli", "mrpc", "imdb"]
FAMILIES = ["qwen3", "gemma3"]

# display name -> registry name; every baseline runs at (tau=0.5, b=level)
BASELINES = [
    ("RouteLLM", "routellm"),
    ("FrugalGPT", "frugalgpt"),
    ("BATCHER-SIM", "batcher-sim"),
    ("BATCHER-DIV", "batcher-div"),
    ("OBP", "obp"),
]


def run(tasks=None, families=None):
    tasks = tasks or (TASKS[:2] if QUICK else TASKS)
    families = families or (FAMILIES[:1] if QUICK else FAMILIES)
    rows = []
    t0 = time.perf_counter()
    for family in families:
        for task in tasks:
            gw = setup_gateway(task, family=family)
            test = gw.wl.subset_indices("test")
            for b in [16, 8, 4, 1]:
                level_costs = []
                for method, name in BASELINES:
                    out = gw.submit(test, policy=name, tau=0.5, b=b)
                    rows.append(dict(family=family, task=task, method=method,
                                     level=b, cost=out.exact_cost, acc=out.accuracy))
                    level_costs.append(out.exact_cost)
                # Robatch at the level's min and max actual cost as budgets
                for tag, budget in [("min", min(level_costs)), ("max", max(level_costs))]:
                    out = gw.submit(test, budget=budget, policy="robatch")
                    rows.append(dict(family=family, task=task, method=f"Robatch-{tag}",
                                     level=b, cost=out.exact_cost, acc=out.accuracy))
    dt = time.perf_counter() - t0
    save("fig7_overall", rows)
    # headline: fraction of (task, level) cells where Robatch-max dominates all baselines
    wins = total = 0
    for family in families:
        for task in tasks:
            for level in [16, 8, 4, 1]:
                cell = [r for r in rows if r["family"] == family and r["task"] == task
                        and r["level"] == level]
                ours = [r for r in cell if r["method"].startswith("Robatch")]
                base = [r for r in cell if not r["method"].startswith("Robatch")]
                for o in ours:
                    total += 1
                    if all(o["acc"] >= b_["acc"] - 1e-9 or o["cost"] < b_["cost"] * 0.98
                           for b_ in base):
                        wins += 1
    emit("fig7_overall", dt / max(len(rows), 1) * 1e6,
         f"robatch_non_dominated={wins}/{total};rows={len(rows)}")
    return rows


if __name__ == "__main__":
    run()
