"""Fig. 7 — overall cost-accuracy trade-off on six benchmarks × two families.

Protocol (§6.2): baselines run at fixed b ∈ {16, 8, 4, 1} (four cost levels);
Robatch is given the min and max actual baseline cost at each level as
budgets.  The x-axis is actual spent cost."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, emit, save, setup
from repro.core import execute, execute_plan
from repro.core.baselines import (
    batcher_assignment_plan, frugalgpt_execute, obp_plan, routellm_assignment,
)

TASKS = ["agnews", "gsm8k", "mmlu", "snli", "mrpc", "imdb"]
FAMILIES = ["qwen3", "gemma3"]


def run(tasks=None, families=None):
    tasks = tasks or (TASKS[:2] if QUICK else TASKS)
    families = families or (FAMILIES[:1] if QUICK else FAMILIES)
    rows = []
    t0 = time.perf_counter()
    for family in families:
        for task in tasks:
            wl, pool, rb = setup(task, family=family)
            test = wl.subset_indices("test")
            for b in [16, 8, 4, 1]:
                level_costs = []
                # RouteLLM: threshold mid-sweep at this batch size
                for tau in [0.5]:
                    out = execute(pool, wl, routellm_assignment(rb, test, tau=tau, b=b))
                    rows.append(dict(family=family, task=task, method="RouteLLM",
                                     level=b, cost=out.exact_cost, acc=out.accuracy))
                    level_costs.append(out.exact_cost)
                out = frugalgpt_execute(rb, test, tau=0.5, b=b)
                rows.append(dict(family=family, task=task, method="FrugalGPT",
                                 level=b, cost=out.exact_cost, acc=out.accuracy))
                level_costs.append(out.exact_cost)
                for mode, name in [("sim", "BATCHER-SIM"), ("div", "BATCHER-DIV")]:
                    _, plan = batcher_assignment_plan(rb, test, tau=0.5, b=b, mode=mode)
                    out = execute_plan(pool, wl, plan, test)
                    rows.append(dict(family=family, task=task, method=name,
                                     level=b, cost=out.exact_cost, acc=out.accuracy))
                    level_costs.append(out.exact_cost)
                _, plan = obp_plan(rb, test, tau=0.5, target_b=b)
                out = execute_plan(pool, wl, plan, test)
                rows.append(dict(family=family, task=task, method="OBP",
                                 level=b, cost=out.exact_cost, acc=out.accuracy))
                level_costs.append(out.exact_cost)
                # Robatch at the level's min and max actual cost as budgets
                for tag, budget in [("min", min(level_costs)), ("max", max(level_costs))]:
                    res = rb.schedule(test, budget)
                    out = execute(pool, wl, res.assignment)
                    rows.append(dict(family=family, task=task, method=f"Robatch-{tag}",
                                     level=b, cost=out.exact_cost, acc=out.accuracy))
    dt = time.perf_counter() - t0
    save("fig7_overall", rows)
    # headline: fraction of (task, level) cells where Robatch-max dominates all baselines
    wins = total = 0
    for family in families:
        for task in tasks:
            for level in [16, 8, 4, 1]:
                cell = [r for r in rows if r["family"] == family and r["task"] == task
                        and r["level"] == level]
                ours = [r for r in cell if r["method"].startswith("Robatch")]
                base = [r for r in cell if not r["method"].startswith("Robatch")]
                for o in ours:
                    total += 1
                    if all(o["acc"] >= b_["acc"] - 1e-9 or o["cost"] < b_["cost"] * 0.98
                           for b_ in base):
                        wins += 1
    emit("fig7_overall", dt / max(len(rows), 1) * 1e6,
         f"robatch_non_dominated={wins}/{total};rows={len(rows)}")
    return rows


if __name__ == "__main__":
    run()
