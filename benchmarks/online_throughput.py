"""Online serving throughput — sustained QPS, p50/p99 latency and realized
cost vs. the rolling budget, swept over admission window sizes AND replica
counts, plus graceful degradation under two scripted outages: a whole-member
failure (circuit breaker trips, traffic reroutes) and a single-replica
failure inside a ReplicaSet (the set degrades instead of breaking).

Two capacity legs ride on the replica machinery: ``cap_mode_compare`` pits
the capacity-aware Δ-heap (pack over-cap members into fewer, larger batches)
against the legacy ``_apply_group_caps`` post-pass on the R=1 stream — the
Δ-heap must defer strictly fewer queries — and ``autoscale`` drives a
warm→burst→drain load ramp through the backlog-driven Autoscaler (replicas
rise with the burst, drain back after it, and hold less work to capacity
than a fixed R=1 pool).

Default pool is the REAL trained tiny pool (``repro.serving.tinypool``, the
``src/repro/configs/tiny_pool.py`` architectures served by the
continuous-batching engine); ``BENCH_QUICK=1`` or ``--pool sim`` swaps in the
calibrated simulator for a fast pass.  Latencies are virtual-stream seconds
(queueing + measured/simulated service time); the wall-clock per-request cost
of the control plane is emitted as ``us_per_call``.

This benchmark measures the SERVING PLANE — sustained QPS, latency
percentiles, budget adherence, fault handling.  On the tiny pool the measured
utilities are near the task's chance floor at smoke step counts (see
``repro.serving.tinypool``); use ``--pool sim`` for utility-sensitive
comparisons.

Besides the usual per-row CSV/JSON, the run writes a stable-schema
``BENCH_online.json`` (next to the other results) that ``tools/bench_check.py``
compares against the committed baseline in ``benchmarks/baselines/`` — the CI
regression gate.

    PYTHONPATH=src python benchmarks/online_throughput.py [--pool sim]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import BENCH_SCHEMA, QUICK, RESULTS_DIR, emit, save, setup
from repro.core import Robatch
from repro.serving.autoscale import AutoscalePolicy
from repro.serving.fault import BreakerPolicy, FlakyMember
from repro.serving.online import OnlineConfig, OnlineRobatchServer, poisson_arrivals
from repro.serving.pool import ReplicaSet, replicate_simulated
from repro.serving.tinypool import replica_factory

WINDOWS = (0.25, 0.5, 1.0, 2.0)


def _build(pool_kind: str, steps: int, seed: int, max_replicas: int):
    """(wl, pool, rb, make_pool): ``make_pool(R)`` yields an R-replica view of
    the same engines — simulated members are copied (deterministic-identical),
    tiny engines are built once at ``max_replicas`` and sliced, so a sweep
    never retrains."""
    if pool_kind == "sim":
        wl, pool, rb = setup("agnews", router="knn", coreset_size=64, seed=seed)

        def make_pool(r: int) -> list:
            return [replicate_simulated(m, r) for m in pool]

        return wl, pool, rb, make_pool
    from repro.serving.tinypool import build_tiny_pool

    rng = np.random.default_rng(seed)
    wl, sets, _fmt = build_tiny_pool(rng, steps=steps, n_train=48, n_test=64,
                                     replicas=max_replicas)
    rb = Robatch(sets, wl, coreset_size=16, router_kind="knn", grid_multiple=2).fit()
    pool = [rs.replicas[0] for rs in sets]          # plain single-engine view

    def make_pool(r: int) -> list:
        return [ReplicaSet(rs.replicas[:r], name=rs.name,
                           factory=replica_factory(rs.replicas[0]))
                for rs in sets]

    return wl, pool, rb, make_pool


def _stream(rb, pool, wl, *, window_s, qps, duration, budget_x, seed,
            policy=None, autoscale=None, arrivals=None, drain_ticks=0,
            semcache=None):
    test = wl.subset_indices("test")
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect, test).mean())
    rate = qps * base * budget_x
    cfg = OnlineConfig(budget_per_s=rate, window_s=window_s,
                       breaker=BreakerPolicy(failure_threshold=1, recovery_time_s=1e9),
                       autoscale=autoscale, semantic_cache=semcache)
    srv = OnlineRobatchServer(policy if policy is not None else rb, pool, wl, cfg)
    if arrivals is None:
        arrivals = poisson_arrivals(np.random.default_rng(seed), qps, duration,
                                    test, repeat_frac=0.2)
    t0 = time.perf_counter()
    stats = srv.run(arrivals)
    for _ in range(drain_ticks):     # idle windows so scale-down can complete
        srv.step()
    wall = time.perf_counter() - t0
    srv.close()
    return srv, stats, wall, len(arrivals)


def _neardup_arrivals(rng, qps, duration, test, emb, nn_frac):
    """Seeded Poisson stream where a ``nn_frac`` fraction of arrivals asks the
    nearest *neighbor* (not a repeat) of a previously-arrived query — the
    exact-match cache cannot touch those, so semantic-cache hits in the sweep
    come only from embedding-space similarity."""
    sims = emb[test] @ emb[test].T
    np.fill_diagonal(sims, -np.inf)
    nn = np.argmax(sims, axis=1)                  # positions within `test`
    pos_of = {int(q): p for p, q in enumerate(test)}
    out, seen, t = [], [], 0.0
    while True:
        t += float(rng.exponential(1.0 / qps))
        if t >= duration:
            return out
        if seen and float(rng.random()) < nn_frac:
            q = int(test[nn[pos_of[seen[int(rng.integers(0, len(seen)))]]]])
        else:
            q = int(test[int(rng.integers(0, len(test)))])
            seen.append(q)
        out.append((t, q))


def _ramp_arrivals(rng, test, phases):
    """Deterministic load ramp: evenly spaced arrivals per (qps, t0, t1)
    phase, query ids drawn from ``test`` — the autoscale leg's workload."""
    out = []
    for qps, t0, t1 in phases:
        n = int(round(qps * (t1 - t0)))
        ts = t0 + (np.arange(n) + 0.5) * (t1 - t0) / max(1, n)
        qs = test[rng.integers(0, len(test), size=n)]
        out.extend((float(t), int(q)) for t, q in zip(ts, qs))
    out.sort(key=lambda a: a[0])
    return out


def run(pool_kind: str | None = None, steps: int = 200, qps: float = 6.0,
        duration: float = 20.0, budget_x: float = 3.0, seed: int = 0):
    pool_kind = pool_kind or ("sim" if QUICK else "tiny")
    replica_counts = (1, 2) if pool_kind == "tiny" else (1, 2, 4)
    # capacity only binds when the schedule wants many concurrent groups:
    # drive the replica legs harder (more arrivals per window, enough budget
    # to upgrade toward small batches) than the window-size sweep
    r_qps, r_budget_x = qps * 4, budget_x * 4
    wl, pool, rb, make_pool = _build(pool_kind, steps, seed, max(replica_counts))
    rows = []
    ramp_hi = r_qps * 2
    ramp_phases = ((qps, 0.0, 4.0), (ramp_hi, 4.0, 12.0), (qps, 12.0, 20.0))
    max_r = max(replica_counts)
    bench = {"schema": BENCH_SCHEMA,
             "config": dict(pool=pool_kind, qps=qps, duration=duration,
                            budget_x=budget_x, seed=seed, windows=list(WINDOWS),
                            replica_counts=list(replica_counts),
                            replica_qps=r_qps, replica_budget_x=r_budget_x,
                            ramp_hi=ramp_hi, autoscale_max=max_r),
             "window_sweep": [], "replica_sweep": [], "cap_mode_compare": {},
             "autoscale": [], "breaker_outage": {}, "replica_outage": {},
             "semcache_sweep": []}

    # ---- window-size sweep --------------------------------------------------
    usage = np.zeros(len(pool), dtype=int)
    for w in WINDOWS:
        srv, stats, wall, n_arr = _stream(rb, pool, wl, window_s=w, qps=qps,
                                          duration=duration, budget_x=budget_x,
                                          seed=seed)
        for r in srv.completed:
            if r.model is not None and not r.cache_hit:
                usage[r.model] += 1
        row = dict(pool=pool_kind, window_s=w, offered_qps=qps,
                   sustained_qps=stats.qps, p50_s=stats.latency_p50,
                   p99_s=stats.latency_p99, mean_utility=stats.mean_utility,
                   cost=stats.total_cost, budget_allowance=stats.budget_allowance,
                   cache_hits=stats.n_cache_hits, dropped=stats.n_dropped,
                   deferred=int(sum(x.n_deferred for x in stats.windows)),
                   wall_s=wall)
        rows.append(row)
        bench["window_sweep"].append({k: row[k] for k in (
            "window_s", "sustained_qps", "p50_s", "p99_s", "mean_utility",
            "cost", "budget_allowance", "cache_hits", "dropped", "deferred")})
        emit(f"online_w{w}", wall / max(1, n_arr) * 1e6,
             f"qps={stats.qps:.1f};p50={stats.latency_p50:.2f}s;"
             f"p99={stats.latency_p99:.2f}s;cost=${stats.total_cost:.5f}"
             f"/${stats.budget_allowance:.5f};util={stats.mean_utility:.3f}")

    # ---- replica sweep: QPS/p99 vs. replica count ---------------------------
    # every member is an R-replica set; per-window capacity caps (R groups per
    # member) are what the scheduler plans against, so throughput scales with
    # R until the budget — not capacity — is the binding constraint
    cap_deferred_by_r = {}
    for r_count in replica_counts:
        srv, stats, wall, n_arr = _stream(rb, make_pool(r_count), wl,
                                          window_s=WINDOWS[1], qps=r_qps,
                                          duration=duration, budget_x=r_budget_x,
                                          seed=seed)
        cap_deferred = int(sum(w.n_capacity_held for w in stats.windows))
        cap_packed = int(sum(w.n_cap_packed for w in stats.windows))
        cap_deferred_by_r[r_count] = cap_deferred
        row = dict(pool=pool_kind, scenario="replica_sweep", replicas=r_count,
                   window_s=WINDOWS[1], offered_qps=r_qps,
                   sustained_qps=stats.qps, p50_s=stats.latency_p50,
                   p99_s=stats.latency_p99, cost=stats.total_cost,
                   capacity_deferred=cap_deferred, capacity_packed=cap_packed,
                   completed=stats.n_completed, dropped=stats.n_dropped,
                   wall_s=wall)
        rows.append(row)
        bench["replica_sweep"].append({k: row[k] for k in (
            "replicas", "sustained_qps", "p50_s", "p99_s", "cost",
            "capacity_deferred", "capacity_packed", "completed", "dropped")})
        emit(f"online_replicas{r_count}", wall / max(1, n_arr) * 1e6,
             f"qps={stats.qps:.1f};p99={stats.latency_p99:.2f}s;"
             f"cap_deferred={cap_deferred};cap_packed={cap_packed};"
             f"dropped={stats.n_dropped}")
        assert stats.n_completed == stats.n_submitted, "replica run lost queries"
    assert cap_deferred_by_r[replica_counts[0]] >= cap_deferred_by_r[replica_counts[-1]], \
        "more replicas should not defer more work to capacity"

    # ---- capacity-aware Δ-heap vs. legacy post-pass on the R=1 sweep --------
    # same stream, same caps: cap_mode="defer" (the _apply_group_caps safety
    # net) holds whole over-cap groups; cap_mode="pack" (the default) re-packs
    # them into fewer, larger batches and must defer strictly less
    from repro.api.policies import RobatchPolicy

    defer_pol = RobatchPolicy(cap_mode="defer").fit(pool, wl, artifacts=rb)
    srv_d, stats_d, wall_d, n_arr = _stream(rb, make_pool(1), wl,
                                            window_s=WINDOWS[1], qps=r_qps,
                                            duration=duration,
                                            budget_x=r_budget_x, seed=seed,
                                            policy=defer_pol)
    defer_held = int(sum(w.n_capacity_held for w in stats_d.windows))
    pack_held = cap_deferred_by_r[1]
    bench["cap_mode_compare"] = dict(
        pack_held=pack_held, defer_held=defer_held,
        pack_packed=int(bench["replica_sweep"][0]["capacity_packed"]),
        pack_qps=bench["replica_sweep"][0]["sustained_qps"],
        defer_qps=stats_d.qps, defer_p99_s=stats_d.latency_p99,
        completed=stats_d.n_completed, dropped=stats_d.n_dropped)
    emit("online_capmode", wall_d / max(1, n_arr) * 1e6,
         f"pack_held={pack_held};defer_held={defer_held};"
         f"pack_qps={bench['replica_sweep'][0]['sustained_qps']:.1f};"
         f"defer_qps={stats_d.qps:.1f}")
    assert stats_d.n_completed == stats_d.n_submitted, "defer run lost queries"
    assert defer_held > 0, "R=1 post-pass run never hit its capacity caps"
    assert pack_held < defer_held, \
        "capacity-aware Δ-heap must defer strictly fewer queries than the post-pass"

    # ---- autoscale leg: load ramp, pool sized by backlog --------------------
    # a warm->burst->drain ramp against (a) a fixed R=1 pool and (b) the same
    # pool under the Autoscaler: replicas must rise with the burst's backlog,
    # drain back down after it, and hold less work to capacity than fixed R=1
    test_idx = wl.subset_indices("test")
    ramp = _ramp_arrivals(np.random.default_rng(seed + 1), test_idx, ramp_phases)
    srv_f, stats_f, wall_f, _ = _stream(rb, make_pool(1), wl,
                                        window_s=WINDOWS[1], qps=ramp_hi,
                                        duration=duration, budget_x=r_budget_x,
                                        seed=seed, arrivals=ramp, drain_ticks=16)
    fixed_pressure = int(sum(w.n_capacity_held + w.n_cap_packed
                             for w in srv_f.windows))
    as_policy = AutoscalePolicy(min_replicas=1, max_replicas=max_r,
                                up_pressure=4, down_pressure=0,
                                up_queue_depth=24, down_queue_depth=4,
                                hold_windows=2, cooldown_s=1.0)
    srv_a, stats_a, wall_a, n_arr = _stream(rb, make_pool(1), wl,
                                            window_s=WINDOWS[1], qps=ramp_hi,
                                            duration=duration,
                                            budget_x=r_budget_x, seed=seed,
                                            arrivals=ramp, autoscale=as_policy,
                                            drain_ticks=16)
    phase_names = ("warm", "burst", "drain")
    bounds = [(t0, t1) for _q, t0, t1 in ramp_phases]
    bounds[-1] = (bounds[-1][0], float("inf"))      # drain includes idle ticks
    peak = 1
    for name, (t0, t1) in zip(phase_names, bounds):
        ws = [w for w in srv_a.windows if t0 < w.t <= t1]
        held = int(sum(w.n_capacity_held for w in ws))
        packed = int(sum(w.n_cap_packed for w in ws))
        max_rep = max((max(w.replica_counts) for w in ws if w.replica_counts),
                      default=1)
        end_rep = max(ws[-1].replica_counts) if ws and ws[-1].replica_counts else 1
        peak = max(peak, max_rep)
        row = dict(pool=pool_kind, scenario="autoscale", phase=name,
                   window_s=WINDOWS[1], capacity_held=held, cap_packed=packed,
                   max_replicas=max_rep, end_replicas=end_rep,
                   n_windows=len(ws))
        rows.append(row)
        bench["autoscale"].append({k: row[k] for k in (
            "phase", "capacity_held", "cap_packed", "max_replicas",
            "end_replicas")})
    auto_pressure = int(sum(w.n_capacity_held + w.n_cap_packed
                            for w in srv_a.windows))
    n_events = len(srv_a.autoscaler.events)
    summary = dict(phase="summary", fixed_pressure=fixed_pressure,
                   auto_pressure=auto_pressure, n_scale_events=n_events,
                   sustained_qps=stats_a.qps, p99_s=stats_a.latency_p99,
                   fixed_p99_s=stats_f.latency_p99, cost=stats_a.total_cost,
                   completed=stats_a.n_completed, dropped=stats_a.n_dropped)
    rows.append(dict(pool=pool_kind, scenario="autoscale", window_s=WINDOWS[1],
                     wall_s=wall_f + wall_a, **summary))
    bench["autoscale"].append(summary)
    emit("online_autoscale", (wall_f + wall_a) / max(1, n_arr) * 1e6,
         f"peak_replicas={peak};events={n_events};"
         f"pressure={auto_pressure}vs{fixed_pressure};"
         f"p99={stats_a.latency_p99:.2f}s_vs_{stats_f.latency_p99:.2f}s")
    assert stats_a.n_completed == stats_a.n_submitted, "autoscale run lost queries"
    assert peak > 1, "the burst never grew the pool"
    end_replicas = max(srv_a.windows[-1].replica_counts)
    assert end_replicas < peak, "the pool never drained back down"
    assert auto_pressure < fixed_pressure, \
        "autoscaling must hold less work to capacity than the fixed R=1 pool"

    # ---- mid-run outage A: whole member fails, breaker trips ----------------
    # fail the member the scheduler actually leans on (the budget level decides
    # whether that is the cheap anchor — which exercises re-anchoring — or an
    # upgraded model), tripping early enough that short streams reach it
    flaky_k = int(np.argmax(usage))
    pool_f = [FlakyMember(m, fail_from=3) if k == flaky_k else m
              for k, m in enumerate(pool)]
    srv, stats, wall, n_arr = _stream(rb, pool_f, wl, window_s=WINDOWS[1],
                                      qps=qps, duration=duration,
                                      budget_x=budget_x, seed=seed)
    tripped = srv.breakers[flaky_k].n_trips > 0
    survivors = sorted({r.model for r in srv.completed
                        if r.model is not None and r.model != flaky_k})
    row = dict(pool=pool_kind, window_s=WINDOWS[1], scenario="breaker_trip",
               tripped=bool(tripped), reroutes=stats.n_reroutes,
               dropped=stats.n_dropped, completed=stats.n_completed,
               submitted=stats.n_submitted, survivors=survivors,
               sustained_qps=stats.qps, p99_s=stats.latency_p99,
               cost=stats.total_cost, mean_utility=stats.mean_utility)
    rows.append(row)
    bench["breaker_outage"] = {k: row[k] for k in (
        "tripped", "reroutes", "dropped", "completed", "submitted",
        "sustained_qps", "p99_s", "cost")}
    emit("online_breaker_trip", wall / max(1, n_arr) * 1e6,
         f"tripped={tripped};reroutes={stats.n_reroutes};"
         f"dropped={stats.n_dropped};completed={stats.n_completed}"
         f"/{stats.n_submitted};util={stats.mean_utility:.3f}")
    assert stats.n_completed == stats.n_submitted, "online layer lost queries"
    assert tripped and stats.n_reroutes > 0, "outage did not exercise rerouting"

    # ---- mid-run outage B: ONE replica fails inside a ReplicaSet ------------
    # the set retries the sibling replica and ejects the dead one, so the
    # member's breaker must stay CLOSED and QPS degrade (capacity shrinks to
    # the healthy-replica count) instead of the member disappearing
    r_outage = replica_counts[-1] if pool_kind == "sim" else 2
    pool_o = make_pool(r_outage)
    pool_o[flaky_k].replicas[0] = FlakyMember(pool_o[flaky_k].replicas[0],
                                              fail_from=3)
    srv, stats, wall, n_arr = _stream(rb, pool_o, wl, window_s=WINDOWS[1],
                                      qps=r_qps, duration=duration,
                                      budget_x=r_budget_x, seed=seed)
    tracker = pool_o[flaky_k].tracker
    row = dict(pool=pool_kind, window_s=WINDOWS[1], scenario="replica_outage",
               replicas=r_outage, member=pool_o[flaky_k].name,
               breaker_tripped=srv.breakers[flaky_k].n_trips > 0,
               replica_failures=tracker.replicas[0].n_failures,
               replica_ejections=tracker.replicas[0].n_ejections,
               healthy_replicas=tracker.n_healthy(),
               sustained_qps=stats.qps, p99_s=stats.latency_p99,
               completed=stats.n_completed, submitted=stats.n_submitted,
               dropped=stats.n_dropped, cost=stats.total_cost)
    rows.append(row)
    bench["replica_outage"] = {k: row[k] for k in (
        "replicas", "breaker_tripped", "replica_failures", "replica_ejections",
        "sustained_qps", "p99_s", "dropped", "completed", "submitted")}
    emit("online_replica_outage", wall / max(1, n_arr) * 1e6,
         f"breaker_tripped={row['breaker_tripped']};"
         f"replica_failures={row['replica_failures']};"
         f"qps={stats.qps:.1f};completed={stats.n_completed}/{stats.n_submitted}")
    assert stats.n_completed == stats.n_submitted, "replica outage lost queries"
    assert stats.qps > 0, "replica outage must degrade, not zero out, throughput"
    assert not row["breaker_tripped"], \
        "a single-replica outage must not trip the member's breaker"
    assert row["replica_failures"] > 0, "outage did not reach the flaky replica"

    # ---- semantic-cache threshold sweep: hit-rate vs. utility-loss vs. cost -
    # a near-duplicate stream (exact repeats excluded by construction) swept
    # over cosine thresholds drawn from the test set's NN-similarity
    # distribution; the off (no cache) run anchors cost-saved, and the
    # threshold=inf run must be bit-identical to it (the wired server with an
    # impossible threshold IS the cache-less server)
    from repro.serving.semcache import SemanticCacheConfig

    emb = wl.embeddings
    nn_frac = 0.5
    sem_arrivals = _neardup_arrivals(np.random.default_rng(seed + 2), qps,
                                     duration, test_idx, emb, nn_frac)
    sims = emb[test_idx] @ emb[test_idx].T
    np.fill_diagonal(sims, -np.inf)
    nn_best = sims.max(axis=1)
    sem_thresholds = [round(float(np.quantile(nn_best, q)), 4)
                      for q in (0.10, 0.50, 0.90)]
    bench["config"]["semcache"] = dict(thresholds=sem_thresholds,
                                       nn_frac=nn_frac)
    base_record, base_cost = None, 0.0
    for tau in [None] + sem_thresholds + [float("inf")]:
        sc = (None if tau is None
              else SemanticCacheConfig(sim_threshold=float(tau)))
        srv, stats, wall, n_arr = _stream(rb, pool, wl, window_s=WINDOWS[0],
                                          qps=qps, duration=duration,
                                          budget_x=budget_x, seed=seed,
                                          arrivals=sem_arrivals, semcache=sc)
        record = [(r.rid, r.query_idx, round(r.completed_at, 9), r.model,
                   round(float(r.utility or 0.0), 9), round(r.cost, 12))
                  for r in srv.completed]
        if tau is None:
            base_record, base_cost = record, stats.total_cost
        scs = srv.semcache.stats() if srv.semcache is not None else {}
        hits, sem_misses = int(scs.get("hits", 0)), int(scs.get("misses", 0))
        label = "off" if tau is None else f"{tau:g}"
        row = dict(pool=pool_kind, scenario="semcache", window_s=WINDOWS[0],
                   sim_threshold=None if tau is None else float(tau),
                   sem_hits=hits, sem_misses=sem_misses,
                   sem_insertions=int(scs.get("insertions", 0)),
                   hit_rate=hits / max(1, hits + sem_misses),
                   utility_loss=float(stats.sem_utility_loss),
                   eps_bound=(float(srv.semcache.eps_model(float(tau)))
                              if sc is not None else 0.0),
                   mean_utility=stats.mean_utility, cost=stats.total_cost,
                   cost_saved=base_cost - stats.total_cost,
                   off_identical=bool(record == base_record), wall_s=wall)
        rows.append(row)
        bench["semcache_sweep"].append({k: row[k] for k in (
            "sim_threshold", "sem_hits", "sem_misses", "sem_insertions",
            "hit_rate", "utility_loss", "eps_bound", "mean_utility", "cost",
            "cost_saved", "off_identical")})
        emit(f"online_semcache_{label}", wall / max(1, n_arr) * 1e6,
             f"hits={hits};hit_rate={row['hit_rate']:.3f};"
             f"loss={row['utility_loss']:.3f};cost=${stats.total_cost:.5f};"
             f"saved=${row['cost_saved']:.5f};util={stats.mean_utility:.3f}")
        assert stats.n_completed == stats.n_submitted, "semcache run lost queries"
        if tau is not None and np.isfinite(tau):
            assert hits > 0, f"near-dup stream produced no hits at tau={tau}"
            # every hit's accounted ε(sim) must respect the threshold's bound:
            # sim ≥ τ and ε monotone non-increasing ⇒ ε(sim) ≤ ε(τ)
            for r in srv.completed:
                if r.sem_hit and (r.utility or 0.0) + r.sem_loss > 0:
                    eps = r.sem_loss / (r.utility + r.sem_loss)
                    assert eps <= row["eps_bound"] + 1e-9, \
                        f"hit ε={eps:.4f} exceeds ε(τ)={row['eps_bound']:.4f}"
        if tau == float("inf"):
            assert row["off_identical"], \
                "threshold=inf serving diverged from the cache-less baseline"
    assert bench["semcache_sweep"][1]["cost_saved"] > 0, \
        "the loosest threshold saved no cost on a near-duplicate stream"

    save("online_throughput", rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    bench_path = os.path.join(RESULTS_DIR, "BENCH_online.json")
    try:        # keep sections other writers merged in on a prior run
        with open(bench_path) as f:
            prior = json.load(f)
        for sec, cfg_key in (("engine_decode", "engine"),
                             ("http_serving", "http"),
                             ("robustness", "robustness")):
            if sec in prior:
                bench[sec] = prior[sec]
                bench["config"][cfg_key] = prior.get("config", {}).get(cfg_key)
    except (OSError, json.JSONDecodeError):
        pass
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1, default=float)
    print(f"wrote {bench_path}", file=sys.stderr)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", choices=["tiny", "sim"], default=None,
                    help="default: tiny (real trained pool); sim under BENCH_QUICK=1")
    ap.add_argument("--steps", type=int, default=200, help="tiny-pool train steps")
    ap.add_argument("--qps", type=float, default=6.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--budget-x", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.pool, steps=args.steps, qps=args.qps, duration=args.duration,
        budget_x=args.budget_x, seed=args.seed)


if __name__ == "__main__":
    main()
