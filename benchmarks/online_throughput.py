"""Online serving throughput — sustained QPS, p50/p99 latency and realized
cost vs. the rolling budget, swept over admission window sizes, plus graceful
degradation when one pool member's circuit breaker trips mid-run.

Default pool is the REAL trained tiny pool (``repro.serving.tinypool``, the
``src/repro/configs/tiny_pool.py`` architectures served by the
continuous-batching engine); ``BENCH_QUICK=1`` or ``--pool sim`` swaps in the
calibrated simulator for a fast pass.  Latencies are virtual-stream seconds
(queueing + measured/simulated service time); the wall-clock per-request cost
of the control plane is emitted as ``us_per_call``.

This benchmark measures the SERVING PLANE — sustained QPS, latency
percentiles, budget adherence, fault handling.  On the tiny pool the measured
utilities are near the task's chance floor at smoke step counts (see
``repro.serving.tinypool``); use ``--pool sim`` for utility-sensitive
comparisons.

    PYTHONPATH=src python benchmarks/online_throughput.py [--pool sim]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import QUICK, emit, save, setup
from repro.core import Robatch
from repro.serving.fault import BreakerPolicy, FlakyMember
from repro.serving.online import OnlineConfig, OnlineRobatchServer, poisson_arrivals

WINDOWS = (0.25, 0.5, 1.0, 2.0)


def _build(pool_kind: str, steps: int, seed: int):
    if pool_kind == "sim":
        wl, pool, rb = setup("agnews", router="knn", coreset_size=64, seed=seed)
        return wl, pool, rb
    from repro.serving.tinypool import build_tiny_pool

    rng = np.random.default_rng(seed)
    wl, pool, _fmt = build_tiny_pool(rng, steps=steps, n_train=48, n_test=64)
    rb = Robatch(pool, wl, coreset_size=16, router_kind="knn", grid_multiple=2).fit()
    return wl, pool, rb


def _stream(rb, pool, wl, *, window_s, qps, duration, budget_x, seed):
    test = wl.subset_indices("test")
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect, test).mean())
    rate = qps * base * budget_x
    cfg = OnlineConfig(budget_per_s=rate, window_s=window_s,
                       breaker=BreakerPolicy(failure_threshold=1, recovery_time_s=1e9))
    srv = OnlineRobatchServer(rb, pool, wl, cfg)
    arrivals = poisson_arrivals(np.random.default_rng(seed), qps, duration, test,
                                repeat_frac=0.2)
    t0 = time.perf_counter()
    stats = srv.run(arrivals)
    wall = time.perf_counter() - t0
    srv.close()
    return srv, stats, wall, len(arrivals)


def run(pool_kind: str | None = None, steps: int = 200, qps: float = 6.0,
        duration: float = 20.0, budget_x: float = 3.0, seed: int = 0):
    pool_kind = pool_kind or ("sim" if QUICK else "tiny")
    wl, pool, rb = _build(pool_kind, steps, seed)
    rows = []

    # ---- window-size sweep --------------------------------------------------
    usage = np.zeros(len(pool), dtype=int)
    for w in WINDOWS:
        srv, stats, wall, n_arr = _stream(rb, pool, wl, window_s=w, qps=qps,
                                          duration=duration, budget_x=budget_x,
                                          seed=seed)
        for r in srv.completed:
            if r.model is not None and not r.cache_hit:
                usage[r.model] += 1
        row = dict(pool=pool_kind, window_s=w, offered_qps=qps,
                   sustained_qps=stats.qps, p50_s=stats.latency_p50,
                   p99_s=stats.latency_p99, mean_utility=stats.mean_utility,
                   cost=stats.total_cost, budget_allowance=stats.budget_allowance,
                   cache_hits=stats.n_cache_hits, dropped=stats.n_dropped,
                   deferred=int(sum(x.n_deferred for x in stats.windows)),
                   wall_s=wall)
        rows.append(row)
        emit(f"online_w{w}", wall / max(1, n_arr) * 1e6,
             f"qps={stats.qps:.1f};p50={stats.latency_p50:.2f}s;"
             f"p99={stats.latency_p99:.2f}s;cost=${stats.total_cost:.5f}"
             f"/${stats.budget_allowance:.5f};util={stats.mean_utility:.3f}")

    # ---- mid-run outage: breaker trips, traffic reroutes --------------------
    # fail the member the scheduler actually leans on (the budget level decides
    # whether that is the cheap anchor — which exercises re-anchoring — or an
    # upgraded model), tripping early enough that short streams reach it
    flaky_k = int(np.argmax(usage))
    pool_f = [FlakyMember(m, fail_from=3) if k == flaky_k else m
              for k, m in enumerate(pool)]
    srv, stats, wall, n_arr = _stream(rb, pool_f, wl, window_s=WINDOWS[1],
                                      qps=qps, duration=duration,
                                      budget_x=budget_x, seed=seed)
    tripped = srv.breakers[flaky_k].n_trips > 0
    survivors = sorted({r.model for r in srv.completed
                        if r.model is not None and r.model != flaky_k})
    row = dict(pool=pool_kind, window_s=WINDOWS[1], scenario="breaker_trip",
               tripped=bool(tripped), reroutes=stats.n_reroutes,
               dropped=stats.n_dropped, completed=stats.n_completed,
               submitted=stats.n_submitted, survivors=survivors,
               sustained_qps=stats.qps, p99_s=stats.latency_p99,
               cost=stats.total_cost, mean_utility=stats.mean_utility)
    rows.append(row)
    emit("online_breaker_trip", wall / max(1, n_arr) * 1e6,
         f"tripped={tripped};reroutes={stats.n_reroutes};"
         f"dropped={stats.n_dropped};completed={stats.n_completed}"
         f"/{stats.n_submitted};util={stats.mean_utility:.3f}")
    assert stats.n_completed == stats.n_submitted, "online layer lost queries"
    assert tripped and stats.n_reroutes > 0, "outage did not exercise rerouting"

    save("online_throughput", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", choices=["tiny", "sim"], default=None,
                    help="default: tiny (real trained pool); sim under BENCH_QUICK=1")
    ap.add_argument("--steps", type=int, default=200, help="tiny-pool train steps")
    ap.add_argument("--qps", type=float, default=6.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--budget-x", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.pool, steps=args.steps, qps=args.qps, duration=args.duration,
        budget_x=args.budget_x, seed=args.seed)


if __name__ == "__main__":
    main()
