"""Online serving throughput — sustained QPS, p50/p99 latency and realized
cost vs. the rolling budget, swept over admission window sizes AND replica
counts, plus graceful degradation under two scripted outages: a whole-member
failure (circuit breaker trips, traffic reroutes) and a single-replica
failure inside a ReplicaSet (the set degrades instead of breaking).

Default pool is the REAL trained tiny pool (``repro.serving.tinypool``, the
``src/repro/configs/tiny_pool.py`` architectures served by the
continuous-batching engine); ``BENCH_QUICK=1`` or ``--pool sim`` swaps in the
calibrated simulator for a fast pass.  Latencies are virtual-stream seconds
(queueing + measured/simulated service time); the wall-clock per-request cost
of the control plane is emitted as ``us_per_call``.

This benchmark measures the SERVING PLANE — sustained QPS, latency
percentiles, budget adherence, fault handling.  On the tiny pool the measured
utilities are near the task's chance floor at smoke step counts (see
``repro.serving.tinypool``); use ``--pool sim`` for utility-sensitive
comparisons.

Besides the usual per-row CSV/JSON, the run writes a stable-schema
``BENCH_online.json`` (next to the other results) that ``tools/bench_check.py``
compares against the committed baseline in ``benchmarks/baselines/`` — the CI
regression gate.

    PYTHONPATH=src python benchmarks/online_throughput.py [--pool sim]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import QUICK, RESULTS_DIR, emit, save, setup
from repro.core import Robatch
from repro.serving.fault import BreakerPolicy, FlakyMember
from repro.serving.online import OnlineConfig, OnlineRobatchServer, poisson_arrivals
from repro.serving.pool import ReplicaSet, replicate_simulated

WINDOWS = (0.25, 0.5, 1.0, 2.0)
BENCH_SCHEMA = 1


def _build(pool_kind: str, steps: int, seed: int, max_replicas: int):
    """(wl, pool, rb, make_pool): ``make_pool(R)`` yields an R-replica view of
    the same engines — simulated members are copied (deterministic-identical),
    tiny engines are built once at ``max_replicas`` and sliced, so a sweep
    never retrains."""
    if pool_kind == "sim":
        wl, pool, rb = setup("agnews", router="knn", coreset_size=64, seed=seed)

        def make_pool(r: int) -> list:
            return [replicate_simulated(m, r) for m in pool]

        return wl, pool, rb, make_pool
    from repro.serving.tinypool import build_tiny_pool

    rng = np.random.default_rng(seed)
    wl, sets, _fmt = build_tiny_pool(rng, steps=steps, n_train=48, n_test=64,
                                     replicas=max_replicas)
    rb = Robatch(sets, wl, coreset_size=16, router_kind="knn", grid_multiple=2).fit()
    pool = [rs.replicas[0] for rs in sets]          # plain single-engine view

    def make_pool(r: int) -> list:
        return [ReplicaSet(rs.replicas[:r], name=rs.name) for rs in sets]

    return wl, pool, rb, make_pool


def _stream(rb, pool, wl, *, window_s, qps, duration, budget_x, seed):
    test = wl.subset_indices("test")
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect, test).mean())
    rate = qps * base * budget_x
    cfg = OnlineConfig(budget_per_s=rate, window_s=window_s,
                       breaker=BreakerPolicy(failure_threshold=1, recovery_time_s=1e9))
    srv = OnlineRobatchServer(rb, pool, wl, cfg)
    arrivals = poisson_arrivals(np.random.default_rng(seed), qps, duration, test,
                                repeat_frac=0.2)
    t0 = time.perf_counter()
    stats = srv.run(arrivals)
    wall = time.perf_counter() - t0
    srv.close()
    return srv, stats, wall, len(arrivals)


def run(pool_kind: str | None = None, steps: int = 200, qps: float = 6.0,
        duration: float = 20.0, budget_x: float = 3.0, seed: int = 0):
    pool_kind = pool_kind or ("sim" if QUICK else "tiny")
    replica_counts = (1, 2) if pool_kind == "tiny" else (1, 2, 4)
    # capacity only binds when the schedule wants many concurrent groups:
    # drive the replica legs harder (more arrivals per window, enough budget
    # to upgrade toward small batches) than the window-size sweep
    r_qps, r_budget_x = qps * 4, budget_x * 4
    wl, pool, rb, make_pool = _build(pool_kind, steps, seed, max(replica_counts))
    rows = []
    bench = {"schema": BENCH_SCHEMA,
             "config": dict(pool=pool_kind, qps=qps, duration=duration,
                            budget_x=budget_x, seed=seed, windows=list(WINDOWS),
                            replica_counts=list(replica_counts),
                            replica_qps=r_qps, replica_budget_x=r_budget_x),
             "window_sweep": [], "replica_sweep": [],
             "breaker_outage": {}, "replica_outage": {}}

    # ---- window-size sweep --------------------------------------------------
    usage = np.zeros(len(pool), dtype=int)
    for w in WINDOWS:
        srv, stats, wall, n_arr = _stream(rb, pool, wl, window_s=w, qps=qps,
                                          duration=duration, budget_x=budget_x,
                                          seed=seed)
        for r in srv.completed:
            if r.model is not None and not r.cache_hit:
                usage[r.model] += 1
        row = dict(pool=pool_kind, window_s=w, offered_qps=qps,
                   sustained_qps=stats.qps, p50_s=stats.latency_p50,
                   p99_s=stats.latency_p99, mean_utility=stats.mean_utility,
                   cost=stats.total_cost, budget_allowance=stats.budget_allowance,
                   cache_hits=stats.n_cache_hits, dropped=stats.n_dropped,
                   deferred=int(sum(x.n_deferred for x in stats.windows)),
                   wall_s=wall)
        rows.append(row)
        bench["window_sweep"].append({k: row[k] for k in (
            "window_s", "sustained_qps", "p50_s", "p99_s", "mean_utility",
            "cost", "budget_allowance", "cache_hits", "dropped", "deferred")})
        emit(f"online_w{w}", wall / max(1, n_arr) * 1e6,
             f"qps={stats.qps:.1f};p50={stats.latency_p50:.2f}s;"
             f"p99={stats.latency_p99:.2f}s;cost=${stats.total_cost:.5f}"
             f"/${stats.budget_allowance:.5f};util={stats.mean_utility:.3f}")

    # ---- replica sweep: QPS/p99 vs. replica count ---------------------------
    # every member is an R-replica set; per-window capacity caps (R groups per
    # member) are what the scheduler plans against, so throughput scales with
    # R until the budget — not capacity — is the binding constraint
    cap_deferred_by_r = {}
    for r_count in replica_counts:
        srv, stats, wall, n_arr = _stream(rb, make_pool(r_count), wl,
                                          window_s=WINDOWS[1], qps=r_qps,
                                          duration=duration, budget_x=r_budget_x,
                                          seed=seed)
        cap_deferred = int(sum(w.n_capacity_held for w in stats.windows))
        cap_deferred_by_r[r_count] = cap_deferred
        row = dict(pool=pool_kind, scenario="replica_sweep", replicas=r_count,
                   window_s=WINDOWS[1], offered_qps=r_qps,
                   sustained_qps=stats.qps, p50_s=stats.latency_p50,
                   p99_s=stats.latency_p99, cost=stats.total_cost,
                   capacity_deferred=cap_deferred,
                   completed=stats.n_completed, dropped=stats.n_dropped,
                   wall_s=wall)
        rows.append(row)
        bench["replica_sweep"].append({k: row[k] for k in (
            "replicas", "sustained_qps", "p50_s", "p99_s", "cost",
            "capacity_deferred", "completed", "dropped")})
        emit(f"online_replicas{r_count}", wall / max(1, n_arr) * 1e6,
             f"qps={stats.qps:.1f};p99={stats.latency_p99:.2f}s;"
             f"cap_deferred={cap_deferred};dropped={stats.n_dropped}")
        assert stats.n_completed == stats.n_submitted, "replica run lost queries"
    assert cap_deferred_by_r[replica_counts[0]] >= cap_deferred_by_r[replica_counts[-1]], \
        "more replicas should not defer more work to capacity"

    # ---- mid-run outage A: whole member fails, breaker trips ----------------
    # fail the member the scheduler actually leans on (the budget level decides
    # whether that is the cheap anchor — which exercises re-anchoring — or an
    # upgraded model), tripping early enough that short streams reach it
    flaky_k = int(np.argmax(usage))
    pool_f = [FlakyMember(m, fail_from=3) if k == flaky_k else m
              for k, m in enumerate(pool)]
    srv, stats, wall, n_arr = _stream(rb, pool_f, wl, window_s=WINDOWS[1],
                                      qps=qps, duration=duration,
                                      budget_x=budget_x, seed=seed)
    tripped = srv.breakers[flaky_k].n_trips > 0
    survivors = sorted({r.model for r in srv.completed
                        if r.model is not None and r.model != flaky_k})
    row = dict(pool=pool_kind, window_s=WINDOWS[1], scenario="breaker_trip",
               tripped=bool(tripped), reroutes=stats.n_reroutes,
               dropped=stats.n_dropped, completed=stats.n_completed,
               submitted=stats.n_submitted, survivors=survivors,
               sustained_qps=stats.qps, p99_s=stats.latency_p99,
               cost=stats.total_cost, mean_utility=stats.mean_utility)
    rows.append(row)
    bench["breaker_outage"] = {k: row[k] for k in (
        "tripped", "reroutes", "dropped", "completed", "submitted",
        "sustained_qps", "p99_s", "cost")}
    emit("online_breaker_trip", wall / max(1, n_arr) * 1e6,
         f"tripped={tripped};reroutes={stats.n_reroutes};"
         f"dropped={stats.n_dropped};completed={stats.n_completed}"
         f"/{stats.n_submitted};util={stats.mean_utility:.3f}")
    assert stats.n_completed == stats.n_submitted, "online layer lost queries"
    assert tripped and stats.n_reroutes > 0, "outage did not exercise rerouting"

    # ---- mid-run outage B: ONE replica fails inside a ReplicaSet ------------
    # the set retries the sibling replica and ejects the dead one, so the
    # member's breaker must stay CLOSED and QPS degrade (capacity shrinks to
    # the healthy-replica count) instead of the member disappearing
    r_outage = replica_counts[-1] if pool_kind == "sim" else 2
    pool_o = make_pool(r_outage)
    pool_o[flaky_k].replicas[0] = FlakyMember(pool_o[flaky_k].replicas[0],
                                              fail_from=3)
    srv, stats, wall, n_arr = _stream(rb, pool_o, wl, window_s=WINDOWS[1],
                                      qps=r_qps, duration=duration,
                                      budget_x=r_budget_x, seed=seed)
    tracker = pool_o[flaky_k].tracker
    row = dict(pool=pool_kind, window_s=WINDOWS[1], scenario="replica_outage",
               replicas=r_outage, member=pool_o[flaky_k].name,
               breaker_tripped=srv.breakers[flaky_k].n_trips > 0,
               replica_failures=tracker.replicas[0].n_failures,
               replica_ejections=tracker.replicas[0].n_ejections,
               healthy_replicas=tracker.n_healthy(),
               sustained_qps=stats.qps, p99_s=stats.latency_p99,
               completed=stats.n_completed, submitted=stats.n_submitted,
               dropped=stats.n_dropped, cost=stats.total_cost)
    rows.append(row)
    bench["replica_outage"] = {k: row[k] for k in (
        "replicas", "breaker_tripped", "replica_failures", "replica_ejections",
        "sustained_qps", "p99_s", "dropped", "completed", "submitted")}
    emit("online_replica_outage", wall / max(1, n_arr) * 1e6,
         f"breaker_tripped={row['breaker_tripped']};"
         f"replica_failures={row['replica_failures']};"
         f"qps={stats.qps:.1f};completed={stats.n_completed}/{stats.n_submitted}")
    assert stats.n_completed == stats.n_submitted, "replica outage lost queries"
    assert stats.qps > 0, "replica outage must degrade, not zero out, throughput"
    assert not row["breaker_tripped"], \
        "a single-replica outage must not trip the member's breaker"
    assert row["replica_failures"] > 0, "outage did not reach the flaky replica"

    save("online_throughput", rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    bench_path = os.path.join(RESULTS_DIR, "BENCH_online.json")
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1, default=float)
    print(f"wrote {bench_path}", file=sys.stderr)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", choices=["tiny", "sim"], default=None,
                    help="default: tiny (real trained pool); sim under BENCH_QUICK=1")
    ap.add_argument("--steps", type=int, default=200, help="tiny-pool train steps")
    ap.add_argument("--qps", type=float, default=6.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--budget-x", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.pool, steps=args.steps, qps=args.qps, duration=args.duration,
        budget_x=args.budget_x, seed=args.seed)


if __name__ == "__main__":
    main()
