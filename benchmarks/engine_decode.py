"""Serving-engine decode microbenchmark: tokens/s, host dispatches and
admission latency of the fused K-step scan decode vs. the per-token reference
driver, swept over slot count and decode block size K.

What it measures (real wall time, CPU):

* **decode throughput** — ``serve()`` (fused: one ``lax.scan`` dispatch per K
  tokens, donated KV cache, horizon-sliced attention) against
  ``serve_stepwise()`` (the pre-fusion path: one host round-trip and one full
  cache copy per token), with ``eos_id=-1`` so every request generates
  exactly ``max_new`` tokens — the step/dispatch/token counters are exact and
  seeded, only the wall-clock rates carry runner noise;
* **admission latency** — one batched bucket-grouped prefill of N requests
  (single ``_prefill`` + scatter ``_insert_many``) vs. N per-request
  admissions;
* **paged vs. contiguous KV** — ``serve()`` on the shared-prefix batch at
  slots=8/K=8 under both cache layouts: tokens/s, peak KV bytes, and the
  pool's share/fork counters.  Bit-identical greedy outputs and a strictly
  lower paged peak are asserted in-process, so they gate the CI bench job;
* **routed speculative decode** — a trained tiny-s drafting ``spec_k=8``
  tokens per round for a trained tiny-m target
  (:class:`repro.serving.speculative.SpeculativeEngine`) on an
  accept-friendly in-distribution batch-prompt stream, vs. the target engine
  decoding alone.  Training is a FIXED 120 steps (never QUICK-scaled): the
  accept rate — and with it the round/draft/accept/bonus counters the gate
  compares exactly — depends on how far the two models have converged toward
  agreeing.  Bit-identical outputs and a >= 1.3x single-stream speedup are
  asserted in-process.

Results join the blocking bench gate: the ``engine_decode`` section (and an
``engine`` config block) is merged into ``results/bench/BENCH_online.json``,
which ``tools/bench_check.py`` compares against the committed baseline —
counter metrics exactly, rates with runner-noise tolerances.  Run
``benchmarks/online_throughput.py`` first so the online sections are present
(this script preserves whatever is already in the file).

    PYTHONPATH=src python benchmarks/engine_decode.py        # BENCH_QUICK=1 to shrink
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import BENCH_SCHEMA, QUICK, RESULTS_DIR, emit, save
from repro.config import ShardingConfig, get_arch
from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.engine import Request, ServingEngine

SLOT_COUNTS = (1, 8)
K_SWEEP = (1, 4, 8)
MAX_LEN = 512                       # the tiny-pool serving config


def _engine(model, params, slots, k, paged=False):
    # eos_id=-1 is unreachable: every request runs to max_new exactly, so
    # token/step/dispatch counts are deterministic across runners
    return ServingEngine(model, params, max_slots=slots, max_len=MAX_LEN,
                         decode_block=k, eos_id=-1, paged=paged)


def _requests(tok, slots, max_new):
    return [Request(rid=i, tokens=tok.encode(f"bench prompt {i} abcdefg"),
                    max_new=max_new) for i in range(slots)]


# batch-prompting shape: one long shared system preamble, short per-query
# tails — the workload the paged engine's prefix sharing is built for
_SYS = ("You are a careful assistant. Answer every numbered query in order, "
        "one line per query, citing the shared context above where relevant. ")


def _shared_requests(tok, slots, max_new):
    return [Request(rid=i, tokens=tok.encode(_SYS + f"query {i}: item {i}"),
                    max_new=max_new) for i in range(slots)]


def _run(eng, tok, slots, max_new, fused, repeats):
    run = eng.serve if fused else eng.serve_stepwise
    run(_requests(tok, slots, max_new))            # warm the jit variants
    best, counts = 0.0, None
    for _ in range(repeats):
        c0, s0, p0 = eng.n_decode_calls, eng.n_decode_steps, eng.n_prefill_calls
        reqs = _requests(tok, slots, max_new)
        t0 = time.perf_counter()
        run(reqs)
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.out_tokens) for r in reqs)
        counts = (eng.n_decode_calls - c0, eng.n_decode_steps - s0,
                  eng.n_prefill_calls - p0, n_tok)
        best = max(best, n_tok / dt)
    return best, counts


# routed speculative decode: configuration is LOCKED — training steps, stream
# shape and spec_k together determine the accept rate, and the gate compares
# the resulting round/draft/accept counters exactly
SPEC_TRAIN_STEPS = 120              # fixed; NOT scaled down under BENCH_QUICK
SPEC_K = 8
SPEC_STREAMS = 16                   # batch prompts in the stream
SPEC_B = 5                          # queries per batch prompt (training max is 6:
#                                     out-of-distribution widths crater accept)
SPEC_MAX_NEW = 16
SPEC_PAGE = 16


def _spec_requests(fmt, rng):
    from repro.serving.engine import Request as Req
    from repro.serving.tinypool import gen_query

    reqs = []
    for i in range(SPEC_STREAMS):
        qs = [gen_query(rng)[0] for _ in range(SPEC_B)]
        reqs.append(Req(rid=i, tokens=fmt.format(qs), max_new=SPEC_MAX_NEW))
    return reqs


def _spec_leg(repeats):
    """Speculative vs. target-only decode on the accept-friendly stream.

    tiny-s drafts for tiny-m; both are trained (fixed step count) on the
    batch-prompt addition task so the draft actually agrees with the target —
    untrained weights accept near 0 and the leg would measure pure overhead.
    The stream is in-distribution (b=5 queries per prompt, inside the
    training formatter's 1..6 range) so the answers are the deterministic
    short digit strings both models learned.  Outputs must be bit-identical
    to the target decoding alone (deterministic-match acceptance), and at
    slots=1 the speedup must clear 1.3x — both asserted here, inside the
    blocking bench job."""
    import numpy as np

    from repro.serving.batcher import BatchPromptFormatter
    from repro.serving.speculative import SpeculativeEngine
    from repro.serving.tinypool import SYSTEM_PROMPT, train_engines

    fmt = BatchPromptFormatter(SYSTEM_PROMPT)
    engines = train_engines(np.random.default_rng(0), fmt, SPEC_TRAIN_STEPS,
                            names=("tiny-s", "tiny-m"), verbose=False)
    draft, target = engines["tiny-s"][0], engines["tiny-m"][0]

    rows, speedups = [], {}
    for slots in SLOT_COUNTS:
        tgt = ServingEngine(target.model, target.params, max_slots=slots,
                            max_len=MAX_LEN, decode_block=SPEC_K,
                            paged=True, page_size=SPEC_PAGE)
        spec = SpeculativeEngine(target.model, target.params,
                                 draft.model, draft.params, max_slots=slots,
                                 max_len=MAX_LEN, spec_k=SPEC_K,
                                 page_size=SPEC_PAGE)
        legs = {}
        for path, eng in (("spec_target", tgt), ("spec", spec)):
            eng.serve(_spec_requests(fmt, np.random.default_rng(42)))  # warm
            best = 0.0
            for _ in range(repeats):
                reqs = _spec_requests(fmt, np.random.default_rng(42))
                t0 = time.perf_counter()
                eng.serve(reqs)
                dt = time.perf_counter() - t0
                n_tok = sum(len(r.out_tokens) for r in reqs)
                best = max(best, n_tok / dt)
            legs[path] = (best, [r.out_tokens for r in reqs], n_tok)
        assert legs["spec"][1] == legs["spec_target"][1], (
            "speculative decode diverged from the target-only engine — "
            "deterministic-match acceptance must be bit-identical")
        tps_t, _, n_tok = legs["spec_target"]
        tps_s = legs["spec"][0]
        speedups[slots] = tps_s / tps_t
        rows.append(dict(slots=slots, k=SPEC_K, path="spec_target",
                         tokens_per_s=tps_t, gen_tokens=n_tok))
        # per-(repeats+warm) cumulative counters divide evenly: every serve()
        # of the seeded stream takes the identical rounds/accepts
        n_runs = repeats + 1
        assert spec.n_rounds % n_runs == 0
        rows.append(dict(slots=slots, k=SPEC_K, path="spec",
                         tokens_per_s=tps_s, gen_tokens=n_tok,
                         speedup=tps_s / tps_t,
                         accept_rate=spec.accept_rate(),
                         rounds=spec.n_rounds // n_runs,
                         drafted=spec.n_drafted // n_runs,
                         accepted=spec.n_accepted // n_runs,
                         bonus=spec.n_bonus // n_runs))
        emit(f"engine_spec_s{slots}_k{SPEC_K}", 1e6 / tps_s,
             f"tok/s={tps_s:.0f};target={tps_t:.0f};"
             f"speedup={tps_s / tps_t:.2f}x;accept={spec.accept_rate():.2f}")

    # the routed-speculation contract on this hardware class (CPU): the
    # trained tiny-s draft must buy the tiny-m target >= 1.3x single-stream
    # decode throughput on the accept-friendly stream, and must never cost
    # more than ~10% at any swept slot count
    assert speedups[1] >= 1.3, (
        f"speculative decode at slots=1 is only {speedups[1]:.2f}x the "
        f"target-only path (needs >= 1.3x)")
    assert min(speedups.values()) >= 0.9, (
        f"speculative decode regressed below target-only: {speedups}")
    return rows


def _admission(model, params, tok, slots, repeats):
    """ms to fill ``slots`` free slots: one batched admission vs. per-request."""
    eng = _engine(model, params, slots, 1)
    reqs = _requests(tok, slots, 4)
    free = list(range(slots))
    eng._admit_batch(reqs, free)                   # warm (B=slots, B=1 variants)
    eng._admit_batch([reqs[0]], [0])
    out = {}
    for mode in ("batched", "sequential"):
        best = float("inf")
        for _ in range(repeats):
            eng.slot_req = [None] * slots          # re-admission overwrites rows
            reqs = _requests(tok, slots, 4)
            t0 = time.perf_counter()
            if mode == "batched":
                eng._admit_batch(reqs, free)
            else:
                for r, s in zip(reqs, free):
                    eng._admit_batch([r], [s])
            best = min(best, (time.perf_counter() - t0) * 1e3)
        out[mode] = best
    return out


def _kv_leg(model, params, tok, max_new, repeats):
    """Paged vs. contiguous KV on the shared-prefix batch at the top sweep
    point (slots=8, K=8): tokens/s plus peak KV bytes from the engines' own
    ``kv_occupancy`` telemetry.  Greedy outputs must be bit-identical across
    the two layouts, and the paged peak must be strictly below the
    contiguous commitment — both asserted here, inside the blocking bench
    job, so a memory-saving regression fails CI outright."""
    slots, k = max(SLOT_COUNTS), max(K_SWEEP)
    rows, outs = [], {}
    for path, paged in (("kv_contig", False), ("kv_paged", True)):
        eng = _engine(model, params, slots, k, paged=paged)
        eng.serve(_shared_requests(tok, slots, max_new))   # warm the variants
        best = 0.0
        for _ in range(repeats):
            reqs = _shared_requests(tok, slots, max_new)
            t0 = time.perf_counter()
            eng.serve(reqs)
            dt = time.perf_counter() - t0
            n_tok = sum(len(r.out_tokens) for r in reqs)
            best = max(best, n_tok / dt)
        outs[path] = [r.out_tokens for r in reqs]
        occ = eng.kv_occupancy()
        row = dict(slots=slots, k=k, path=path, tokens_per_s=best,
                   gen_tokens=n_tok, peak_kv_bytes=occ["peak_kv_bytes"])
        if paged:
            row.update(page_size=occ["page_size"], peak_pages=occ["peak_pages"],
                       prefix_shares=occ["prefix_shares"],
                       cow_forks=occ["cow_forks"])
        rows.append(row)
        emit(f"engine_{path}_s{slots}_k{k}", 1e6 / best,
             f"tok/s={best:.0f};peak_kv_bytes={occ['peak_kv_bytes']}")
    assert outs["kv_paged"] == outs["kv_contig"], (
        "paged decode diverged from the contiguous reference on the "
        "shared-prefix batch — greedy outputs must be bit-identical")
    contig, paged = rows
    assert paged["peak_kv_bytes"] < contig["peak_kv_bytes"], (
        f"paged peak KV {paged['peak_kv_bytes']} is not below the contiguous "
        f"commitment {contig['peak_kv_bytes']} on the shared-prefix batch")
    return rows


def run(max_new: int | None = None, repeats: int | None = None, seed: int = 3):
    max_new = max_new or (32 if QUICK else 128)
    repeats = repeats or (2 if QUICK else 3)
    cfg = get_arch("tiny-s")
    model = Model(cfg, ShardingConfig(remat="none"))
    import jax
    params = model.init(jax.random.PRNGKey(seed))
    tok = ByteTokenizer()

    rows = []
    speedups = {}
    for slots in SLOT_COUNTS:
        ref = _engine(model, params, slots, 1)
        ref_tps, (calls, steps, prefills, n_tok) = _run(ref, tok, slots,
                                                        max_new, False, repeats)
        rows.append(dict(slots=slots, path="stepwise", k=0,
                         tokens_per_s=ref_tps, gen_tokens=n_tok, steps=steps,
                         dispatches=calls, prefills=prefills))
        emit(f"engine_stepwise_s{slots}", 1e6 / ref_tps,
             f"tok/s={ref_tps:.0f};steps={steps};dispatches={calls}")
        for k in K_SWEEP:
            eng = _engine(model, params, slots, k)
            tps, (calls, steps, prefills, n_tok) = _run(eng, tok, slots,
                                                        max_new, True, repeats)
            speedups[(slots, k)] = tps / ref_tps
            rows.append(dict(slots=slots, path="fused", k=k,
                             tokens_per_s=tps, gen_tokens=n_tok, steps=steps,
                             dispatches=calls, prefills=prefills,
                             speedup=tps / ref_tps))
            emit(f"engine_fused_s{slots}_k{k}", 1e6 / tps,
                 f"tok/s={tps:.0f};speedup={tps / ref_tps:.2f}x;"
                 f"dispatches={calls};steps={steps}")

    rows += _kv_leg(model, params, tok, max_new, repeats)
    rows += _spec_leg(repeats)

    adm = _admission(model, params, tok, max(SLOT_COUNTS), repeats)
    rows.append(dict(slots=max(SLOT_COUNTS), path="admission", k=0,
                     n_requests=max(SLOT_COUNTS),
                     batched_ms=adm["batched"], sequential_ms=adm["sequential"]))
    emit(f"engine_admission_s{max(SLOT_COUNTS)}", adm["batched"] * 1e3,
         f"batched={adm['batched']:.1f}ms;sequential={adm['sequential']:.1f}ms")

    # the fusion's contract on this hardware class (CPU): K=8 at max_slots=8
    # must clear 3x the per-token path
    top = speedups[(max(SLOT_COUNTS), max(K_SWEEP))]
    assert top >= 3.0, (
        f"fused K={max(K_SWEEP)} decode at {max(SLOT_COUNTS)} slots is only "
        f"{top:.2f}x the per-token path (needs >= 3x)")

    save("engine_decode", rows)
    _merge_into_gate(rows, dict(max_len=MAX_LEN, max_new=max_new, seed=seed,
                                slot_counts=list(SLOT_COUNTS),
                                k_sweep=list(K_SWEEP), arch="tiny-s",
                                spec=dict(train_steps=SPEC_TRAIN_STEPS,
                                          spec_k=SPEC_K, streams=SPEC_STREAMS,
                                          b=SPEC_B, max_new=SPEC_MAX_NEW,
                                          draft="tiny-s", target="tiny-m")))
    return rows


def _merge_into_gate(rows, engine_cfg):
    """Attach the engine_decode section to the shared BENCH_online.json (the
    file the blocking CI gate compares); online sections are preserved."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    bench_path = os.path.join(RESULTS_DIR, "BENCH_online.json")
    try:
        with open(bench_path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError):
        bench = {"config": {}}
    bench["schema"] = BENCH_SCHEMA
    bench.setdefault("config", {})["engine"] = engine_cfg
    bench["engine_decode"] = rows
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1, default=float)
    print(f"wrote {bench_path} (engine_decode section)", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-new", type=int, default=None,
                    help="tokens per request (default 128; 32 under BENCH_QUICK=1)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(max_new=args.max_new, repeats=args.repeats, seed=args.seed)


if __name__ == "__main__":
    main()
