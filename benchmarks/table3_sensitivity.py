"""Table 3 + Fig. 9 + Fig. 10 — sensitivity & design-choice analysis.

Budget levels per §6.4: the total cost of the cheapest model, the medium
model, and their midpoint.  Sweeps: coreset selection algorithm (k-center /
FL / herding), coreset size {64..512}, embedding model stand-ins, scaling-
function fit (piecewise / power-law / KNN), router architecture & HPs.
"""
from __future__ import annotations

import copy
import time


from benchmarks.common import QUICK, emit, save, setup
from repro.core import Robatch, execute
from repro.data.workload import alternate_embeddings

TASKS = ["agnews", "gsm8k", "imdb"]


def _three_budgets(rb, test):
    cm = rb.cost_model
    cheap = cm.single_model_cost(0, test, 1)
    mid = cm.single_model_cost(1, test, 1)
    return {"cheap": cheap, "mid": (cheap + mid) / 2, "expensive": mid}


def _eval(rb, wl, pool, test) -> dict:
    out = {}
    for tag, budget in _three_budgets(rb, test).items():
        res = rb.schedule(test, budget)
        out[tag] = execute(pool, wl, res.assignment).accuracy
    return out


def run():
    rows = []
    t0 = time.perf_counter()
    tasks = TASKS[:1] if QUICK else TASKS

    for task in tasks:
        # --- coreset selection algorithms (Table 3 top) -------------------
        for method in ["kcenter", "fl", "herding"]:
            wl, pool, rb = setup(task, coreset=method)
            accs = _eval(rb, wl, pool, wl.subset_indices("test"))
            rows.append(dict(axis="coreset_method", value=method, task=task, **accs))
        # --- coreset sizes (Fig. 9) ---------------------------------------
        for size in [64, 128, 256, 512]:
            wl, pool, rb = setup(task, coreset_size=size)
            accs = _eval(rb, wl, pool, wl.subset_indices("test"))
            rows.append(dict(axis="coreset_size", value=size, task=task, **accs))
        # --- embedding models (Table 3 middle) -----------------------------
        for kind in ["qwen3-0.6b", "e5-base", "bge-base"]:
            wl, pool, _ = setup(task)
            wl2 = copy.copy(wl)
            wl2.embeddings = alternate_embeddings(wl, kind)
            coreset = min(256, len(wl2.subset_indices("train")) // 2)
            rb = Robatch(pool, wl2, coreset_size=coreset).fit()
            accs = _eval(rb, wl2, pool, wl2.subset_indices("test"))
            rows.append(dict(axis="embedding", value=kind, task=task, **accs))
        # --- scaling-function fits (Table 3 bottom) -------------------------
        for fit in ["piecewise", "powerlaw", "knn"]:
            wl, pool, rb = setup(task, scaling_fit=fit)
            accs = _eval(rb, wl, pool, wl.subset_indices("test"))
            rows.append(dict(axis="scaling_fit", value=fit, task=task, **accs))
        # --- router architectures / hyper-parameters (Fig. 10) -------------
        for hidden in [(128,), (256, 128), (512, 256, 128)]:
            wl, pool, _ = setup(task)
            rb = Robatch(pool, wl, router_hidden=hidden,
                         coreset_size=min(256, len(wl.subset_indices("train")) // 2)).fit()
            accs = _eval(rb, wl, pool, wl.subset_indices("test"))
            rows.append(dict(axis="mlp_hidden", value=str(hidden), task=task, **accs))
        for k in [1, 4, 16, 64]:
            wl, pool, _ = setup(task)
            rb = Robatch(pool, wl, router_kind="knn", knn_k=k,
                         coreset_size=min(256, len(wl.subset_indices("train")) // 2)).fit()
            accs = _eval(rb, wl, pool, wl.subset_indices("test"))
            rows.append(dict(axis="knn_k", value=k, task=task, **accs))

    dt = time.perf_counter() - t0
    save("table3_sensitivity", rows)
    for axis in ["coreset_method", "coreset_size", "embedding", "scaling_fit",
                 "mlp_hidden", "knn_k"]:
        spreads = []
        for task in tasks:
            sub = [r for r in rows if r["axis"] == axis and r["task"] == task]
            if sub:
                spreads.append(max(r["mid"] for r in sub) - min(r["mid"] for r in sub))
        if spreads:
            emit(f"table3_{axis}", dt / max(len(rows), 1) * 1e6,
                 f"mid_budget_acc_spread_max_over_tasks={max(spreads):.3f};n_tasks={len(spreads)}")
    return rows


if __name__ == "__main__":
    run()
