"""Fig. 5 — RCU curves, ternary-search efficiency and chosen b_effect."""
from __future__ import annotations

import time


from benchmarks.common import emit, save, setup
from repro.core.scaling import batch_grid, rcu


def run():
    rows = []
    t0 = time.perf_counter()
    for task in ["agnews", "gsm8k"]:
        wl, pool, rb = setup(task)
        probes_used = rb.profile.n_probes
        for cal, m in zip(rb.calibrations, pool):
            # exhaustive curve (all probes beyond the search are extra billing
            # the real system avoids; we pay them here only to plot the curve)
            grid = batch_grid(cal.b_max)
            curve = [{"b": int(b), "rcu": float(rcu(rb.cost_model, rb.profile, cal.k, int(b))),
                      "u": rb.profile.mean_utility(cal.k, int(b))} for b in grid]
            rows.append(dict(task=task, model=m.name, b_max=cal.b_max,
                             b_effect=cal.b_effect, curve=curve))
        exhaustive = sum(len(batch_grid(c.b_max)) for c in rb.calibrations)
        emit(f"fig5_{task}", (time.perf_counter() - t0) * 1e6 / max(len(rows), 1),
             f"b_eff={[c.b_effect for c in rb.calibrations]};"
             f"search_probes={probes_used};exhaustive_probes={exhaustive}")
    save("fig5_rcu", rows)
    return rows


if __name__ == "__main__":
    run()
