"""HTTP front-end benchmark: concurrent-client throughput and latency of the
OpenAI-compatible wire surface (``repro.http``) over the live ingress bridge.

What it measures (real wall time, loopback HTTP):

* **unary** — ``POST /v1/chat/completions`` round-trips: requests/s sustained
  by N concurrent clients, p50/p99 full-response latency;
* **stream** — the same with ``"stream": true``: time-to-first-chunk (TTFC)
  vs. full SSE latency, with the framing contract asserted in-process — every
  stream must deliver the role frame, **>= 2 content chunks** (the
  decode_block-cadence guarantee), a ``finish_reason`` frame and the
  ``[DONE]`` sentinel.

The pool is the calibrated simulator (deterministic content, so chunk and
completion counts are exact across runners); every request addresses a
distinct ``query_idx`` so the response cache never blurs the latency
distribution.  The budget is set effectively unlimited — this leg gates the
HTTP plane (framing, demux, concurrency, parity counters), not the budget
scheduler, which ``online_throughput.py`` already gates.

Results join the blocking bench gate: the ``http_serving`` section (and an
``http`` config block) is merged into ``results/bench/BENCH_online.json``
for ``tools/bench_check.py`` — counter metrics exactly (completed, chunk
totals), wall-clock rates and latencies with wide runner-noise tolerances.

    PYTHONPATH=src python benchmarks/http_serving.py     # BENCH_QUICK=1 to shrink
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import BENCH_SCHEMA, QUICK, RESULTS_DIR, emit, save, setup
from repro.http import HttpFrontend
from repro.serving.online import OnlineConfig, OnlineRobatchServer

CLIENTS = (1, 4) if QUICK else (1, 4, 8)
WINDOW_S = 0.05


def _post(base: str, payload: dict, timeout: float = 60.0):
    req = urllib.request.Request(
        base + "/v1/chat/completions", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    return urllib.request.urlopen(req, timeout=timeout)


def _unary_once(base: str, q: int):
    t0 = time.perf_counter()
    with _post(base, {"messages": [{"role": "user", "content": f"#{q}"}],
                      "query_idx": q}) as r:
        body = json.loads(r.read())
    latency = time.perf_counter() - t0
    content = body["choices"][0]["message"]["content"]
    ok = bool(content) and body["robatch"]["query_idx"] is not None
    return ok, None, latency, 0


def _stream_once(base: str, q: int):
    t0 = time.perf_counter()
    ttfc, chunks, finished, done = None, 0, False, False
    with _post(base, {"messages": [{"role": "user", "content": f"#{q}"}],
                      "query_idx": q, "stream": True}) as r:
        for line in r:
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            payload = line[len(b"data: "):]
            if payload == b"[DONE]":
                done = True
                break
            frame = json.loads(payload)
            choice = frame["choices"][0]
            if "content" in choice.get("delta", {}):
                if ttfc is None:
                    ttfc = time.perf_counter() - t0
                chunks += 1
            if choice.get("finish_reason") == "stop":
                finished = True
    latency = time.perf_counter() - t0
    return (chunks >= 2 and finished and done), ttfc, latency, chunks


def _leg(base: str, mode: str, n_clients: int, per_client: int, q0: int):
    """N clients, each issuing ``per_client`` back-to-back requests against
    its own slice of distinct query indices; returns per-request records."""
    once = _stream_once if mode == "stream" else _unary_once
    records: list[tuple] = []
    lock = threading.Lock()

    def client(c: int):
        for i in range(per_client):
            rec = once(base, q0 + c * per_client + i)
            with lock:
                records.append(rec)

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return records, time.perf_counter() - t0


def _pct(xs: list, p: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * len(xs)))] if xs else 0.0


def run(per_client: int | None = None, seed: int = 0):
    per_client = per_client or (4 if QUICK else 8)
    wl, pool, rb = setup("agnews", router="knn", coreset_size=64, seed=seed)
    # budget effectively unlimited: this leg gates the HTTP plane, not the
    # budget scheduler — completion counts must be exact across runners
    cfg = OnlineConfig(budget_per_s=1e6, window_s=WINDOW_S, realtime=True)
    srv = OnlineRobatchServer(rb, pool, wl, cfg)
    rows = []
    with HttpFrontend(srv, port=0) as fe:
        base = f"http://127.0.0.1:{fe.port}"
        q0 = 0
        for mode in ("unary", "stream"):
            for n_clients in CLIENTS:
                records, wall = _leg(base, mode, n_clients, per_client, q0)
                n = n_clients * per_client
                q0 += n
                oks = [r[0] for r in records]
                ttfcs = [r[1] for r in records if r[1] is not None]
                lats = [r[2] for r in records]
                chunks = sum(r[3] for r in records)
                row = dict(scenario="http", mode=mode, clients=n_clients,
                           n_requests=n, completed=int(sum(oks)),
                           qps=n / wall, latency_p50_s=_pct(lats, 0.50),
                           latency_p99_s=_pct(lats, 0.99),
                           total_chunks=chunks, wall_s=wall)
                derived = (f"qps={row['qps']:.1f};"
                           f"p50={row['latency_p50_s'] * 1e3:.0f}ms;"
                           f"p99={row['latency_p99_s'] * 1e3:.0f}ms")
                if mode == "stream":
                    row["ttfc_p50_s"] = _pct(ttfcs, 0.50)
                    derived += f";ttfc_p50={row['ttfc_p50_s'] * 1e3:.0f}ms"
                rows.append(row)
                emit(f"http_{mode}_c{n_clients}", wall / n * 1e6, derived)
                assert row["completed"] == n, (
                    f"{mode} x{n_clients}: {row['completed']}/{n} requests "
                    f"completed the wire contract")
                if mode == "stream":
                    # deterministic: simulated members stream nothing live, so
                    # every sink splits its sealed content into exactly 2 deltas
                    assert chunks == 2 * n, (
                        f"stream x{n_clients}: {chunks} content chunks for {n} "
                        f"requests (need exactly 2 per request, >= 2 is the "
                        f"wire contract)")
    assert srv.stats().n_dropped == 0, "unlimited budget must shed nothing"

    save("http_serving", rows)
    _merge_into_gate(rows, dict(task="agnews", clients=list(CLIENTS),
                                per_client=per_client, window_s=WINDOW_S,
                                seed=seed))
    return rows


def _merge_into_gate(rows, http_cfg):
    """Attach the http_serving section to the shared BENCH_online.json (the
    file the blocking CI gate compares); other sections are preserved."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    bench_path = os.path.join(RESULTS_DIR, "BENCH_online.json")
    try:
        with open(bench_path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError):
        bench = {"config": {}}
    bench["schema"] = BENCH_SCHEMA
    bench.setdefault("config", {})["http"] = http_cfg
    bench["http_serving"] = rows
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1, default=float)
    print(f"wrote {bench_path} (http_serving section)", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--per-client", type=int, default=None,
                    help="requests per client thread (default 8; 4 under "
                         "BENCH_QUICK=1)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(per_client=args.per_client, seed=args.seed)


if __name__ == "__main__":
    main()
