"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus per-module JSON payloads
under results/bench/).  ``BENCH_QUICK=1`` shrinks workloads for smoke runs.
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    fig11_scalability,
    fig12_breakdown,
    fig2_routing_impact,
    fig34_batching_impact,
    fig5_rcu,
    fig7_overall,
    fig8_ablation,
    online_throughput,
    roofline_table,
    table3_sensitivity,
)

MODULES = [
    ("fig2_routing_impact", fig2_routing_impact),
    ("fig34_batching_impact", fig34_batching_impact),
    ("fig5_rcu", fig5_rcu),
    ("fig7_overall", fig7_overall),
    ("fig8_ablation", fig8_ablation),
    ("table3_sensitivity", table3_sensitivity),
    ("fig11_scalability", fig11_scalability),
    ("fig12_breakdown", fig12_breakdown),
    ("online_throughput", online_throughput),
    ("roofline_table", roofline_table),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:    # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
