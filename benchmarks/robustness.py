"""Robustness benchmark: the three failure axes PR 10 hardened, each driven
to deterministic counters and merged into the blocking bench gate.

* **bottleneck_burst** — a scripted capacity burst attributed to ONE pool
  member (``WindowReport.held_by_member``) drives the bottleneck-aware
  :class:`~repro.serving.autoscale.Autoscaler` against real
  :class:`~repro.serving.pool.ReplicaSet`\\ s: only the pressured member may
  grow, it must drain back after the burst, and its siblings must never see
  a scale event — asserted via exact per-member ``events_by_member()``
  counters.
* **robust_sweep** — the uncertainty-robust frontier walk
  (``greedy_schedule(robust_lambda=λ, cost_margin=m)``) against seeded
  adverse noise ∝ the calibration ``sigma`` carried by the candidate space:
  realized utility (û − draw·σ at the chosen states) of the best λ>0
  schedule must beat the λ=0 point-estimate schedule, every robust schedule
  must fit its worst-case cost ``(1+m)·Σc`` inside the budget, and the λ=0
  walk must be bit-identical across runs.
* **hung_replica** — one replica of the anchor member wrapped in a hanging
  :class:`~repro.serving.fault.ChaosMember`, served through the online loop
  with ``dispatch_timeout_s`` set: the set times the hang out, fails over to
  the sibling, ejects the dead replica after the second hang, and the
  member's breaker stays CLOSED (even at ``failure_threshold=1``) because
  the ReplicaSet absorbed the fault — completed == submitted, nothing shed.

Results join ``results/bench/BENCH_online.json`` as the ``robustness``
section (rows keyed by ``leg``/``lam``/``member``) for
``tools/bench_check.py`` — event/hang/timeout counters exactly, utilities
and rates with tolerance bands.

    PYTHONPATH=src python benchmarks/robustness.py      # BENCH_QUICK=1 to shrink
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import BENCH_SCHEMA, QUICK, RESULTS_DIR, emit, save, setup
from repro.core.scheduler import greedy_schedule
from repro.serving.autoscale import AutoscalePolicy, Autoscaler
from repro.serving.fault import BreakerPolicy, ChaosMember
from repro.serving.online import (OnlineConfig, OnlineRobatchServer,
                                  WindowReport, poisson_arrivals)
from repro.serving.pool import replicate_simulated

LAMS = (0.5, 1.0, 2.0)
COST_MARGIN = 0.1
NOISE_X = 2.0          # adverse-draw amplification (draw = NOISE_X·|N(0,1)|·σ)


# --------------------------------------------------------------- leg A
def leg_bottleneck_burst(pool, rows, bench_rows):
    """Scripted one-member burst through the per-member autoscaler: the
    window reports attribute every held query to member 1, so member 1 —
    and ONLY member 1 — must scale up, then drain once the burst ends."""
    sets = [replicate_simulated(m, 1) for m in pool]
    policy = AutoscalePolicy(min_replicas=1, max_replicas=3, up_pressure=4,
                             down_pressure=0, up_queue_depth=10 ** 9,
                             down_queue_depth=4, hold_windows=2,
                             cooldown_s=0.9, step=1)
    scaler = Autoscaler(sets, policy)
    bottleneck = 1
    peak = 1
    t0 = time.perf_counter()
    for i in range(6):            # burst: 6 held queries/window on member 1
        rep = WindowReport(t=0.5 * (i + 1), n_capacity_held=6,
                           held_by_member=((bottleneck, 6),),
                           group_models=tuple(range(len(sets))))
        scaler.observe(rep, queue_depth=0, now=rep.t)
        peak = max(peak, max(scaler.replica_counts()))
    for i in range(6):            # idle: pressure gone, pool must drain
        rep = WindowReport(t=3.5 + 0.5 * i)
        scaler.observe(rep, queue_depth=0, now=rep.t)
    wall = time.perf_counter() - t0
    by_member = scaler.events_by_member()
    end = scaler.replica_counts()

    assert set(by_member) == {sets[bottleneck].name}, \
        f"scale events leaked to non-bottleneck members: {by_member}"
    assert by_member[sets[bottleneck].name] == (2, 2), \
        f"expected 2 up + 2 down on the bottleneck, got {by_member}"
    assert peak == 3, f"burst should reach max_replicas=3, peaked at {peak}"
    assert end == tuple(1 for _ in sets), f"pool did not drain: {end}"

    for k, rs in enumerate(sets):
        ups, downs = by_member.get(rs.name, (0, 0))
        row = dict(leg="bottleneck", member=rs.name, events_up=ups,
                   events_down=downs, max_replicas=(peak if k == bottleneck
                                                    else 1),
                   end_replicas=end[k])
        rows.append(dict(scenario="robustness", **row, wall_s=wall))
        bench_rows.append(row)
    emit("robust_bottleneck", wall / 12 * 1e6,
         f"events={dict(by_member)};peak={peak};end={end}")


# --------------------------------------------------------------- leg B
def leg_robust_sweep(wl, rb, rows, bench_rows, *, budget_x, seed):
    """λ sweep of the uncertainty-robust walk under seeded adverse noise:
    the scheduler sees (û, σ); realization draws û − NOISE_X·|N|·σ."""
    test = wl.subset_indices("test")
    space = rb.candidate_space(test)
    assert space.sigma is not None, "fitted space must carry calibration sigma"
    rng = np.random.default_rng(seed)
    draws = NOISE_X * np.abs(rng.standard_normal(space.util.shape))
    realized_mat = space.util - draws * space.sigma
    budget = float(space.cost[:, space.initial_state].sum()) * budget_x
    col_of = {(s.model, s.batch): j for j, s in enumerate(space.states)}
    n = len(test)

    def realized(res) -> float:
        cols = np.array([col_of[(int(m), int(b))] for m, b in
                         zip(res.assignment.model, res.assignment.batch)])
        return float(realized_mat[np.arange(n), cols].sum())

    point = greedy_schedule(space, test, budget)
    again = greedy_schedule(space, test, budget)
    lam0_identical = (point.est_utility == again.est_utility
                      and point.amortized_cost == again.amortized_cost
                      and np.array_equal(point.assignment.model,
                                         again.assignment.model)
                      and np.array_equal(point.assignment.batch,
                                         again.assignment.batch))
    assert lam0_identical, "λ=0 schedule is not deterministic across runs"
    point_realized = realized(point)

    t0 = time.perf_counter()
    results = []
    for lam in (0.0,) + LAMS:
        margin = 0.0 if lam == 0.0 else COST_MARGIN
        res = greedy_schedule(space, test, budget,
                              robust_lambda=lam, cost_margin=margin)
        r_util = realized(res)
        within = bool(res.amortized_cost * (1 + margin) <= budget + 1e-9)
        row = dict(leg="robust", lam=lam, cost_margin=margin,
                   est_utility=res.est_utility,
                   amortized_cost=res.amortized_cost,
                   realized_utility=r_util, upgrades=res.n_upgrades,
                   within_worst_case=within,
                   beats_point_estimate=bool(r_util >= point_realized),
                   lam0_identical=bool(lam0_identical) if lam == 0.0 else True)
        results.append(row)
        rows.append(dict(scenario="robustness", **row))
        bench_rows.append(row)
        emit(f"robust_lam{lam:g}",
             (time.perf_counter() - t0) / max(1, n) * 1e6,
             f"est={res.est_utility:.2f};realized={r_util:.2f};"
             f"worst_cost={res.amortized_cost * (1 + margin):.5f}"
             f"/{budget:.5f};upgrades={res.n_upgrades}")
        assert within, \
            f"λ={lam}: worst-case cost overran the budget it promised to fit"

    best = max(results[1:], key=lambda r: r["realized_utility"])
    assert best["realized_utility"] > point_realized, \
        (f"robust walk gained nothing under adverse noise: best λ="
         f"{best['lam']} realized {best['realized_utility']:.3f} vs "
         f"point {point_realized:.3f}")
    return budget


# --------------------------------------------------------------- leg C
def leg_hung_replica(wl, pool, rb, rows, bench_rows, *, qps, duration,
                     budget_x, seed):
    """One anchor replica hangs (wall-clock sleep); the ReplicaSet's
    dispatch timeout unwedges the serving thread, fails over to the
    sibling, and ejects the hung replica — the member's breaker must stay
    CLOSED even at a hair-trigger failure_threshold=1."""
    hung_k = 0                    # the cheap anchor member serves every window
    sets = [replicate_simulated(m, 2, dispatch_timeout_s=0.25)
            for m in pool]
    sets[hung_k].replicas[0] = ChaosMember(
        sets[hung_k].replicas[0], seed=seed,
        hang_from=0, hang_until=2, hang_s=1.0)
    chaos = sets[hung_k].replicas[0]

    test = wl.subset_indices("test")
    base = float(rb.cost_model.state_cost(
        0, rb.calibrations[0].b_effect, test).mean())
    cfg = OnlineConfig(budget_per_s=qps * base * budget_x, window_s=0.5,
                       breaker=BreakerPolicy(failure_threshold=1,
                                             recovery_time_s=1e9))
    srv = OnlineRobatchServer(rb, sets, wl, cfg)
    arrivals = poisson_arrivals(np.random.default_rng(seed), qps, duration,
                                test, repeat_frac=0.2)
    t0 = time.perf_counter()
    stats = srv.run(arrivals)
    wall = time.perf_counter() - t0
    srv.close()

    tracker = sets[hung_k].tracker
    closed = all(br.state.value == "closed" for br in srv.breakers)
    row = dict(leg="hung_replica", member=sets[hung_k].name,
               completed=stats.n_completed, submitted=stats.n_submitted,
               dropped=stats.n_dropped, hangs=chaos.n_hangs,
               timeouts=sets[hung_k].n_timeouts,
               ejections=tracker.replicas[0].n_ejections,
               breaker_closed=bool(closed), sustained_qps=stats.qps,
               p99_s=stats.latency_p99)
    rows.append(dict(scenario="robustness", **row, wall_s=wall))
    bench_rows.append(row)
    emit("robust_hung_replica", wall / max(1, len(arrivals)) * 1e6,
         f"hangs={chaos.n_hangs};timeouts={sets[hung_k].n_timeouts};"
         f"ejections={row['ejections']};breakers_closed={closed};"
         f"completed={stats.n_completed}/{stats.n_submitted}")
    assert stats.n_completed == stats.n_submitted, "hung-replica run lost queries"
    assert stats.n_dropped == 0, "timeout failover must not shed work"
    assert chaos.n_hangs == 2, \
        f"hang window [0,2) not consumed: {chaos.n_hangs} hangs"
    assert sets[hung_k].n_timeouts == 2, \
        f"each hang must cost exactly one timeout: {sets[hung_k].n_timeouts}"
    assert row["ejections"] == 1, "second timeout must eject the hung replica"
    assert closed, "a replica-level hang must never trip the member breaker"


def run(qps: float = 6.0, duration: float = 10.0, budget_x: float = 3.0,
        seed: int = 0):
    wl, pool, rb = setup("agnews", router="knn", coreset_size=64, seed=seed)
    rows: list[dict] = []
    bench_rows: list[dict] = []
    leg_bottleneck_burst(pool, rows, bench_rows)
    budget = leg_robust_sweep(wl, rb, rows, bench_rows,
                              budget_x=budget_x, seed=seed)
    leg_hung_replica(wl, pool, rb, rows, bench_rows, qps=qps,
                     duration=duration, budget_x=budget_x, seed=seed)
    save("robustness", rows)
    _merge_into_gate(bench_rows, dict(
        task="agnews", quick=QUICK, qps=qps, duration=duration,
        budget_x=budget_x, seed=seed, lams=list(LAMS),
        cost_margin=COST_MARGIN, noise_x=NOISE_X, budget=budget))
    return rows


def _merge_into_gate(bench_rows, cfg):
    """Attach the robustness section to the shared BENCH_online.json (the
    file the blocking CI gate compares); other sections are preserved."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    bench_path = os.path.join(RESULTS_DIR, "BENCH_online.json")
    try:
        with open(bench_path) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError):
        bench = {"config": {}}
    bench["schema"] = BENCH_SCHEMA
    bench.setdefault("config", {})["robustness"] = cfg
    bench["robustness"] = bench_rows
    with open(bench_path, "w") as f:
        json.dump(bench, f, indent=1, default=float)
    print(f"wrote {bench_path} (robustness section)", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=6.0)
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--budget-x", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(qps=args.qps, duration=args.duration, budget_x=args.budget_x,
        seed=args.seed)


if __name__ == "__main__":
    main()
