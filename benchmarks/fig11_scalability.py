"""Fig. 11 — scheduling latency vs workload size (excl. LLM API latency).

Workload sizes double from 1k to 16k queries (test queries tiled);
compares Robatch, RouteLLM-style scoring, BATCHER clustering and OBP."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, emit, save, setup
from repro.core.baselines import batcher_assignment_plan, obp_plan, routellm_assignment
from repro.core.scheduler import greedy_schedule_vectorized


def run():
    rows = []
    sizes = [1024, 2048, 4096] if QUICK else [1024, 2048, 4096, 8192, 16384]
    for task in ["agnews", "imdb", "mmlu"]:
        wl, pool, rb = setup(task)
        test = wl.subset_indices("test")
        budget_rate = rb.cost_model.single_model_cost(1, test, 1) / len(test)
        for n in sizes:
            reps = int(np.ceil(n / len(test)))
            queries = np.tile(test, reps)[:n]
            t0 = time.perf_counter()
            res, timings = rb.schedule_timed(queries, budget_rate * n)
            t_rb = time.perf_counter() - t0
            # beyond-paper vectorized scheduler: speed + objective parity
            space = rb.candidate_space(queries)
            t0 = time.perf_counter()
            vec = greedy_schedule_vectorized(space, queries, budget_rate * n)
            t_vec = time.perf_counter() - t0
            parity = vec.est_utility / max(res.est_utility, 1e-9)
            t0 = time.perf_counter()
            routellm_assignment(rb, queries, tau=0.5, b=8)
            t_rl = time.perf_counter() - t0
            t0 = time.perf_counter()
            batcher_assignment_plan(rb, queries, tau=0.5, b=8, mode="sim")
            t_ba = time.perf_counter() - t0
            t0 = time.perf_counter()
            obp_plan(rb, queries, tau=0.5, target_b=8)
            t_ob = time.perf_counter() - t0
            rows.append(dict(task=task, n=n, robatch=t_rb, routellm=t_rl,
                             batcher=t_ba, obp=t_ob, vectorized=t_vec,
                             vec_parity=parity, breakdown=timings))
        small = next(r for r in rows if r["task"] == task and r["n"] == sizes[0])
        big = next(r for r in rows if r["task"] == task and r["n"] == sizes[-1])
        growth = big["robatch"] / max(small["robatch"], 1e-9)
        ideal = sizes[-1] / sizes[0]
        emit(f"fig11_{task}", big["robatch"] / big["n"] * 1e6,
             f"robatch_{sizes[0]}={small['robatch']:.2f}s;"
             f"robatch_{sizes[-1]}={big['robatch']:.2f}s;"
             f"growth={growth:.1f}x_vs_linear_{ideal:.0f}x;"
             f"vectorized={big['vectorized']:.2f}s_parity={big['vec_parity']:.4f}")
    save("fig11_scalability", rows)
    return rows


if __name__ == "__main__":
    run()
