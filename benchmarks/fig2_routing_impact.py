"""Fig. 2 — the impact of routing on avg. accuracy and cost.

Single-model baselines (b=1) vs vanilla MLP/KNN routers across threshold
sweeps, on AGNews and GSM8K with the Qwen3-family pool."""
from __future__ import annotations

import time


from benchmarks.common import emit, save, setup
from repro.core import execute
from repro.core.baselines import single_model_assignment, vanilla_router_assignment


def run():
    rows = []
    t0 = time.perf_counter()
    for task in ["agnews", "gsm8k"]:
        for router in ["mlp", "knn"]:
            wl, pool, rb = setup(task, router=router)
            test = wl.subset_indices("test")
            if router == "mlp":      # single-model points once per task
                for k, m in enumerate(pool):
                    out = execute(pool, wl, single_model_assignment(test, k, 1))
                    rows.append(dict(task=task, method=m.name, cost=out.exact_cost,
                                     acc=out.accuracy))
            for tau in [0.3, 0.5, 0.7, 0.9]:
                a = vanilla_router_assignment(rb, test, tau=tau, b=1)
                out = execute(pool, wl, a)
                rows.append(dict(task=task, method=f"router-{router}(τ={tau})",
                                 cost=out.exact_cost, acc=out.accuracy))
    dt = time.perf_counter() - t0
    save("fig2_routing_impact", rows)
    # headline: routers reach within X of the best single model at fraction of cost
    for task in ["agnews", "gsm8k"]:
        tr = [r for r in rows if r["task"] == task]
        best_single = max(r["acc"] for r in tr if not r["method"].startswith("router"))
        cheap_router = min((r for r in tr if r["method"].startswith("router")),
                           key=lambda r: r["cost"])
        emit(f"fig2_{task}", dt / len(rows) * 1e6,
             f"best_single_acc={best_single:.3f};cheapest_router_acc={cheap_router['acc']:.3f}"
             f"@${cheap_router['cost']:.3f}")
    return rows


if __name__ == "__main__":
    run()
