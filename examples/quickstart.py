"""Quickstart: Robatch end-to-end on a simulated pool in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py [task] [family] [--policy NAME]

Declares the experiment as a :class:`repro.api.RunSpec`, fits the modeling
stage once through the :class:`repro.api.Gateway`, then plans + commits the
test workload at three budgets.  ``--policy`` swaps in any registered
strategy (``repro.api.list_policies()``).  The ``--n-train/--n-val/--n-test/
--coreset`` flags shrink the instance for smoke runs (tools/smoke.sh).
"""
import argparse

from repro.api import Gateway, PolicySpec, PoolSpec, RunSpec, list_policies
from repro.core import execute
from repro.core.baselines import single_model_assignment


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("task", nargs="?", default="agnews")
    ap.add_argument("family", nargs="?", default="qwen3")
    ap.add_argument("--policy", default="robatch", choices=list_policies())
    ap.add_argument("--n-train", type=int, default=2048)
    ap.add_argument("--n-val", type=int, default=512)
    ap.add_argument("--n-test", type=int, default=1024)
    ap.add_argument("--coreset", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = RunSpec(
        pool=PoolSpec(task=args.task, family=args.family, n_train=args.n_train,
                      n_val=args.n_val, n_test=args.n_test, seed=args.seed),
        policy=PolicySpec(args.policy),
        coreset_size=args.coreset, seed=args.seed)

    print(f"== Robatch quickstart: {args.task} / {args.family} "
          f"(policy {args.policy}) ==")
    gw = Gateway.from_spec(spec).fit()
    rb, pool = gw.robatch, gw.pool

    print("\nModeling stage (per model): b_max, ternary-searched b_effect, ρ(b_eff):")
    for cal, m in zip(rb.calibrations, pool):
        print(f"  {m.name:12s} b_max={cal.b_max:4d} b_effect={cal.b_effect:3d} "
              f"rho(b_eff)={float(cal.scaling(cal.b_effect)):.3f} "
              f"u(b=1)={cal.u_mean_at[1]:.3f}")
    print(f"  profiling probes billed: {rb.profile.n_probes} "
          f"({rb.profile.billed_tokens / 1e6:.2f}M tokens)")

    test = gw.wl.subset_indices("test")
    cm = rb.cost_model
    cheap = cm.single_model_cost(0, test, 1)
    exp = cm.single_model_cost(2, test, 1)

    pol = gw.policy()
    print("\nRouting stage:")
    print(f"  {'budget':>10} {'accuracy':>9} {'spent':>9} {'upgrades':>9}")
    for budget in [cheap, (cheap + exp) / 2, exp]:
        plan = pol.plan(test, budget)
        out = pol.commit(plan)
        upgrades = plan.schedule.n_upgrades if plan.schedule is not None else 0
        print(f"  ${budget:9.4f} {out.accuracy:9.3f} ${out.exact_cost:8.4f} "
              f"{upgrades:9d}")

    print("\nReference points (single model, b=1):")
    for k, m in enumerate(pool):
        out = execute(pool, gw.wl, single_model_assignment(test, k, 1))
        print(f"  {m.name:12s} acc={out.accuracy:.3f} cost=${out.exact_cost:.4f}")


if __name__ == "__main__":
    main()
