"""Cost-accuracy Pareto sweep: Robatch vs all adapted baselines on one task —
the Fig. 7 protocol as a runnable script with a textual frontier plot.

    PYTHONPATH=src python examples/pareto_sweep.py gsm8k qwen3
"""
import sys

import numpy as np

from repro.core import Robatch, execute, execute_plan
from repro.core.baselines import (
    batcher_assignment_plan,
    frugalgpt_execute,
    obp_plan,
    routellm_assignment,
)
from repro.data import make_simulated_pool, make_workload


def main(task: str = "gsm8k", family: str = "qwen3"):
    wl = make_workload(task)
    pool = make_simulated_pool(family)
    rb = Robatch(pool, wl).fit()
    test = wl.subset_indices("test")

    points = []
    for b in [16, 8, 4, 1]:
        out = execute(pool, wl, routellm_assignment(rb, test, tau=0.5, b=b))
        points.append(("RouteLLM", out.exact_cost, out.accuracy))
        out = frugalgpt_execute(rb, test, tau=0.5, b=b)
        points.append(("FrugalGPT", out.exact_cost, out.accuracy))
        for mode in ["sim", "div"]:
            _, plan = batcher_assignment_plan(rb, test, tau=0.5, b=b, mode=mode)
            out = execute_plan(pool, wl, plan, test)
            points.append((f"BATCHER-{mode.upper()}", out.exact_cost, out.accuracy))
        _, plan = obp_plan(rb, test, tau=0.5, target_b=b)
        out = execute_plan(pool, wl, plan, test)
        points.append(("OBP", out.exact_cost, out.accuracy))
    costs = [c for _, c, _ in points]
    for budget in np.linspace(min(costs), max(costs), 8):
        res = rb.schedule(test, budget)
        out = execute(pool, wl, res.assignment)
        points.append(("Robatch", out.exact_cost, out.accuracy))

    print(f"\n{task} / {family} — cost vs accuracy (sorted by cost):")
    lo, hi = min(a for _, _, a in points), max(a for _, _, a in points)
    for name, cost, acc in sorted(points, key=lambda p: p[1]):
        bar = "#" * int(40 * (acc - lo) / max(hi - lo, 1e-9))
        marker = "*" if name == "Robatch" else " "
        print(f" {marker}{name:13s} ${cost:8.4f} {acc:.3f} |{bar}")
    print(" (* = Robatch; a dominant frontier climbs monotonically with cost)")


if __name__ == "__main__":
    main(*sys.argv[1:3])
