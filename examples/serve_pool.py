"""End-to-end driver: REAL model pool, REAL batch prompting, Robatch on top.

This is the full-stack counterpart of the paper's API experiments:

  1. trains three tiny LMs of ascending capacity (the ``tiny-s/m/l`` configs)
     on a multi-term addition task, *including batched-prompt examples* so the
     batch-prompting format is in-distribution;
  2. serves them with the continuous-batching engine (prefill + KV-cache
     decode) behind the PoolMember protocol with API-style per-token prices;
  3. runs the full Robatch pipeline — offline b=1 labeling, router training,
     coreset profiling with *real* batched invocations, ternary-searched
     b_effect, greedy scheduling — and executes the plan on the live pool.

Accuracy-vs-batch-size degradation here is an emergent property of the
trained models, not a simulator assumption.

    PYTHONPATH=src python examples/serve_pool.py [--steps 400] [--n-train 96]
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

print = functools.partial(print, flush=True)  # noqa: A001 — visible progress

from repro.config import ShardingConfig, get_arch
from repro.core import Robatch, execute
from repro.data.tokenizer import ByteTokenizer
from repro.data.workload import BenchmarkSpec, Workload
from repro.models.transformer import Model
from repro.serving.batcher import BatchPromptFormatter
from repro.serving.engine import ServingEngine
from repro.serving.pool import ServedPoolMember, TextTask
from repro.training.optimizer import adamw

SYSTEM_PROMPT = ("You are a calculator. For each question output the last digit "
                 "of the sum, answers separated by ';'.")


# ---------------------------------------------------------------------------
# task
# ---------------------------------------------------------------------------

def gen_query(rng) -> tuple[str, str, float]:
    """Two-term addition with difficulty tiers by operand size.
    Answer = last digit of the sum (single token)."""
    tier = int(rng.integers(0, 3))               # 0 easy … 2 hard
    hi = (10, 50, 100)[tier]
    a_, b_ = int(rng.integers(0, hi)), int(rng.integers(0, hi))
    q = f"{a_}+{b_}"
    ans = str((a_ + b_) % 10)
    return q, ans, tier / 2.0


def format_training_example(rng, fmt: BatchPromptFormatter, max_b: int = 6):
    b = int(rng.integers(1, max_b + 1))
    qas = [gen_query(rng) for _ in range(b)]
    prompt = fmt.format([q for q, _, _ in qas])
    answer = ";".join(a for _, a, _ in qas)
    tok = fmt.tokenizer
    full = prompt + tok.encode(answer, add_bos=False, add_eos=True)
    return full


def make_batches(rng, fmt, vocab, batch_size, seq_len, n_steps):
    tok = fmt.tokenizer
    for _ in range(n_steps):
        seqs = [format_training_example(rng, fmt) for _ in range(batch_size)]
        tokens, lengths = tok.pad_batch(seqs, seq_len + 1)
        labels = tokens[:, 1:].copy()
        labels[labels == tok.pad] = -100
        yield {"tokens": jnp.asarray(tokens[:, :-1]),
               "labels": jnp.asarray(np.where(labels == -100, -100, labels))}


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n-train", type=int, default=48)
    ap.add_argument("--n-test", type=int, default=48)
    ap.add_argument("--coreset", type=int, default=16)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    fmt = BatchPromptFormatter(SYSTEM_PROMPT)
    tok = fmt.tokenizer

    # ---- 1. train the pool -------------------------------------------------
    engines = {}
    for name, steps_scale in [("tiny-s", 1.0), ("tiny-m", 1.0), ("tiny-l", 1.0)]:
        cfg = get_arch(name)
        model = Model(cfg, ShardingConfig(remat="none"))
        params = model.init(jax.random.PRNGKey(hash(name) % 2**31))
        opt = adamw(3e-3, grad_clip=1.0)
        state = opt.init(params)

        @jax.jit
        def step(params, state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        t0 = time.time()
        losses = []
        print(f"training {name} ({model.param_count() / 1e6:.2f}M params)...")
        for batch in make_batches(rng, fmt, cfg.vocab_size, 8, 160,
                                  int(args.steps * steps_scale)):
            params, state, loss = step(params, state, batch)
            losses.append(float(loss))   # blocks: real per-step time on CPU
        print(f"trained {name}: loss {losses[0]:.2f} -> {np.mean(losses[-20:]):.2f} "
              f"({time.time() - t0:.0f}s, {len(losses)} steps)")
        engines[name] = ServingEngine(model, params, max_slots=4, max_len=512)

    # ---- 2. build the workload + text task ---------------------------------
    n = args.n_train + args.n_test
    queries, answers, difficulty = [], [], []
    for _ in range(n):
        q, a, d = gen_query(rng)
        queries.append(q)
        answers.append(a)
        difficulty.append(d)
    difficulty = np.array(difficulty, np.float32)
    # embeddings: simple text features (the real system would use a sentence
    # embedding model; tiny pool queries are fully described by these)
    feats = np.stack([
        [len(q), sum(int(c) for c in q if c.isdigit()) / 20.0,
         max(len(t) for t in q.split("+")), min(len(t) for t in q.split("+"))]
        for q in queries
    ]).astype(np.float32)
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)
    emb = np.concatenate([feats, rng.normal(0, 0.1, (n, 4)).astype(np.float32)], axis=1)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-8

    in_tokens = np.array([fmt.query_tokens(q) for q in queries], np.int32)
    spec = BenchmarkSpec("tiny-add", "reasoning", 10, fmt.sys_tokens,
                         (float(in_tokens.mean()), 0.2), (2, 0.1), (2.0, 2.0), 3, 5.0)
    wl = Workload(
        name="tiny-add", spec=spec, embeddings=emb, difficulty=difficulty,
        topic=np.zeros(n, np.int32), in_tokens=in_tokens,
        out_tokens=np.full(n, 2, np.int32), sys_tokens=fmt.sys_tokens,
        split={"train": np.arange(args.n_train),
               "val": np.arange(0),
               "test": np.arange(args.n_train, n)},
    )
    task = TextTask(queries=queries, answers=answers)
    pool = [
        ServedPoolMember("tiny-s", engines["tiny-s"], fmt, task, c_in=0.1, c_out=0.4,
                         context_len=512),
        ServedPoolMember("tiny-m", engines["tiny-m"], fmt, task, c_in=0.3, c_out=1.2,
                         context_len=512),
        ServedPoolMember("tiny-l", engines["tiny-l"], fmt, task, c_in=0.8, c_out=3.2,
                         context_len=512),
    ]

    # ---- 3. Robatch over the live pool --------------------------------------
    print("\nfitting Robatch on the live pool (real batched invocations)...")
    t0 = time.time()
    rb = Robatch(pool, wl, coreset_size=args.coreset, router_kind="knn",
                 grid_multiple=2).fit()
    print(f"modeling stage done in {time.time() - t0:.0f}s; "
          f"probes={rb.profile.n_probes} billed_tokens={rb.profile.billed_tokens}")
    for cal, m in zip(rb.calibrations, pool):
        print(f"  {m.name}: b_max={cal.b_max} b_effect={cal.b_effect} "
              f"u(b=1)={cal.u_mean_at[1]:.2f}")

    test = wl.subset_indices("test")
    cm = rb.cost_model
    budgets = [cm.single_model_cost(0, test, 1),
               cm.single_model_cost(1, test, 1),
               cm.single_model_cost(2, test, 1)]
    print("\nserving the test workload through the scheduled plan:")
    for budget in budgets:
        res = rb.schedule(test, budget)
        out = execute(pool, wl, res.assignment)
        states = {}
        for k, b in zip(res.assignment.model, res.assignment.batch):
            states[(pool[k].name, int(b))] = states.get((pool[k].name, int(b)), 0) + 1
        print(f"  budget ${budget:.5f}: acc={out.accuracy:.3f} "
              f"spent=${out.exact_cost:.5f} states={states}")


if __name__ == "__main__":
    main()
