"""End-to-end driver: REAL model pool, REAL batch prompting, Robatch on top.

This is the full-stack counterpart of the paper's API experiments:

  1. trains three tiny LMs of ascending capacity (the ``tiny-s/m/l`` configs)
     on a multi-term addition task, *including batched-prompt examples* so the
     batch-prompting format is in-distribution;
  2. serves them with the continuous-batching engine (prefill + KV-cache
     decode) behind the PoolMember protocol with API-style per-token prices;
  3. runs the full Robatch pipeline — offline b=1 labeling, router training,
     coreset profiling with *real* batched invocations, ternary-searched
     b_effect, greedy scheduling — and executes the plan on the live pool;
  4. optionally (--online-seconds N) streams a Poisson arrival workload
     through the online serving layer: windowed scheduling under a rolling
     budget, concurrent dispatch across the three live engines, response
     caching, circuit breaking.

The pool/workload construction lives in :mod:`repro.serving.tinypool`
(shared with benchmarks/online_throughput.py), declared here as a
``PoolSpec(kind="tiny")`` and driven through the :class:`repro.api.Gateway`;
``--policy`` swaps any registered strategy onto the same live pool.
Accuracy-vs-batch-size degradation here is an emergent property of the
trained models, not a simulator assumption.

    PYTHONPATH=src python examples/serve_pool.py [--steps 400] [--n-train 96] \
        [--online-seconds 30] [--policy robatch]
"""
import argparse
import functools
import time

import numpy as np

print = functools.partial(print, flush=True)  # noqa: A001 — visible progress

from repro.api import Gateway, PolicySpec, PoolSpec, RunSpec, list_policies


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n-train", type=int, default=48)
    ap.add_argument("--n-test", type=int, default=48)
    ap.add_argument("--coreset", type=int, default=16)
    ap.add_argument("--policy", default="robatch", choices=list_policies())
    ap.add_argument("--replicas", type=int, default=1,
                    help="engines per member (a ReplicaSet when > 1; weights "
                         "are trained once and shared)")
    ap.add_argument("--autoscale", type=int, default=0, metavar="MAX",
                    help="autoscale each member up to MAX replicas during the "
                         "online stream (backlog-driven; 0 = fixed pool)")
    ap.add_argument("--online-seconds", type=float, default=0.0,
                    help="stream the test set through the online layer this long")
    ap.add_argument("--online-qps", type=float, default=8.0)
    ap.add_argument("--online-window", type=float, default=0.5)
    ap.add_argument("--budget-x", type=float, default=3.0)
    args = ap.parse_args()

    spec = RunSpec(
        pool=PoolSpec(kind="tiny", steps=args.steps, n_train=args.n_train,
                      n_test=args.n_test, seed=0, replicas=args.replicas,
                      max_replicas=args.autoscale),
        policy=PolicySpec(args.policy),
        router="knn", coreset_size=args.coreset, grid_multiple=2)

    # ---- 1–2. train + serve the pool (PoolSpec materialization) -------------
    gw = Gateway.from_spec(spec)
    pool, wl = gw.pool, gw.wl
    if args.replicas > 1:
        print(f"pool: {', '.join(m.name for m in pool)} × {args.replicas} "
              f"replica engines each (shared trained weights)")

    # ---- 3. the modeling stage over the live pool ---------------------------
    print("\nfitting Robatch on the live pool (real batched invocations)...")
    t0 = time.time()
    gw.fit()
    rb = gw.robatch
    print(f"modeling stage done in {time.time() - t0:.0f}s; "
          f"probes={rb.profile.n_probes} billed_tokens={rb.profile.billed_tokens}")
    for cal, m in zip(rb.calibrations, pool):
        print(f"  {m.name}: b_max={cal.b_max} b_effect={cal.b_effect} "
              f"u(b=1)={cal.u_mean_at[1]:.2f}")

    test = wl.subset_indices("test")
    cm = rb.cost_model
    budgets = [cm.single_model_cost(0, test, 1),
               cm.single_model_cost(1, test, 1),
               cm.single_model_cost(2, test, 1)]
    pol = gw.policy()
    print("\nserving the test workload through the scheduled plan:")
    for budget in budgets:
        plan = pol.plan(test, budget)
        out = pol.commit(plan)
        states = {}
        for state, members in plan.groups or []:
            key = (pol.exec_pool[state.model].name, int(state.batch))
            states[key] = states.get(key, 0) + len(members)
        print(f"  budget ${budget:.5f}: acc={out.accuracy:.3f} "
              f"spent=${out.exact_cost:.5f} states={states}")

    # ---- 4. online streaming over the live pool -----------------------------
    if args.online_seconds > 0:
        from repro.serving.online import OnlineConfig, poisson_arrivals

        rng = np.random.default_rng(0)
        base = float(cm.state_cost(0, rb.calibrations[0].b_effect, test).mean())
        rate = args.online_qps * base * args.budget_x
        arrivals = poisson_arrivals(rng, args.online_qps, args.online_seconds,
                                    test, repeat_frac=0.25)
        print(f"\nonline: streaming {len(arrivals)} arrivals at "
              f"{args.online_qps} qps through the live engines "
              f"(window {args.online_window}s, budget ${rate:.6f}/s)...")
        t0 = time.time()
        stats = gw.serve(arrivals, OnlineConfig(
            budget_per_s=rate, window_s=args.online_window,
            autoscale=spec.pool.autoscale_policy()))
        print(stats.summary())
        print(f"(wall clock {time.time() - t0:.0f}s; latencies above are "
              f"virtual-stream seconds incl. measured engine time)")
        if gw.server.autoscaler is not None:
            print(gw.server.autoscaler.summary())


if __name__ == "__main__":
    main()
