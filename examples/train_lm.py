"""Train an LM from the zoo on synthetic data with the full training substrate:
AdamW, grad accumulation, checkpointing + crash-resume, cosine schedule.

CPU-friendly defaults (a few-M-param model, a few hundred steps); point
``--arch`` at any registered architecture and scale ``--dim/--layers`` up on
real hardware (e.g. ~100M: --dim 768 --layers 12).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --resume  # continue
"""
import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShardingConfig, get_arch
from repro.models.transformer import Model
from repro.training.optimizer import adamw, cosine_schedule
from repro.training.train_loop import Trainer


def synthetic_lm_batches(vocab: int, batch: int, seq: int, steps: int, seed: int = 0):
    """Markov-ish synthetic language: learnable structure, not noise."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.full(64, 0.1), size=64)   # 64-state chain
    proj = rng.integers(0, vocab, 64)
    for _ in range(steps):
        states = np.zeros((batch, seq + 1), np.int64)
        states[:, 0] = rng.integers(0, 64, batch)
        for t in range(seq):
            p = trans[states[:, t]]
            states[:, t + 1] = (p.cumsum(1) > rng.random((batch, 1))).argmax(1)
        tokens = proj[states]
        yield {"tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
               "labels": jnp.asarray(tokens[:, 1:], jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    base = get_arch(args.arch)
    cfg = replace(base, name=base.name + "-mini", n_layers=args.layers,
                  d_model=args.dim, n_heads=max(args.dim // 32, 1),
                  n_kv_heads=max(args.dim // 32, 1), head_dim=32,
                  d_ff=args.dim * 3, vocab_size=2048, dtype="float32")
    model = Model(cfg, ShardingConfig(remat="none", microbatches=args.microbatches))
    opt = adamw(cosine_schedule(3e-3, warmup=20, total=args.steps), grad_clip=1.0,
                weight_decay=1e-4)
    trainer = Trainer(model, opt, model.shard, ckpt_dir=args.ckpt, ckpt_every=50)
    params, opt_state, start = trainer.restore_or_init(jax.random.PRNGKey(0))
    if not args.resume and start:
        print(f"(checkpoint at step {start} found; pass --resume to continue, "
              f"or remove {args.ckpt})")
    print(f"arch={cfg.name} params={model.param_count() / 1e6:.2f}M "
          f"start_step={start}")
    batches = synthetic_lm_batches(cfg.vocab_size, args.batch, args.seq,
                                   max(args.steps - start, 0), seed=start)
    params, opt_state, hist = trainer.fit(params, opt_state, batches,
                                          start_step=start, log_every=20)
    for h in hist:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.2f} ({h['time']:.0f}s)")
    print(f"final checkpoint: step {trainer._mgr.latest_step()} in {args.ckpt}")


if __name__ == "__main__":
    main()
