from repro.checkpoint.ckpt import CheckpointManager, load_pytree, save_pytree
