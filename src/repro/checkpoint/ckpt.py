"""Fault-tolerant pytree checkpointing (no orbax in this environment).

Design for 1000+ node operation:
  * atomic commit: write to ``<dir>/tmp.<step>``, fsync, rename to
    ``<dir>/step_<n>`` — a crash mid-write never corrupts the latest good
    checkpoint, and restart logic simply picks the largest committed step;
  * keep-last-N retention;
  * layout-independent restore: arrays are saved with their tree paths, and
    ``restore_with_specs`` re-materializes them under *new* shardings — a
    restarted job may come back on a different mesh (elastic scaling);
  * metadata (step, config fingerprint, timestamps) in a sidecar JSON.

On a real multi-host cluster each host would write only its addressable
shards; on this single-process runtime arrays are fully addressable, so the
writer saves full arrays (the reshard-on-load path is identical either way).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_elem(p) for p in path)
        flat[key] = leaf
    return flat


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_pytree(tree, directory: str, step: int, metadata: Optional[dict] = None) -> str:
    """Atomically save a pytree as ``<directory>/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:012d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "time": time.time(), "n_leaves": len(arrays), **(metadata or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")]
    return max(steps) if steps else None


def load_pytree(template, directory: str, step: Optional[int] = None):
    """Restore into the structure of ``template`` (values replaced)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:012d}", "arrays.npz")
    with np.load(path) as data:
        flat_keys = _flatten_with_paths(template)
        leaves, treedef = jax.tree_util.tree_flatten(template)
        keys = list(flat_keys.keys())
        assert len(keys) == len(leaves)
        new_leaves = [jax.numpy.asarray(data[k], dtype=l.dtype if hasattr(l, "dtype") else None)
                      for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def restore_with_specs(template_specs, directory: str, shardings=None,
                       step: Optional[int] = None):
    """Restore and (optionally) place each leaf under a new sharding —
    the elastic-restart path: checkpoint written on mesh A, restored on mesh B."""
    restored, step = load_pytree(
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), template_specs),
        directory, step)
    if shardings is not None:
        restored = jax.tree.map(lambda x, sh: jax.device_put(x, sh), restored, shardings)
    return restored, step


class CheckpointManager:
    """Keep-N manager with resume support."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, tree, step: int, metadata: Optional[dict] = None) -> str:
        path = save_pytree(tree, self.directory, step, metadata)
        self._gc()
        return path

    def restore(self, template, step: Optional[int] = None):
        return load_pytree(template, self.directory, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"), ignore_errors=True)
