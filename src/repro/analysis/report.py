"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.json."""
from __future__ import annotations

import json


def fmt_row(cells):
    return "| " + " | ".join(str(c) for c in cells) + " |"


def dryrun_tables(path: str = "results/dryrun.json") -> str:
    rows = json.load(open(path))
    out = []
    for mesh in ["16x16", "2x16x16"]:
        sub = [r for r in rows if r["mesh"] == mesh]
        if not sub:
            continue
        chips = 256 if mesh == "16x16" else 512
        out.append(f"\n### Mesh {mesh} ({chips} chips)\n")
        hdr = ["arch", "shape", "status", "peak GB/chip (tpu-est / raw-cpu)",
               "compile s", "HLO GFLOP/dev", "coll GB/dev"]
        out.append(fmt_row(hdr))
        out.append(fmt_row(["---"] * len(hdr)))
        for r in sub:
            if r["status"] != "ok":
                out.append(fmt_row([r["arch"], r["shape"], r["status"], "-", "-", "-", "-"]))
                continue
            rf = r["roofline"]
            out.append(fmt_row([
                r["arch"], r["shape"], "ok",
                f"{r['mem']['peak_tpu_est_GB']:.1f} / {r['mem']['peak_GB']:.1f}",
                r["compile_s"],
                f"{rf['flops_per_device'] / 1e9:.1f}",
                f"{rf['collective_GB_per_device']:.2f}",
            ]))
    return "\n".join(out)


def roofline_table(path: str = "results/dryrun.json", mesh: str = "16x16") -> str:
    rows = [r for r in json.load(open(path)) if r["mesh"] == mesh]
    out = []
    hdr = ["arch", "shape", "compute s", "memory s", "collective s (bf16-basis)",
           "dominant", "MODEL_FLOPS", "useful ratio",
           "what would move the dominant term"]
    out.append(fmt_row(hdr))
    out.append(fmt_row(["---"] * len(hdr)))
    for r in rows:
        if r["status"] != "ok":
            out.append(fmt_row([r["arch"], r["shape"], "-", "-", "-", r["status"],
                                "-", "-", "-"]))
            continue
        rf = r["roofline"]
        hint = _hint(r)
        coll = f"{rf['collective_s']:.3f}"
        if rf.get("collective_bf16_s") is not None:
            coll += f" ({rf['collective_bf16_s']:.3f})"
        out.append(fmt_row([
            r["arch"], r["shape"],
            f"{rf['compute_s']:.3f}", f"{rf['memory_s']:.3f}",
            coll, f"**{rf['dominant']}**",
            f"{r['model_flops']:.2e}",
            f"{r['useful_ratio']:.2f}" if r.get("useful_ratio") else "-",
            hint,
        ]))
    return "\n".join(out)


def _hint(r) -> str:
    rf = r["roofline"]
    dom = rf["dominant"]
    moe = "moe" in r["arch"]
    if dom == "collective":
        if moe:
            return "EP-aware dispatch (all-to-all over expert shards instead of activation gathers)"
        if r["shape"].startswith("prefill"):
            return "drop per-layer KV seq-reshard; write cache in compute layout"
        if r["shape"] == "train_4k":
            return "reduce-scatter grads + overlap FSDP gathers with compute"
        return "batch-shard decode fully; avoid cache resharding"
    if dom == "memory":
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return "KV/state streaming is the floor: quantize cache or raise batch"
        return "remat policy / fused kernels to cut activation traffic"
    return "compute-bound: increase arithmetic intensity (larger per-chip tiles)"
