from repro.analysis.roofline import HW_V5E, analyze_compiled, model_flops
