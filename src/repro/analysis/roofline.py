"""Roofline terms from a compiled (dry-run) artifact.

Primary source: the optimized per-device HLO text.  XLA's cost_analysis()
counts every while-loop body ONCE (verified empirically), which under-counts
scan-over-layers models by the layer count, so instead we:

  1. parse every computation's ``dot`` instructions and compute their FLOPs
     from operand shapes (2 · prod(out dims) · prod(contracting dims));
  2. parse every collective (all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute) and sum payload bytes;
  3. walk the call graph (fusions, calls, while bodies) multiplying loop
     bodies by their trip counts, extracted from each condition's
     ``constant(N)`` compare.

Elementwise FLOPs are not counted (matmul-dominated workloads; noted in
EXPERIMENTS.md).  HBM bytes come from cost_analysis, corrected by the
caller-supplied loop product (layer scan × microbatches) — an upper-bound
approximation documented per table.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
All quantities are PER DEVICE (the compiled module is the post-GSPMD
per-device program).

Terms (seconds per step):
    compute    = dot_flops_per_device / peak_flops
    memory     = hbm_bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / ici_bw
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

__all__ = ["HW_V5E", "analyze_compiled", "analyze_hlo_text", "model_flops", "RooflineReport"]

HW_V5E = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # B/s per chip
    "ici_bw": 50e9,           # B/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],\{\}\d]+)\s+(\S+?)\(")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _shape_dims(shape_str: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (tuples sum their components)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class _Computation:
    name: str
    collective_bytes: dict = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    dot_flops: float = 0.0
    calls: list = field(default_factory=list)        # fusion/call targets
    whiles: list = field(default_factory=list)       # (body, cond)
    compare_constants: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)       # instr name -> shape str
    f32_converts: list = field(default_factory=list)  # (name, dims, bytes)
    collective_bf16: float = 0.0                      # bf16-normalized payload


def _parse_computations(hlo: str) -> tuple[dict[str, _Computation], Optional[str]]:
    comps: dict[str, _Computation] = {}
    entry = None
    current: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        # computation header (column 0): `%name (...) -> ... {` or `ENTRY ...`
        if not line.startswith(" ") and "{" in line and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", line)
            if m:
                current = _Computation(m.group(2))
                comps[current.name] = current
                if m.group(1):
                    entry = current.name
            continue
        if current is None:
            continue
        if stripped.startswith("}"):
            current = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, shape_str, op = dm.group(1), dm.group(2), dm.group(3)
        current.shapes[name] = shape_str
        op_lower = op.lower()
        # ---- collectives ----
        for coll in _COLLECTIVES:
            if op_lower.startswith(coll) and not op_lower.startswith(coll + "-done"):
                b = _shape_bytes(shape_str)
                current.collective_bytes[coll] += b
                # bf16-normalized: XLA-CPU upcasts bf16 payloads to f32 before
                # collectives; a TPU build moves them in bf16 (half the bytes)
                current.collective_bf16 += b / 2 if shape_str.lstrip().startswith("f32") else b
                break
        # ---- dots ----
        if op_lower == "dot":
            flops = _dot_flops(line, shape_str, current.shapes)
            current.dot_flops += flops
        # ---- hoistable whole-stack buffers (CPU-backend artifact accounting):
        # f32 upcasts of bf16 dot operands, and loop-invariant-hoisted
        # all-gathers of FSDP-sharded weight stacks
        if op_lower in ("convert", "all-gather", "copy") and (
                shape_str.startswith("f32[") or shape_str.startswith("bf16[")):
            dt, dims = _shape_dims(shape_str)
            b = _shape_bytes(shape_str)
            if b >= 64 * 2**20:
                current.f32_converts.append((name, tuple(dims), b))
        # ---- control flow ----
        if op_lower == "while":
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            if body and cond:
                current.whiles.append((body.group(1), cond.group(1)))
        else:
            for key in ("calls=", "to_apply=", "branch_computations={"):
                if key in line:
                    tail = line.split(key, 1)[1]
                    for cm in re.finditer(r"%?([\w\.\-]+)", tail[:200]):
                        cand = cm.group(1)
                        if cand in ("true_computation", "false_computation"):
                            continue
                        current.calls.append(cand)
                        if key != "branch_computations={":
                            break
                    break
        # ---- trip-count hints (condition computations) ----
        cc = re.search(r"constant\((\d+)\)", stripped)
        if cc and op_lower == "constant":
            current.compare_constants.append(int(cc.group(1)))
    return comps, entry


def _dot_flops(line: str, out_shape: str, shapes: dict) -> float:
    """2 · prod(output dims) · prod(lhs contracting dims)."""
    _, out_dims = _shape_dims(out_shape)
    ops = _OPERANDS_RE.search(line.split("dot(", 1)[1] if "dot(" in line else line)
    lhs_name = None
    if "dot(" in line:
        args = line.split("dot(", 1)[1].split(")")[0]
        lhs_name = args.split(",")[0].strip().lstrip("%")
    lhs_shape = shapes.get(lhs_name, "")
    _, lhs_dims = _shape_dims(lhs_shape)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if cm and lhs_dims:
        for d in cm.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * contract


def _trip_count(comps: dict, cond_name: str, default: int) -> int:
    cond = comps.get(cond_name)
    if cond and cond.compare_constants:
        return max(cond.compare_constants)
    return default


def _accumulate(comps: dict, name: str, default_trip: int, memo: dict, _depth=0):
    """(collective_bytes dict, dot_flops) reachable from ``name``; while bodies
    multiplied by parsed trip counts."""
    if name in memo:
        return memo[name]
    if name not in comps or _depth > 128:
        return ({k: 0.0 for k in _COLLECTIVES}, 0.0)
    c = comps[name]
    coll = dict(c.collective_bytes)
    coll["_bf16norm"] = c.collective_bf16
    flops = c.dot_flops
    for callee in c.calls:
        if callee == name:
            continue
        sub_c, sub_f = _accumulate(comps, callee, default_trip, memo, _depth + 1)
        for k in coll:
            coll[k] += sub_c.get(k, 0.0)
        flops += sub_f
    for body, cond in c.whiles:
        trips = _trip_count(comps, cond, default_trip)
        sub_c, sub_f = _accumulate(comps, body, default_trip, memo, _depth + 1)
        for k in coll:
            coll[k] += trips * sub_c.get(k, 0.0)
        flops += trips * sub_f
    memo[name] = (coll, flops)
    return memo[name]


def analyze_hlo_text(hlo: str, default_trip: int = 1):
    """Returns (collective_bytes dict, dot_flops) for the entry computation."""
    comps, entry = _parse_computations(hlo)
    if entry is None:
        entry = next(iter(comps)) if comps else ""
    return _accumulate(comps, entry, default_trip, {})


def cpu_upcast_bytes(hlo: str, stack_len: int) -> float:
    """Bytes of whole-layer-stack hoisted buffers — XLA *CPU* lowering
    artifacts the TPU pipeline does not materialize:

      * f32 upcasts of bf16 dot operands (MXU consumes bf16 natively), and
      * loop-invariant-hoisted all-gathers / copies of FSDP-sharded weight
        stacks (the TPU latency-hiding scheduler keeps them per-layer).

    Each is counted at (1 − 1/stack_len) of its size — one layer's slice
    would legitimately be alive at a time.  The dry-run reports peak memory
    both raw and with this adjustment."""
    comps, _ = _parse_computations(hlo)
    total = 0.0
    for c in comps.values():
        for name, dims, b in c.f32_converts:
            if len(dims) >= 3 and dims[0] == stack_len:
                total += b * (1.0 - 1.0 / max(stack_len, 2))
    return total


@dataclass
class RooflineReport:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    mem_per_device: dict
    cost_raw: dict
    collective_bf16_s: float = 0.0

    def terms(self) -> dict:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}

    def summary(self) -> dict:
        total_coll = sum(self.collective_bytes_per_device.values())
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_GB_per_device": self.hbm_bytes_per_device / 1e9,
            "collective_GB_per_device": total_coll / 1e9,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "collective_bf16_s": self.collective_bf16_s,
            "dominant": self.dominant,
            "peak_mem_GB": self.mem_per_device.get("peak_GB"),
            **{f"coll_{k}_GB": v / 1e9 for k, v in
               self.collective_bytes_per_device.items() if v > 0},
        }


def analyze_compiled(compiled, known_loops: Optional[dict] = None,
                     hw: dict = HW_V5E, hbm_bytes: Optional[float] = None) -> RooflineReport:
    """known_loops: loop trip counts enclosing the layer stack (e.g.
    {"layer_scan": 24, "microbatches": 4}) — fallback multiplier only; FLOPs
    and collective bytes come from the trip-count-aware HLO walk.
    ``hbm_bytes``: analytic per-device HBM traffic (see analytic_hbm_bytes);
    XLA-CPU's "bytes accessed" counts unfused intermediates and is kept only
    as a reference in cost_raw."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    bytes_once = float(ca.get("bytes accessed", 0.0) or 0.0)
    flops_once = float(ca.get("flops", 0.0) or 0.0)
    mult = 1.0
    for trips in (known_loops or {}).values():
        mult *= max(int(trips), 1)
    coll, dot_flops = analyze_hlo_text(compiled.as_text(), default_trip=1)
    coll_bf16 = coll.pop("_bf16norm", None)
    flops_total = dot_flops if dot_flops > 0 else flops_once * mult
    bytes_total = hbm_bytes if hbm_bytes is not None else bytes_once * mult
    mem = compiled.memory_analysis()
    mem_per_device = {
        "args_GB": mem.argument_size_in_bytes / 2**30,
        "out_GB": mem.output_size_in_bytes / 2**30,
        "temp_GB": mem.temp_size_in_bytes / 2**30,
        "alias_GB": mem.alias_size_in_bytes / 2**30,
        "peak_GB": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30,
    }
    compute_s = flops_total / hw["peak_flops"]
    memory_s = bytes_total / hw["hbm_bw"]
    collective_s = sum(coll.values()) / hw["ici_bw"]
    collective_bf16_s = (coll_bf16 / hw["ici_bw"]) if coll_bf16 is not None else collective_s
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    rep = RooflineReport(
        flops_per_device=flops_total,
        hbm_bytes_per_device=bytes_total,
        collective_bytes_per_device=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        mem_per_device=mem_per_device,
        cost_raw={"flops_body_once": flops_once, "bytes_body_once": bytes_once,
                  "loop_multiplier": mult, "dot_flops_parsed": dot_flops},
    )
    rep.collective_bf16_s = collective_bf16_s
    return rep


# ---------------------------------------------------------------------------
# analytic model FLOPs (6·N·D convention)
# ---------------------------------------------------------------------------

def model_flops(n_params_active: float, n_tokens: float, kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n_params_active * n_tokens


# ---------------------------------------------------------------------------
# analytic HBM traffic (per device, per step)
# ---------------------------------------------------------------------------
# XLA CPU's cost_analysis() "bytes accessed" counts every unfused
# intermediate — orders of magnitude above real TPU HBM traffic (fusions keep
# intermediates in VMEM).  The memory roofline term therefore uses this
# explicit traffic model; every constant is documented inline and the raw HLO
# number is retained in cost_raw for reference.

def analytic_hbm_bytes(cfg, shape, shard, mesh_cfg, n_params: int,
                       n_params_active: int) -> float:
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    msize = sizes.get("model", 1)
    dsize = 1
    for a in ("pod", "data"):
        dsize *= sizes.get(a, 1)

    d, L = cfg.d_model, cfg.n_layers
    Hk, hd = cfg.n_kv_heads, cfg.head_dim
    bytes_w = 2                                        # bf16 weights
    P_full = n_params * bytes_w
    P_act = n_params_active * bytes_w
    P_tp = P_full / msize                              # per-device compute weights
    P_act_tp = P_act / msize
    B, S = shape.global_batch, shape.seq_len
    tokens_dev = B * S / dsize
    kv_tok = 2 * Hk * hd * bytes_w                     # K+V bytes per token per layer
    qb = max(shard.attn_q_block, 1)

    if shape.kind == "train":
        mb = max(shard.microbatches, 1)
        tokens_mb = tokens_dev / mb
        # weights: fwd read + bwd dx/dw reads (+1 remat re-read), per microbatch
        w_reads = 4 if shard.remat == "block" else 3
        weights = P_act_tp * mb * w_reads
        # optimizer: params r+w (2), moments r+w (4 × moment bytes), grads read
        store_div = dsize if shard.zero1 else 1
        mom_b = 2 if shard.moment_dtype == "bfloat16" else 4
        opt = (P_full / msize / (dsize if shard.fsdp_params else 1)) * 2 \
            + (n_params * mom_b / msize / store_div) * 4 \
            + (n_params * 4 / msize / store_div)
        # grad accumulation buffer (fp32) read+write per microbatch
        acc = 2 * (n_params * 4 / msize / store_div) * mb if mb > 1 else 0.0
        # activations: saved block inputs + spilled intermediates, fwd+bwd
        # (≈8 residual-stream passes per layer with block remat)
        act = tokens_mb * d * 2 * L * 8 * mb
        # causal flash-attention KV re-streaming from HBM: q-block i re-reads
        # ~i·qb keys → Σ_i i·qb ≈ S²/(2·qb) key-tokens per layer per sequence;
        # backward re-streams once more (×2)
        kv_restream = 0.0
        if _n_attn_layers(cfg):
            win = cfg.window or S
            per_seq_tokens = min(S * S / (2 * qb), S * win / qb + S)
            kv_restream = 2 * (B / dsize / mb) * mb * _n_attn_layers(cfg) \
                * kv_tok * per_seq_tokens
        # embeddings + logits (fp32 logits read/write for the loss)
        vocab_io = tokens_dev * (cfg.d_model * 2 + cfg.vocab_size / msize * 4 * 2)
        return weights + opt + acc + act + kv_restream + vocab_io

    if shape.kind == "prefill":
        act = tokens_dev * d * 2 * L * 4
        cache_write = (B / dsize) * S * kv_tok * _n_attn_layers(cfg) / max(
            msize if shard.kv_seq_shard else 1, 1)
        kv_restream = (B / dsize) * _n_attn_layers(cfg) * kv_tok * (S * S / (2 * qb)) / S
        vocab_io = (B / dsize) * (cfg.vocab_size / msize) * 4
        return P_act_tp + act + cache_write + kv_restream + vocab_io

    # decode: read all (active) weights once + the live cache/state once
    cache_read = 0.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            cache_read += (B / dsize) * S * kv_tok / (msize if shard.kv_seq_shard else 1)
        elif kind == "local" and cfg.window:
            cache_read += (B / dsize) * min(cfg.window, S) * kv_tok
        elif kind == "rwkv":
            # WKV state (H, hd, hd) fp32, read+write
            cache_read += (B / dsize) * (d // cfg.rwkv_head_dim) * cfg.rwkv_head_dim ** 2 * 4 * 2
        elif kind == "rglru":
            cache_read += (B / dsize) * (cfg.lru_width or d) * 4 * 2
    act = (B / dsize) * d * 2 * L * 6
    vocab_io = (B / dsize) * (cfg.vocab_size / msize) * 4
    return P_act_tp + cache_read + act + vocab_io


def _n_attn_layers(cfg) -> int:
    return sum(1 for k in cfg.layer_kinds() if k in ("attn", "local"))
