"""Framework configuration system.

``ModelConfig`` is the single source of truth for an architecture: the model zoo,
the launcher, the dry-run, the roofline analyzer and the smoke tests all consume
it.  Architecture modules under ``repro.configs`` construct ``ModelConfig``
instances with the exact published shapes and register them with
``register_arch``; reduced smoke variants are derived with ``cfg.reduced()``.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, replace
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Block kinds understood by the unified transformer stack.
BLOCK_ATTN = "attn"          # global causal (or bidirectional for encoders) attention
BLOCK_LOCAL_ATTN = "local"   # sliding-window attention
BLOCK_RGLRU = "rglru"        # RecurrentGemma RG-LRU recurrent block
BLOCK_RWKV = "rwkv"          # RWKV6 time-mix block
VALID_BLOCKS = {BLOCK_ATTN, BLOCK_LOCAL_ATTN, BLOCK_RGLRU, BLOCK_RWKV}


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (None on a ModelConfig => dense MLP)."""

    n_experts: int
    top_k: int
    d_expert: int                   # hidden width of each routed expert
    n_shared_experts: int = 0       # always-on shared experts (Qwen2-MoE style)
    d_shared: int = 0               # total hidden width of the fused shared expert
    router_aux_weight: float = 0.001  # load-balance auxiliary loss weight
    capacity_factor: float = 1.25   # used by the capacity-based dispatch path


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    # trunk shape
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    # block pattern: repeated (cyclically) to cover n_layers.
    block_pattern: Sequence[str] = (BLOCK_ATTN,)
    window: Optional[int] = None    # sliding window size for BLOCK_LOCAL_ATTN
    # nonlinearity / norm
    activation: str = "swiglu"      # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0
    rope_type: str = "rope"         # rope | mrope | none
    mrope_sections: Optional[Sequence[int]] = None  # (t, h, w) half-dim sections
    # encoder-decoder
    enc_dec: bool = False
    n_encoder_layers: int = 0
    # recurrent families
    rwkv_head_dim: int = 64
    lru_width: Optional[int] = None  # RG-LRU recurrence width (defaults to d_model)
    conv_width: int = 4              # temporal conv width in RG-LRU blocks
    # modality frontend: None | "vision" | "audio".  Frontends are STUBS: the
    # model consumes precomputed patch/frame embeddings via input_specs().
    frontend: Optional[str] = None
    # embeddings
    tie_embeddings: bool = False
    # numerics
    dtype: str = "bfloat16"
    # citation tag from the assignment table
    source: str = ""

    # -- derived ------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA requires n_heads % n_kv_heads == 0"
        for b in self.block_pattern:
            assert b in VALID_BLOCKS, f"unknown block kind {b!r}"

    @property
    def attention_free(self) -> bool:
        return all(b in (BLOCK_RGLRU, BLOCK_RWKV) for b in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends over unbounded context (SSM / local-attn hybrid)."""
        return all(b != BLOCK_ATTN for b in self.block_pattern)

    @property
    def uses_kv_cache(self) -> bool:
        return any(b in (BLOCK_ATTN, BLOCK_LOCAL_ATTN) for b in self.block_pattern)

    def layer_kinds(self) -> list[str]:
        pat = list(self.block_pattern)
        reps = math.ceil(self.n_layers / len(pat))
        return (pat * reps)[: self.n_layers]

    # -- parameter accounting (used by roofline + memory planning) ----------
    def param_count(self) -> int:
        """Exact trunk parameter count (matches the initialized pytree)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab_size * d                      # token embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # output head
        per_layer_by_kind = {}
        for kind in set(self.layer_kinds()):
            per_layer_by_kind[kind] = self._block_params(kind)
        total += sum(per_layer_by_kind[k] for k in self.layer_kinds())
        total += d  # final norm
        if self.enc_dec:
            # encoder trunk: self-attn blocks + decoder cross-attn adds
            enc_block = self._block_params(BLOCK_ATTN) + self._mlp_params()
            # _block_params for attn already includes one MLP; encoder layers are
            # identical to decoder self-attn layers, so reuse directly:
            total += self.n_encoder_layers * self._block_params(BLOCK_ATTN)
            total += self.n_encoder_layers * 0
            # decoder cross-attention (q from d_model, kv from encoder d_model) + norm
            total += self.n_layers * (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                                      + self.n_heads * hd * d + d)
        return int(total)

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            per_expert = (3 * d * m.d_expert
                          if self.activation in ("swiglu", "geglu")
                          else 2 * d * m.d_expert)
            shared = 3 * d * m.d_shared if m.d_shared else 0
            return m.n_experts * per_expert + shared + d * m.n_experts  # + router
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * d * self.d_ff

    def _block_params(self, kind: str) -> int:
        d, hd = self.d_model, self.head_dim
        norms = 2 * d
        if kind in (BLOCK_ATTN, BLOCK_LOCAL_ATTN):
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
            return attn + self._mlp_params() + norms
        if kind == BLOCK_RGLRU:
            w = self.lru_width or d
            # linear in/out, gates (a and input gate), conv1d
            rec = 2 * d * w + 2 * w * w // 1 + self.conv_width * w + w
            return rec + self._mlp_params() + norms
        if kind == BLOCK_RWKV:
            # time-mix: r,k,v,g,o (5 d*d) + data-dependent decay LoRA (small) ;
            # channel-mix: k (d*ff) + v (ff*d) + r (d*d)
            tm = 5 * d * d + 6 * d * 32 * 2
            cm = 2 * d * self.d_ff + d * d
            return tm + cm + norms
        raise ValueError(kind)

    moe: Optional[MoEConfig] = None

    # -- reduced variants for CPU smoke tests -------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny config of the same family: same block pattern / features,
        small widths — used by per-arch smoke tests on CPU."""
        kw: dict = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 * len(self.block_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            window=min(self.window, 32) if self.window else None,
            lru_width=64 if self.lru_width else None,
            rwkv_head_dim=16,
            n_encoder_layers=2 if self.enc_dec else 0,
            dtype="float32",
        )
        if self.moe is not None:
            # capacity_factor 8: the smoke tests check prefill/decode/forward
            # consistency, which capacity drops would legitimately break
            kw["moe"] = replace(self.moe, n_experts=min(self.moe.n_experts, 8),
                                top_k=min(self.moe.top_k, 2), d_expert=32,
                                d_shared=64 if self.moe.d_shared else 0,
                                capacity_factor=8.0)
        if self.mrope_sections is not None:
            kw["mrope_sections"] = (4, 2, 2)  # sums to head_dim // 2
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Mesh / parallelism configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Sequence[int] = (16, 16)
    axes: Sequence[str] = ("data", "model")

    @property
    def n_devices(self) -> int:
        return int(math.prod(self.shape))

    @property
    def data_axes(self):
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


@dataclass(frozen=True)
class ShardingConfig:
    """Knobs the perf loop iterates on."""

    zero1: bool = True                  # shard optimizer state over data axes
    fsdp_params: bool = False           # additionally shard params over data axes (ZeRO-3 storage)
    seq_shard_residual: bool = False    # Megatron-SP: shard saved residuals over model axis
    remat: str = "block"                # none | block
    scan_layers: bool = True
    kv_seq_shard: bool = False          # shard KV cache sequence over model axis (flash-decode)
    moe_dispatch: str = "gather"        # gather (capacity-based) | dense (one-hot einsum)
    microbatches: int = 1               # gradient accumulation steps
    moment_dtype: str = "float32"       # Adam moment storage (bfloat16 halves optimizer memory)
    acc_dtype: str = "float32"          # gradient-accumulation buffer dtype
    pin_kv_layout: bool = False         # pin attention K/V to batch-sharded/seq-replicated
                                        # (§Perf cell 3: serve cells + big FSDP train only)
    attn_q_block: int = 512             # flash-attention tile sizes (per-cell tunable)
    attn_kv_block: int = 1024
    causal_skip: bool = True            # statically skip fully-masked KV blocks


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape suite)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_SUITE = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per the assignment rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention)"
    return True, "ok"


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}

ARCH_IDS = [
    "qwen3-moe-30b-a3b",
    "qwen2-moe-a2.7b",
    "rwkv6-3b",
    "recurrentgemma-9b",
    "qwen2-vl-72b",
    "qwen1.5-4b",
    "qwen1.5-0.5b",
    "stablelm-1.6b",
    "nemotron-4-340b",
    "seamless-m4t-large-v2",
]

_MODULE_FOR_ARCH = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = _MODULE_FOR_ARCH.get(name)
        if mod is None:
            # allow ad-hoc registered names (e.g. tiny pool members)
            importlib.import_module("repro.configs")
        else:
            importlib.import_module(mod)
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return list(ARCH_IDS)


# dataclass field ordering fix-up: `moe` was declared after methods above so it
# participates in replace()/asdict; verify it exists.
assert any(f.name == "moe" for f in dataclasses.fields(ModelConfig))
