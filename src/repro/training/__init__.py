from repro.training.optimizer import adamw, OptimizerState, clip_by_global_norm, cosine_schedule
