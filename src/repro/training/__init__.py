from repro.training.optimizer import OptimizerState, adamw, clip_by_global_norm, cosine_schedule
