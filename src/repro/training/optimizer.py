"""AdamW optimizer (pure JAX, pytree-based) with ZeRO-1 support hooks.

No optax in this environment — this is the framework's own optimizer substrate.
The API mirrors the (init, update) pair convention so the train loop and the
router trainer share it.

ZeRO-1: the train loop shards ``OptimizerState`` over the data axes by passing
sharded out_shardings for the optimizer state; moments live fp32 (sharded),
params bf16 (replicated over data, TP-sharded over model).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptimizerState(NamedTuple):
    step: jax.Array          # int32 scalar
    mu: any                  # first moment (pytree, fp32)
    nu: any                  # second moment (pytree, fp32)


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw(
    learning_rate: float | Callable = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: Optional[float] = None,
    moment_dtype=jnp.float32,
) -> Optimizer:
    """``moment_dtype=bfloat16`` halves optimizer memory (updates still
    computed in fp32) — required to fit 340B-class training on 16 GB chips."""
    lr_fn = learning_rate if callable(learning_rate) else (lambda _: learning_rate)
    mdt = jnp.dtype(moment_dtype)

    def init(params) -> OptimizerState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
        return OptimizerState(step=jnp.zeros((), jnp.int32), mu=zeros,
                              nu=jax.tree.map(jnp.copy, zeros))

    def update(grads, state: OptimizerState, params):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state.step + 1
        lr = lr_fn(step)
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            d = (m32 / b1t) / (jnp.sqrt(v32 / b2t) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * d).astype(p.dtype),
                    m32.astype(mdt), v32.astype(mdt))

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        mflat = treedef.flatten_up_to(state.mu)
        vflat = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptimizerState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update)
