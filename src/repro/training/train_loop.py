"""Distributed training loop: pjit train_step with TP/DP/EP sharding, ZeRO-1
optimizer-state sharding, gradient accumulation, checkpoint/restart.

``make_train_step`` builds the canonical step the multi-pod dry-run lowers:
    (params, opt_state, batch) -> (params, opt_state, metrics)
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.config import MeshConfig, ShardingConfig
from repro.models.transformer import Model
from repro.training.optimizer import Optimizer, OptimizerState


def batch_pspec(mesh_cfg: MeshConfig) -> P:
    dp = tuple(a for a in ("pod", "data") if a in mesh_cfg.axes)
    return P(dp)


def _dp_size(mesh_cfg: MeshConfig, dp: tuple) -> int:
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    n = 1
    for a in dp:
        n *= sizes.get(a, 1)
    return n


def _claim_dp(shape, pspec: P, dp: tuple, dp_n: int, start_dim: int) -> P:
    """Claim the data axes on the first unsharded, divisible dim ≥ start_dim.
    No-op if any dim already uses a data axis (a mesh axis may appear in at
    most one position of a PartitionSpec)."""
    parts = list(pspec) if len(pspec) else []
    parts = parts + [None] * (len(shape) - len(parts))
    used = {a for part in parts if part is not None
            for a in ((part,) if isinstance(part, str) else tuple(part))}
    if used & set(dp):
        return pspec
    for i in range(start_dim, len(parts)):
        if parts[i] is None and shape[i] % dp_n == 0 and shape[i] > 0:
            parts[i] = dp
            return P(*parts)
    return pspec


def zero1_pspecs(param_pspecs, abstract_params, mesh_cfg: MeshConfig,
                 shard_cfg: ShardingConfig):
    """Optimizer-moment shardings.  ZeRO-1: additionally shard each moment over
    the data axes on its first unsharded divisible dim (moments dominate
    training memory)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh_cfg.axes)
    if not shard_cfg.zero1 or not dp:
        return param_pspecs
    dp_n = _dp_size(mesh_cfg, dp)
    return jax.tree.map(lambda a, s: _claim_dp(a.shape, s, dp, dp_n, 0),
                        abstract_params, param_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def fsdp_param_pspecs(param_pspecs, abstract_params, mesh_cfg: MeshConfig,
                      shard_cfg: ShardingConfig):
    """ZeRO-3-style parameter *storage* sharding: claim the data axes on each
    weight's first unsharded divisible dim past the scan 'layers' dim.  GSPMD
    inserts the per-layer all-gathers (FSDP semantics); required to store
    340B-class weights on 16 GB chips."""
    if not shard_cfg.fsdp_params:
        return param_pspecs
    dp = tuple(a for a in ("pod", "data") if a in mesh_cfg.axes)
    if not dp:
        return param_pspecs
    dp_n = _dp_size(mesh_cfg, dp)
    return jax.tree.map(
        lambda a, s: _claim_dp(a.shape, s, dp, dp_n, 1) if len(a.shape) > 1 else s,
        abstract_params, param_pspecs, is_leaf=lambda x: isinstance(x, P))


def opt_state_pspecs(param_pspecs, abstract_params, mesh_cfg: MeshConfig,
                     shard_cfg: ShardingConfig) -> OptimizerState:
    mom = zero1_pspecs(param_pspecs, abstract_params, mesh_cfg, shard_cfg)
    return OptimizerState(step=P(), mu=mom, nu=mom)


def make_train_step(model: Model, opt: Optimizer, shard_cfg: ShardingConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation: ``shard_cfg.microbatches`` > 1 splits the batch on
    the leading axis and accumulates grads in fp32 via lax.scan (per-microbatch
    reduce keeps peak activation memory at one microbatch).
    """
    n_micro = shard_cfg.microbatches
    acc_dt = jnp.bfloat16 if shard_cfg.acc_dtype == "bfloat16" else jnp.float32

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if n_micro > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32), gsum)
            loss = lsum / n_micro
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                             for g in jax.tree.leaves(grads)))}
        return new_params, new_state, metrics

    return train_step


@dataclass
class Trainer:
    """Host-side loop: data pipeline in, checkpoints out, resume on restart."""

    model: Model
    opt: Optimizer
    shard_cfg: ShardingConfig
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3

    def __post_init__(self):
        self._step_fn = jax.jit(make_train_step(self.model, self.opt, self.shard_cfg),
                                donate_argnums=(0, 1))
        self._mgr = CheckpointManager(self.ckpt_dir, self.keep) if self.ckpt_dir else None

    def init_state(self, key):
        params = self.model.init(key)
        return params, self.opt.init(params)

    def restore_or_init(self, key):
        params, opt_state = self.init_state(key)
        start = 0
        if self._mgr and self._mgr.latest_step() is not None:
            (params, opt_state), start = self._mgr.restore((params, opt_state))
        return params, opt_state, start

    def fit(self, params, opt_state, batches, start_step: int = 0, log_every: int = 10):
        """batches: iterable of batch dicts.  Returns (params, opt_state, history)."""
        history = []
        t0 = time.time()
        step = start_step
        for batch in batches:
            params, opt_state, metrics = self._step_fn(params, opt_state, batch)
            step += 1
            if step % log_every == 0 or step == start_step + 1:
                history.append({"step": step, "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "time": time.time() - t0})
            if self._mgr and step % self.ckpt_every == 0:
                self._mgr.save((params, opt_state), step)
        if self._mgr:
            self._mgr.save((params, opt_state), step)
        return params, opt_state, history
