import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) cell
on the production meshes, prove memory fits, and extract roofline inputs.

The two lines above MUST stay first: jax locks the device count on first init.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun.json

Cells lower ``train_step`` (train shapes) or ``serve_step`` (prefill / decode
shapes: decode = one new token against a seq_len KV cache).  Sub-quadratic
``long_500k`` runs only for SSM/hybrid archs (full-attention archs record
SKIP, per the assignment).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import (
    analyze_compiled,
    analytic_hbm_bytes,
    cpu_upcast_bytes,
    model_flops,
)
from repro.config import MeshConfig
from repro.launch import specs as S
from repro.launch.mesh import make_mesh_from_config, mesh_config
from repro.models.layers import sanitize_pspec
from repro.models.transformer import Model
from repro.training.optimizer import OptimizerState, adamw
from repro.training.train_loop import fsdp_param_pspecs, make_train_step, opt_state_pspecs


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract_opt_state(abstract_params, moment_dtype):
    mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(moment_dtype)),
                       abstract_params)
    import copy
    return OptimizerState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=mom,
                          nu=copy.deepcopy(mom))


def lower_cell(cfg, shape, mesh, mesh_cfg: MeshConfig, verbose: bool = True):
    """Lower + compile one cell.  Returns a result dict (or raises)."""
    shard = S.shard_preset(cfg, shape)
    model = Model(cfg, shard, mesh=mesh)
    abstract_params = model.abstract_params()
    pspecs = fsdp_param_pspecs(model.param_pspecs(mesh_cfg), abstract_params,
                               mesh_cfg, shard)
    dp = S.dp_axes(mesh_cfg, shape.global_batch)
    n_groups = max(model.n_groups, 1)
    known_loops = {"layer_scan": n_groups}

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt = adamw(1e-4, moment_dtype=shard.moment_dtype)
            step = make_train_step(model, opt, shard)
            batch = S.batch_inputs(cfg, shape)
            b_ps = S.batch_pspecs(cfg, mesh_cfg, shape.global_batch)
            opt_ps = opt_state_pspecs(pspecs, abstract_params, mesh_cfg, shard)
            in_sh = (_named(mesh, pspecs), _named(mesh, opt_ps), _named(mesh, b_ps))
            out_sh = (_named(mesh, pspecs), _named(mesh, opt_ps), None)
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(
                abstract_params, _abstract_opt_state(abstract_params, shard.moment_dtype),
                batch)
            known_loops["microbatches"] = shard.microbatches
            n_tokens = shape.global_batch * shape.seq_len
            mf_kind = "train"
        elif shape.kind == "prefill":
            inputs = S.prefill_inputs(cfg, shape)
            enc_len = S.enc_len_for(cfg, shape)
            cache_ps = model.cache_pspecs(mesh_cfg, shape.global_batch, shape.seq_len,
                                          enc_len)
            logits_ps = sanitize_pspec((shape.global_batch, 1, model.vocab_padded),
                                       P(dp, None, "model"), mesh_cfg)

            if cfg.enc_dec:
                def serve_step(params, tokens, enc_embeds):
                    return model.prefill(params, tokens, shape.seq_len,
                                         enc_inputs=enc_embeds)
                args = (abstract_params, inputs["tokens"], inputs["enc_embeds"])
                in_sh = (_named(mesh, pspecs),
                         NamedSharding(mesh, P(dp, None)),
                         NamedSharding(mesh, P(dp, None, None)))
            else:
                def serve_step(params, tokens):
                    return model.prefill(params, tokens, shape.seq_len)
                args = (abstract_params, inputs["tokens"])
                tok_ps = P(dp, None, None) if cfg.frontend == "vision" else P(dp, None)
                in_sh = (_named(mesh, pspecs), NamedSharding(mesh, tok_ps))
            out_sh = (NamedSharding(mesh, logits_ps), _named(mesh, cache_ps))
            lowered = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
            n_tokens = shape.global_batch * shape.seq_len
            mf_kind = "serve"
        else:  # decode
            enc_len = S.enc_len_for(cfg, shape)
            cache_ps = model.cache_pspecs(mesh_cfg, shape.global_batch, shape.seq_len,
                                          enc_len)
            abstract_cache = model.abstract_cache(shape.global_batch, shape.seq_len,
                                                  enc_len)
            inputs = S.decode_inputs(cfg, shape)
            tok_ps = P(dp, None, None) if cfg.frontend == "vision" else P(dp, None)
            in_sh = (_named(mesh, pspecs), NamedSharding(mesh, tok_ps),
                     _named(mesh, cache_ps))
            logits_ps = sanitize_pspec((shape.global_batch, 1, model.vocab_padded),
                                       P(dp, None, "model"), mesh_cfg)
            out_sh = (NamedSharding(mesh, logits_ps), _named(mesh, cache_ps))
            lowered = jax.jit(model.decode_step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(
                abstract_params, inputs["tokens"], abstract_cache)
            n_tokens = shape.global_batch          # one token per sequence
            mf_kind = "serve"
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    n_params = model.param_count()
    active = n_params
    if cfg.moe is not None:
        m = cfg.moe
        gated = 3 if cfg.activation in ("swiglu", "geglu") else 2
        expert_params = cfg.n_layers * m.n_experts * gated * cfg.d_model * m.d_expert
        active_experts = cfg.n_layers * m.top_k * gated * cfg.d_model * m.d_expert
        active = n_params - expert_params + active_experts
    hbm = analytic_hbm_bytes(cfg, shape, shard, mesh_cfg, n_params, active)
    rep = analyze_compiled(compiled, known_loops=known_loops, hbm_bytes=hbm)
    # XLA-CPU upcasts bf16 dot operands to f32 and hoists whole-stack converts
    # out of the layer scan; on TPU these buffers do not exist.  Report both.
    upcast = cpu_upcast_bytes(compiled.as_text(), n_groups)
    rep.mem_per_device["cpu_upcast_GB"] = upcast / 2**30
    floor = rep.mem_per_device["args_GB"] + rep.mem_per_device["out_GB"]
    rep.mem_per_device["peak_tpu_est_GB"] = max(
        rep.mem_per_device["peak_GB"] - upcast / 2**30, floor)
    mf = model_flops(active, n_tokens, mf_kind)
    chips = mesh_cfg.n_devices
    hlo_global_flops = rep.flops_per_device * chips
    result = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(map(str, mesh_cfg.shape)),
        "status": "ok",
        "params_B": n_params / 1e9,
        "active_params_B": active / 1e9,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "model_flops": mf,
        "hlo_flops_global": hlo_global_flops,
        "useful_ratio": mf / hlo_global_flops if hlo_global_flops else None,
        "mem": rep.mem_per_device,
        "roofline": rep.summary(),
        "shard": {k: getattr(S.shard_preset(cfg, shape), k) for k in
                  ("fsdp_params", "seq_shard_residual", "microbatches", "kv_seq_shard",
                   "moment_dtype", "moe_dispatch", "remat")},
    }
    if verbose:
        r = rep.summary()
        print(f"  {cfg.name} × {shape.name} [{result['mesh']}]: "
              f"compile {t_compile:.0f}s peak {rep.mem_per_device['peak_tpu_est_GB']:.1f}"
              f"({rep.mem_per_device['peak_GB']:.1f})GB/chip "
              f"compute {r['compute_s']:.3f}s mem {r['memory_s']:.3f}s "
              f"coll {r['collective_s']:.3f}s → {r['dominant']} "
              f"useful {result['useful_ratio'] and round(result['useful_ratio'], 2)}",
              flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append((mesh_config(multi_pod=False), False))
    if args.mesh in ("multi", "both"):
        meshes.append((mesh_config(multi_pod=True), True))

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for mesh_cfg, multi in meshes:
        mesh = make_mesh_from_config(mesh_cfg)
        mesh_tag = "x".join(map(str, mesh_cfg.shape))
        print(f"== mesh {mesh_tag} ({mesh_cfg.n_devices} chips) ==", flush=True)
        for cfg, shape, ok, why in S.iter_cells(args.arch, args.shape):
            key = (cfg.name, shape.name, mesh_tag)
            if key in done:
                continue
            if not ok:
                results.append({"arch": cfg.name, "shape": shape.name,
                                "mesh": mesh_tag, "status": why})
                print(f"  {cfg.name} × {shape.name}: {why}", flush=True)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                continue
            try:
                results.append(lower_cell(cfg, shape, mesh, mesh_cfg))
            except Exception as e:   # noqa: BLE001 — record and continue
                results.append({"arch": cfg.name, "shape": shape.name,
                                "mesh": mesh_tag, "status": "error",
                                "error": f"{type(e).__name__}: {e}"})
                print(f"  {cfg.name} × {shape.name}: ERROR {type(e).__name__}: "
                      f"{str(e)[:300]}", flush=True)
                traceback.print_exc()
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"].startswith("SKIP"))
    n_err = len(results) - n_ok - n_skip
    print(f"dry-run complete: {n_ok} ok, {n_skip} skip, {n_err} error -> {args.out}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
