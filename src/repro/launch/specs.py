"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) cell,
plus per-cell sharding presets.

No device allocation happens here — the dry-run lowers/compiles purely from
abstract shapes (the shannon/kernels pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import (
    SHAPE_SUITE,
    MeshConfig,
    ModelConfig,
    ShapeConfig,
    ShardingConfig,
    get_arch,
    shape_applicable,
)

# encoder frame count for the enc-dec audio arch (≈30 s at 50 Hz + margin;
# train uses seq_len frames to exercise the full encoder)
AUDIO_ENC_FRAMES = 1536


def shard_preset(cfg: ModelConfig, shape: ShapeConfig) -> ShardingConfig:
    """Per-cell parallelism preset (the §Perf baselines).

    Rationale (memory-driven, see DESIGN.md):
      * ≥70B params: FSDP param storage + bf16 Adam moments + Megatron-SP
        saved-activation sharding + deep grad accumulation.
      * MoE: FSDP + moderate accumulation (expert weights dominate).
      * decode cells: flash-decode KV sequence sharding for global-attention
        archs (the KV cache is the footprint).
    """
    big = cfg.param_count() > 6e10
    moe = cfg.moe is not None
    kw: dict = {}
    if moe and cfg.moe.n_experts % 16 == 0 and shape.kind != "train":
        # §Perf cell 1: expert-parallel all-to-all dispatch (per-shard local
        # ranking + one token A2A) replaces the naive activation gathers.
        # (train ablation below decides the train-side dispatch)
        kw.update(moe_dispatch="ep")
    if shape.kind == "train":
        # K/V layout pinning measured beneficial only for the FSDP+SP big-model
        # train cells (nemotron 444 s pinned vs 620 s unpinned); small dense /
        # MoE train cells regress with it (§Perf post-sweep ablation)
        kw.update(pin_kv_layout=big)
        if big:
            # §Perf cell 2: FSDP weight gathers repeat per microbatch, so fewer
            # larger microbatches cut the dominant collective term ~4×; SP
            # keeps the per-microbatch activations small enough to afford it,
            # and bf16 accumulation buffers keep the optimizer state in budget.
            kw.update(fsdp_params=True, seq_shard_residual=True, microbatches=4,
                      moment_dtype="bfloat16", acc_dtype="bfloat16")
        elif moe:
            kw.update(fsdp_params=True, microbatches=8)
        elif cfg.param_count() > 5e9:
            kw.update(fsdp_params=True, microbatches=4)
        elif cfg.family in ("ssm", "hybrid"):
            # chunked recurrences materialize per-chunk pair tensors; deeper
            # accumulation keeps the per-microbatch working set in budget
            kw.update(microbatches=8)
        else:
            kw.update(microbatches=2)
        kw.update(remat="block")
    else:
        kw.update(remat="none", microbatches=1, pin_kv_layout=True)
        if big:
            kw.update(fsdp_params=True)
        if shape.kind == "decode" and cfg.uses_kv_cache and not cfg.sub_quadratic:
            kw.update(kv_seq_shard=True)
        if shape.name == "prefill_32k":
            kw.update(attn_q_block=2048, attn_kv_block=2048,
                      kv_seq_shard=not cfg.sub_quadratic)
        if shape.name == "long_500k":
            kw.update(attn_q_block=2048, attn_kv_block=2048)
    return ShardingConfig(**kw)


def batch_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract train-batch inputs {name: ShapeDtypeStruct}."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.enc_dec:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, min(AUDIO_ENC_FRAMES, S), cfg.d_model),
                                                   jnp.bfloat16)
    elif cfg.frontend == "vision":
        # stub frontend: precomputed patch+text embeddings
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return specs


def dp_axes(mesh_cfg: MeshConfig, batch: int):
    """Data axes for a batch dim, dropped when not divisible (e.g. batch 1)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh_cfg.axes)
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    n = 1
    for a in dp:
        n *= sizes[a]
    return dp if (dp and batch % n == 0) else None


def batch_pspecs(cfg: ModelConfig, mesh_cfg: MeshConfig, batch: int = 0) -> dict:
    dp = dp_axes(mesh_cfg, batch) if batch else tuple(
        a for a in ("pod", "data") if a in mesh_cfg.axes)
    out = {"labels": P(dp, None)}
    if cfg.enc_dec:
        out["tokens"] = P(dp, None)
        out["enc_embeds"] = P(dp, None, None)
    elif cfg.frontend == "vision":
        out["embeds"] = P(dp, None, None)
    else:
        out["tokens"] = P(dp, None)
    return out


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    if cfg.frontend == "vision":
        return {"tokens": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if cfg.frontend == "vision":
        out["tokens"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.enc_dec:
        out["enc_embeds"] = jax.ShapeDtypeStruct((B, AUDIO_ENC_FRAMES, cfg.d_model), jnp.bfloat16)
    return out


def enc_len_for(cfg: ModelConfig, shape: ShapeConfig) -> int:
    return AUDIO_ENC_FRAMES if cfg.enc_dec else 0


def iter_cells(arch_filter: str = "all", shape_filter: str = "all"):
    """All (arch, shape) cells with applicability verdicts."""
    from repro.config import ARCH_IDS

    archs = ARCH_IDS if arch_filter == "all" else [arch_filter]
    shapes = list(SHAPE_SUITE) if shape_filter == "all" else [shape_filter]
    for a in archs:
        cfg = get_arch(a)
        for s in shapes:
            shape = SHAPE_SUITE[s]
            ok, why = shape_applicable(cfg, shape)
            yield cfg, shape, ok, why
