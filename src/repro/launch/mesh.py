"""Production meshes.

Functions (never module-level constants) so importing this module never
touches jax device state.  The single-pod production mesh is 16×16 = 256
chips (data × model); the multi-pod mesh adds a leading pod axis:
2 × 16 × 16 = 512 chips.  Pods are data-parallel replicas by default (the
"pod" axis joins "data" in every batch/optimizer sharding rule), which keeps
cross-pod traffic to gradient reduction — the right default for DCN-connected
pods.
"""
from __future__ import annotations

import jax

try:                                   # jax ≥ 0.5 takes explicit axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: meshes are Auto implicitly
    AxisType = None

from repro.config import MeshConfig


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    if multi_pod:
        return MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))
    return MeshConfig(shape=(16, 16), axes=("data", "model"))


def make_mesh_from_config(cfg: MeshConfig):
    return _make_mesh(tuple(cfg.shape), tuple(cfg.axes))
