"""Serving launcher: bring up a continuous-batching engine for an architecture
and serve a batched-prompt workload (Robatch's data plane as a CLI).

    PYTHONPATH=src python -m repro.launch.serve --arch tiny-m --requests 12
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-s")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-prompt", type=int, default=0,
                    help="pack N queries per request (batch prompting); 0 = single")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--reduced", action="store_true",
                    help="serve the reduced (smoke) config of a big arch")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.config import ShardingConfig, get_arch
    from repro.models.transformer import Model
    from repro.serving.batcher import BatchPromptFormatter
    from repro.serving.engine import Request, ServingEngine

    cfg = get_arch(args.arch)
    if args.reduced or cfg.param_count() > 5e7:
        cfg = cfg.reduced()
    if cfg.vocab_size < 259 or cfg.enc_dec or cfg.frontend:
        raise SystemExit(f"{cfg.name}: byte-tokenizer text serving needs a plain "
                         f"decoder with vocab ≥ 259 (use tiny-s/m/l or --reduced dense archs)")
    model = Model(cfg, ShardingConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_slots=args.slots, max_len=args.max_len)
    fmt = BatchPromptFormatter("Answer each question.")

    rng = np.random.default_rng(0)
    prompts = []
    for i in range(args.requests):
        qs = [f"{rng.integers(0, 99)}+{rng.integers(0, 99)}"
              for _ in range(max(args.batch_prompt, 1))]
        prompts.append(fmt.format(qs) if args.batch_prompt else fmt.tokenizer.encode(qs[0]))
    reqs = [Request(rid=i, tokens=p, max_new=args.max_new) for i, p in enumerate(prompts)]

    t0 = time.time()
    engine.serve(reqs)
    dt = time.time() - t0
    tok = fmt.tokenizer
    done = sum(r.done for r in reqs)
    out_toks = sum(len(r.out_tokens) for r in reqs)
    print(f"{cfg.name}: served {done}/{len(reqs)} requests "
          f"({out_toks} tokens) in {dt:.1f}s via {args.slots} slots")
    for r in reqs[:3]:
        print(f"  req{r.rid}: prompt {len(r.tokens)} toks -> "
              f"{tok.decode(r.out_tokens)[:48]!r}")


if __name__ == "__main__":
    main()
