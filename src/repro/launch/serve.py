"""Serving launcher: RoBatch's data plane as a CLI, in two modes.

``engine`` — bring up one continuous-batching engine for an architecture and
serve a batched-prompt workload (the original single-model path)::

    PYTHONPATH=src python -m repro.launch.serve engine --arch tiny-m --requests 12

``online`` — the full online serving layer: build the pool a spec describes,
fit the modeling stage once, then stream a Poisson arrival workload through
the pluggable policy under a rolling budget, the response cache and the
circuit breakers::

    PYTHONPATH=src python -m repro.launch.serve online --task agnews --qps 40 \
        --duration 20 --window 0.25 --budget-x 3.0
    PYTHONPATH=src python -m repro.launch.serve online --policy routellm
    PYTHONPATH=src python -m repro.launch.serve online --spec run.json

``--realtime`` paces the stream against the wall clock: a live Poisson
arrival thread (``LiveArrivalSource``) submits the same seeded stream at its
due times while the server fires one scheduling round per window boundary —
the run takes ~``--duration`` wall seconds and reports window-pacing lateness.
``--replicas N`` builds every pool member as an N-engine ``ReplicaSet``
(least-loaded dispatch, per-window capacity caps in the scheduler).
``--autoscale`` sizes the pool at serving time: a backlog-driven control
loop (``repro.serving.autoscale``) grows each ReplicaSet under capacity
pressure and drains it back when idle, between ``--min-replicas`` and
``--max-replicas``::

    PYTHONPATH=src python -m repro.launch.serve online --qps 40 \
        --autoscale --min-replicas 1 --max-replicas 4

``--semantic-cache`` adds the embedding-space near-duplicate response cache
(``repro.serving.semcache``) behind the exact-match one; ``--sim-threshold``
sets its cosine hit threshold (docs/caching.md)::

    PYTHONPATH=src python -m repro.launch.serve online --qps 40 \
        --semantic-cache --sim-threshold 0.9

``http`` — the OpenAI-compatible HTTP front-end (``repro.http``): fit the
same control plane, then serve it over the wire — ``POST
/v1/chat/completions`` (SSE streaming with ``"stream": true``), ``GET
/v1/models``, ``GET /healthz`` and Prometheus ``GET /metrics`` — until
SIGINT/SIGTERM (or ``--max-seconds``)::

    PYTHONPATH=src python -m repro.launch.serve http --port 8000
    PYTHONPATH=src python -m repro.launch.serve http --port 0 --policy robatch \
        --replicas 2 --autoscale --max-replicas 4
    curl -N localhost:8000/v1/chat/completions -d \
        '{"messages":[{"role":"user","content":"#7"}],"stream":true}'

``--port 0`` binds an ephemeral port (printed on the ``listening on`` line —
how ``tools/smoke.sh`` runs it).

``--policy`` selects any name from the policy registry
(``repro.api.list_policies()``); ``--spec`` takes a ``RunSpec`` JSON (a file
path or an inline JSON string) and subsumes the individual flags.  Legacy
flag-only invocations (no subcommand) default to ``engine`` mode, and the
pre-spec flags (``--task``/``--family``/``--n-train``/``--coreset``/
``--seed``) keep working as a deprecation shim that overrides the spec.
"""
import argparse
import sys
import time


def engine_main(argv):
    ap = argparse.ArgumentParser(prog="serve engine")
    ap.add_argument("--arch", default="tiny-s")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-prompt", type=int, default=0,
                    help="pack N queries per request (batch prompting); 0 = single")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--decode-block", type=int, default=8,
                    help="tokens generated per fused on-device decode dispatch")
    ap.add_argument("--contiguous", action="store_true",
                    help="use the contiguous (max_slots, max_len) KV layout "
                         "instead of the default paged block pool")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page in the paged layout")
    ap.add_argument("--reduced", action="store_true",
                    help="serve the reduced (smoke) config of a big arch")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep the k highest-probability tokens (0 = all)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = all)")
    ap.add_argument("--gen-seed", type=int, default=0,
                    help="PRNG seed for sampled decoding (same seed ⇒ "
                         "bit-identical streams at any decode block/slot count)")
    ap.add_argument("--draft-member", default="",
                    help="arch whose model drafts for --arch via speculative "
                         "decoding (e.g. tiny-s drafting for tiny-m)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculation depth with --draft-member")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.config import ShardingConfig, get_arch
    from repro.models.transformer import Model
    from repro.serving.batcher import BatchPromptFormatter
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.generation import GenerationConfig

    cfg = get_arch(args.arch)
    if args.reduced or cfg.param_count() > 5e7:
        cfg = cfg.reduced()
    if cfg.vocab_size < 259 or cfg.enc_dec or cfg.frontend:
        raise SystemExit(f"{cfg.name}: byte-tokenizer text serving needs a plain "
                         f"decoder with vocab ≥ 259 (use tiny-s/m/l or --reduced dense archs)")
    model = Model(cfg, ShardingConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    paged = not args.contiguous
    if paged and (cfg.enc_dec or any(k != "attn" for k in cfg.layer_kinds())):
        print(f"{cfg.name}: paged KV needs a decoder-only global-attention "
              f"stack; falling back to the contiguous layout")
        paged = False
    if args.draft_member:
        from repro.serving.speculative import SpeculativeEngine

        dcfg = get_arch(args.draft_member)
        if not paged:
            raise SystemExit("--draft-member needs the paged KV layout "
                             "(drop --contiguous)")
        dmodel = Model(dcfg, ShardingConfig(remat="none"))
        dparams = dmodel.init(jax.random.PRNGKey(0))
        engine = SpeculativeEngine(model, params, dmodel, dparams,
                                   max_slots=args.slots, max_len=args.max_len,
                                   spec_k=args.spec_k,
                                   page_size=args.page_size)
    else:
        engine = ServingEngine(model, params, max_slots=args.slots,
                               max_len=args.max_len,
                               decode_block=args.decode_block,
                               paged=paged, page_size=args.page_size)
    fmt = BatchPromptFormatter("Answer each question.")

    gen = None
    if args.temperature > 0 or args.top_k > 0 or args.top_p < 1.0:
        gen = GenerationConfig(max_new=args.max_new,
                               temperature=args.temperature,
                               top_k=args.top_k, top_p=args.top_p,
                               seed=args.gen_seed)
    rng = np.random.default_rng(0)
    prompts = []
    for i in range(args.requests):
        qs = [f"{rng.integers(0, 99)}+{rng.integers(0, 99)}"
              for _ in range(max(args.batch_prompt, 1))]
        prompts.append(fmt.format(qs) if args.batch_prompt else fmt.tokenizer.encode(qs[0]))
    reqs = [Request(rid=i, tokens=p, max_new=args.max_new, gen=gen)
            for i, p in enumerate(prompts)]

    t0 = time.time()
    engine.serve(reqs)
    dt = time.time() - t0
    tok = fmt.tokenizer
    done = sum(r.done for r in reqs)
    out_toks = sum(len(r.out_tokens) for r in reqs)
    print(f"{cfg.name}: served {done}/{len(reqs)} requests "
          f"({out_toks} tokens) in {dt:.1f}s via {args.slots} slots")
    occ = engine.kv_occupancy()
    if occ.get("paged"):
        print(f"  kv pages: {occ['pages_used']}/{occ['n_pages']} live "
              f"(peak {occ['peak_pages']}), {occ['prefix_shares']} prefix "
              f"shares, {occ['cow_forks']} CoW forks")
    if hasattr(engine, "accept_rate"):
        print(f"  speculative: k={engine.spec_k} rounds={engine.n_rounds} "
              f"accept={engine.accept_rate():.2f} bonus={engine.n_bonus}")
    for r in reqs[:3]:
        print(f"  req{r.rid}: prompt {len(r.tokens)} toks -> "
              f"{tok.decode(r.out_tokens)[:48]!r}")


def _add_robust_flags(ap):
    """Shared online/http uncertainty-robust scheduling flags (they land on
    the PolicySpec params, so --spec files can declare the same fields)."""
    ap.add_argument("--robust-lambda", type=float, default=None,
                    help="uncertainty penalty λ of the robust frontier walk "
                         "(utility − λ·σ); 0 = the point-estimate walk "
                         "(docs/robustness.md)")
    ap.add_argument("--cost-margin", type=float, default=None,
                    help="worst-case budget margin: the walk draws the window "
                         "budget down at cost·(1+margin)")


def _apply_robust_flags(prog, spec, args):
    if args.robust_lambda is None and args.cost_margin is None:
        return
    params = dict(spec.policy.params)
    if args.robust_lambda is not None:
        params["robust"] = args.robust_lambda
    if args.cost_margin is not None:
        params["cost_margin"] = args.cost_margin
    spec.policy.params = params


def _add_generation_flags(ap):
    """Shared online/http sampling + speculative-decoding flags (they land on
    the PoolSpec, so --spec files can declare the same fields)."""
    ap.add_argument("--temperature", type=float, default=None,
                    help="default sampling temperature for real pool members "
                         "(0 = greedy)")
    ap.add_argument("--top-p", type=float, default=None,
                    help="nucleus sampling mass (1.0 = all)")
    ap.add_argument("--gen-seed", type=int, default=None,
                    help="PRNG seed for sampled decoding")
    ap.add_argument("--draft-member", default=None,
                    help="tiny pool: cheap member that drafts for the more "
                         "expensive ones (routed speculative decoding)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="speculation depth with --draft-member (default 4)")


def _apply_generation_flags(prog, spec, args):
    if args.temperature is not None:
        spec.pool.temperature = args.temperature
    if args.top_p is not None:
        spec.pool.top_p = args.top_p
    if args.gen_seed is not None:
        spec.pool.gen_seed = args.gen_seed
    if args.draft_member is not None:
        if spec.pool.kind != "tiny":
            raise SystemExit(f"{prog}: --draft-member needs the tiny real "
                             f"pool (kind='tiny'), not {spec.pool.kind!r}")
        spec.pool.draft_member = args.draft_member
    if args.spec_k is not None:
        spec.pool.spec_k = args.spec_k


def _online_spec(args):
    """Resolve the RunSpec: --spec JSON (file or inline) as the base, legacy
    per-field flags as a deprecation shim layered on top."""
    from repro.api import PolicySpec, PoolSpec, RunSpec

    legacy = {k: v for k, v in [("task", args.task), ("family", args.family),
                                ("n_train", args.n_train), ("coreset", args.coreset),
                                ("seed", args.seed)] if v is not None}
    if args.spec:
        if args.spec.lstrip().startswith("{"):
            text = args.spec                 # inline JSON
        else:
            with open(args.spec) as f:       # else a file path: a typo should
                text = f.read()              # fail as file-not-found, not JSON
        spec = RunSpec.from_json(text)
        if legacy:
            print(f"serve online: legacy flags {sorted(legacy)} override the "
                  f"spec (deprecated; prefer editing --spec)")
            if "task" in legacy:
                spec.pool.task = legacy["task"]
            if "family" in legacy:
                spec.pool.family = legacy["family"]
            if "n_train" in legacy:
                spec.pool.n_train = legacy["n_train"]
            if "coreset" in legacy:
                spec.coreset_size = legacy["coreset"]
            if "seed" in legacy:
                spec.seed = spec.pool.seed = legacy["seed"]
    else:
        spec = RunSpec(
            pool=PoolSpec(task=legacy.get("task", "agnews"),
                          family=legacy.get("family", "qwen3"),
                          n_train=legacy.get("n_train", 512), n_val=128,
                          n_test=512, seed=legacy.get("seed", 0)),
            router="knn", coreset_size=legacy.get("coreset", 64),
            seed=legacy.get("seed", 0))
    if args.policy is not None:
        spec.policy = PolicySpec(args.policy)
    return spec


def online_main(argv):
    ap = argparse.ArgumentParser(prog="serve online")
    ap.add_argument("--policy", default=None,
                    help="registered policy name (repro.api.list_policies())")
    ap.add_argument("--spec", default=None,
                    help="RunSpec JSON — a file path or an inline JSON string")
    ap.add_argument("--task", default=None, help="workload benchmark name")
    ap.add_argument("--family", default=None, help="simulated pool family")
    ap.add_argument("--qps", type=float, default=40.0, help="offered load")
    ap.add_argument("--duration", type=float, default=20.0, help="stream length (s, virtual)")
    ap.add_argument("--window", type=float, default=0.25, help="admission window (s)")
    ap.add_argument("--budget-x", type=float, default=3.0,
                    help="budget rate = qps × cheapest-state cost × this factor")
    ap.add_argument("--repeat-frac", type=float, default=0.2,
                    help="fraction of arrivals re-asking an earlier query (cache hits)")
    ap.add_argument("--realtime", action="store_true",
                    help="pace against the wall clock behind a live arrival thread")
    ap.add_argument("--replicas", type=int, default=None,
                    help="engines per pool member (ReplicaSet when > 1)")
    ap.add_argument("--autoscale", action="store_true",
                    help="backlog-driven replica autoscaling (ReplicaSet."
                         "scale_to between --min-replicas and --max-replicas)")
    ap.add_argument("--min-replicas", type=int, default=None,
                    help="autoscale floor (default 1)")
    ap.add_argument("--max-replicas", type=int, default=None,
                    help="autoscale ceiling (default 4 with --autoscale)")
    ap.add_argument("--semantic-cache", action="store_true",
                    help="embedding-space near-duplicate response cache "
                         "(repro.serving.semcache; see docs/caching.md)")
    ap.add_argument("--sim-threshold", type=float, default=None,
                    help="semantic-cache cosine hit threshold (default 0.92)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="wrap every pool member in a seeded ChaosMember fault "
                         "injector (latency noise everywhere, a short error "
                         "burst on the most expensive member) — the smoke "
                         "suite's degraded-path leg (docs/robustness.md)")
    _add_generation_flags(ap)
    _add_robust_flags(ap)
    ap.add_argument("--n-train", type=int, default=None)
    ap.add_argument("--coreset", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    args = ap.parse_args(argv)

    import numpy as np

    from repro.api import Gateway, UnknownPolicyError, get_policy, list_policies
    from repro.data import BENCHMARKS
    from repro.serving.online import OnlineConfig, poisson_arrivals

    if args.qps <= 0:
        raise SystemExit("serve online: --qps must be positive")
    spec = _online_spec(args)
    if args.replicas is not None:
        spec.pool.replicas = args.replicas
    if args.min_replicas is not None:
        spec.pool.min_replicas = args.min_replicas
    if args.max_replicas is not None:
        spec.pool.max_replicas = args.max_replicas
    if args.autoscale and spec.pool.max_replicas <= 0:
        spec.pool.max_replicas = 4               # sensible default ceiling
    if args.semantic_cache:
        spec.pool.semantic_cache = True
    if args.sim_threshold is not None:
        spec.pool.semantic_cache = True
        spec.pool.sim_threshold = args.sim_threshold
    _apply_generation_flags("serve online", spec, args)
    _apply_robust_flags("serve online", spec, args)
    if spec.pool.kind == "simulated" and spec.pool.task not in BENCHMARKS:
        raise SystemExit(f"serve online: unknown task {spec.pool.task!r}; "
                         f"known: {sorted(BENCHMARKS)}")
    try:
        get_policy(spec.policy.name)
    except UnknownPolicyError:
        raise SystemExit(f"serve online: unknown policy {spec.policy.name!r}; "
                         f"known: {list_policies()}")

    gw = Gateway.from_spec(spec)
    print(f"fitting RoBatch on {spec.pool.task}/{spec.pool.family} "
          f"({spec.pool.n_train} train, coreset {spec.coreset_size})...")
    gw.fit()
    rb = gw.robatch

    test = gw.wl.subset_indices("test")
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect, test).mean())
    rate = args.qps * base * args.budget_x
    autoscale = spec.pool.autoscale_policy() if args.autoscale else None
    cfg = OnlineConfig(budget_per_s=rate, window_s=args.window,
                       realtime=args.realtime, autoscale=autoscale)
    rng = np.random.default_rng(spec.seed)
    arrivals = poisson_arrivals(rng, args.qps, args.duration, test,
                                repeat_frac=args.repeat_frac)
    mode = "live wall-clock" if args.realtime else "virtual-clock"
    print(f"streaming {len(arrivals)} arrivals at {args.qps} qps ({mode}) "
          f"through policy={spec.policy.name}, window {args.window}s, "
          f"budget ${rate:.6f}/s...")
    chaos = None
    if args.chaos is not None:
        from repro.serving.fault import ChaosMember

        # latency noise everywhere; a short (sub-breaker-threshold) error
        # burst on the most expensive member so the degraded path exercises
        # reroutes while every breaker ends the run CLOSED
        last = len(gw.pool) - 1
        chaos = [ChaosMember(m, seed=args.chaos + k, latency_noise_s=0.002,
                             fail_from=1 if k == last else 10**9,
                             fail_until=3 if k == last else 10**9)
                 for k, m in enumerate(gw.pool)]
    t_wall = time.monotonic()
    stats = gw.serve(arrivals, cfg, live=args.realtime, pool=chaos)
    wall = time.monotonic() - t_wall
    srv = gw.server

    print(stats.summary())
    if args.realtime:
        late = [w.late_s for w in stats.windows]
        print(f"realtime: {wall:.2f}s wall for a {args.duration:.0f}s stream · "
              f"{len(late)} windows · max window lateness "
              f"{max(late, default=0.0) * 1e3:.1f}ms")
        if getattr(srv, "pacer_leaked", False):
            print("serve online: WARNING arrival pacer thread leaked past "
                  "shutdown join", file=sys.stderr)
    by_model = {}
    for r in srv.completed:
        if r.model is not None and not r.cache_hit:
            key = (srv.pool[r.model].name, r.batch)
            by_model[key] = by_model.get(key, 0) + 1
    print("dispatch mix (model, batch) -> queries:")
    for key in sorted(by_model, key=lambda t: (t[0], t[1] or 0)):
        print(f"  {key[0]:12s} b={key[1]}: {by_model[key]}")
    deferred = sum(w.n_deferred for w in stats.windows)
    print(f"policy={spec.policy.name} windows={len(stats.windows)} "
          f"deferred={deferred} shed={sum(w.n_shed for w in stats.windows)} "
          f"cache_entries={len(srv.cache)}")
    if srv.semcache is not None:
        sc = srv.semcache.stats()
        print(f"semcache: hits={sc['hits']} misses={sc['misses']} "
              f"entries={sc['entries']} bytes={sc['bytes']} "
              f"threshold={srv.semcache.cfg.sim_threshold} "
              f"utility_loss={sc['utility_loss']:.4f}")
    if chaos is not None:
        closed = all(br.state.value == "closed" for br in srv.breakers)
        print(f"chaos: seed={args.chaos} calls={sum(c.n_calls for c in chaos)} "
              f"faults={sum(c.n_faults for c in chaos)} "
              f"hangs={sum(c.n_hangs for c in chaos)} breakers_closed={closed}")
    if srv.autoscaler is not None:
        print(srv.autoscaler.summary())
        for e in srv.autoscaler.events:
            print(f"  t={e.t:7.2f}s {e.member}: {e.from_n} -> {e.to_n} ({e.reason})")


def http_main(argv):
    ap = argparse.ArgumentParser(prog="serve http")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="bind port (0 = ephemeral; the bound port is printed "
                         "on the 'listening on' line)")
    ap.add_argument("--policy", default=None,
                    help="registered policy name (repro.api.list_policies())")
    ap.add_argument("--spec", default=None,
                    help="RunSpec JSON — a file path or an inline JSON string")
    ap.add_argument("--task", default=None, help="workload benchmark name")
    ap.add_argument("--family", default=None, help="simulated pool family")
    ap.add_argument("--qps", type=float, default=40.0,
                    help="assumed offered load for budget sizing")
    ap.add_argument("--window", type=float, default=0.1, help="admission window (s)")
    ap.add_argument("--budget-x", type=float, default=3.0,
                    help="budget rate = qps × cheapest-state cost × this factor")
    ap.add_argument("--replicas", type=int, default=None,
                    help="engines per pool member (ReplicaSet when > 1)")
    ap.add_argument("--autoscale", action="store_true",
                    help="backlog-driven replica autoscaling between "
                         "--min-replicas and --max-replicas")
    ap.add_argument("--min-replicas", type=int, default=None,
                    help="autoscale floor (default 1)")
    ap.add_argument("--max-replicas", type=int, default=None,
                    help="autoscale ceiling (default 4 with --autoscale)")
    ap.add_argument("--semantic-cache", action="store_true",
                    help="embedding-space near-duplicate response cache "
                         "(repro.serving.semcache; see docs/caching.md)")
    ap.add_argument("--sim-threshold", type=float, default=None,
                    help="semantic-cache cosine hit threshold (default 0.92)")
    _add_generation_flags(ap)
    _add_robust_flags(ap)
    ap.add_argument("--max-seconds", type=float, default=0.0,
                    help="serve for N wall seconds then exit (0 = until "
                         "SIGINT/SIGTERM)")
    ap.add_argument("--n-train", type=int, default=None)
    ap.add_argument("--coreset", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    args = ap.parse_args(argv)

    import signal
    import threading

    from repro.api import Gateway, UnknownPolicyError, get_policy, list_policies
    from repro.data import BENCHMARKS
    from repro.serving.online import OnlineConfig

    if args.qps <= 0:
        raise SystemExit("serve http: --qps must be positive")
    spec = _online_spec(args)
    if args.replicas is not None:
        spec.pool.replicas = args.replicas
    if args.min_replicas is not None:
        spec.pool.min_replicas = args.min_replicas
    if args.max_replicas is not None:
        spec.pool.max_replicas = args.max_replicas
    if args.autoscale and spec.pool.max_replicas <= 0:
        spec.pool.max_replicas = 4
    if args.semantic_cache:
        spec.pool.semantic_cache = True
    if args.sim_threshold is not None:
        spec.pool.semantic_cache = True
        spec.pool.sim_threshold = args.sim_threshold
    _apply_generation_flags("serve http", spec, args)
    _apply_robust_flags("serve http", spec, args)
    if spec.pool.kind == "simulated" and spec.pool.task not in BENCHMARKS:
        raise SystemExit(f"serve http: unknown task {spec.pool.task!r}; "
                         f"known: {sorted(BENCHMARKS)}")
    try:
        get_policy(spec.policy.name)
    except UnknownPolicyError:
        raise SystemExit(f"serve http: unknown policy {spec.policy.name!r}; "
                         f"known: {list_policies()}")

    gw = Gateway.from_spec(spec)
    print(f"fitting RoBatch on {spec.pool.task}/{spec.pool.family} "
          f"({spec.pool.n_train} train, coreset {spec.coreset_size})...",
          flush=True)
    gw.fit()
    rb = gw.robatch

    test = gw.wl.subset_indices("test")
    base = float(rb.cost_model.state_cost(0, rb.calibrations[0].b_effect, test).mean())
    rate = args.qps * base * args.budget_x
    autoscale = spec.pool.autoscale_policy() if args.autoscale else None
    cfg = OnlineConfig(budget_per_s=rate, window_s=args.window,
                       realtime=True, autoscale=autoscale)
    fe = gw.serve_http(cfg, host=args.host, port=args.port)
    print(f"serve http: listening on http://{args.host}:{fe.port} "
          f"(policy={spec.policy.name}, {len(gw.pool)} members, "
          f"window {args.window}s, budget ${rate:.6f}/s)", flush=True)

    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: stop.set())
    t0 = time.monotonic()
    while not stop.is_set():
        stop.wait(0.25)
        if args.max_seconds and time.monotonic() - t0 >= args.max_seconds:
            break
    fe.stop()
    srv = gw.server
    if fe.threads_leaked:
        print(f"serve http: shutdown LEAKED threads {fe.threads_leaked} — "
              f"{fe.n_http_requests} http requests, "
              f"{len(srv.completed)} completed", flush=True)
    else:
        print(f"serve http: shutdown clean — {fe.n_http_requests} http "
              f"requests, {len(srv.completed)} completed, "
              f"{len(srv.windows)} windows, "
              f"${srv.bucket.total_spent:.6f} spent", flush=True)
    if srv.semcache is not None:
        sc = srv.semcache.stats()
        print(f"semcache: hits={sc['hits']} misses={sc['misses']} "
              f"entries={sc['entries']} bytes={sc['bytes']}", flush=True)
    if srv.windows:
        print(f"  last window: {srv.windows[-1].summary()}", flush=True)


def main():
    argv = sys.argv[1:]
    if argv and argv[0] in ("engine", "online", "http"):
        mode, rest = argv[0], argv[1:]
    else:
        mode, rest = "engine", argv     # legacy: bare flags mean engine mode
    {"online": online_main, "http": http_main}.get(mode, engine_main)(rest)


if __name__ == "__main__":
    main()
