"""Training launcher: any registered architecture, local run or production lower.

Local (CPU-feasible, reduced config)::

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --steps 50

Production mesh compile-check of the full config (same path as the dry-run,
exposed here for operators)::

    PYTHONPATH=src python -m repro.launch.train --arch nemotron-4-340b \
        --mode lower --mesh multi
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--mode", choices=["local", "lower"], default="local")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.mode == "lower":
        # production compile path needs the 512-device flag BEFORE jax init
        os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    import jax

    from repro.config import SHAPE_SUITE, ShardingConfig, get_arch
    from repro.data.pipeline import ShardedPipeline, synthetic_lm_stream
    from repro.models.transformer import Model
    from repro.training.optimizer import adamw, cosine_schedule
    from repro.training.train_loop import Trainer

    if args.mode == "lower":
        from repro.launch.dryrun import lower_cell
        from repro.launch.mesh import make_mesh_from_config, mesh_config

        mesh_cfg = mesh_config(multi_pod=args.mesh == "multi")
        mesh = make_mesh_from_config(mesh_cfg)
        res = lower_cell(get_arch(args.arch), SHAPE_SUITE["train_4k"], mesh, mesh_cfg)
        print(res["roofline"])
        return

    cfg = get_arch(args.arch).reduced()
    model = Model(cfg, ShardingConfig(remat="none", microbatches=args.microbatches))
    opt = adamw(cosine_schedule(args.lr, warmup=10, total=args.steps), grad_clip=1.0)
    trainer = Trainer(model, opt, model.shard, ckpt_dir=args.ckpt or None)
    params, opt_state, start = trainer.restore_or_init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {model.param_count() / 1e6:.2f}M params, start step {start}")

    stream = synthetic_lm_stream(cfg.vocab_size, args.batch, args.seq, seed=start)
    pipeline = ShardedPipeline(stream)
    n = max(args.steps - start, 0)
    batches = (b for _, b in zip(range(n), pipeline))
    params, opt_state, hist = trainer.fit(params, opt_state, batches,
                                          start_step=start, log_every=10)
    pipeline.close()
    for h in hist:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} ({h['time']:.0f}s)")


if __name__ == "__main__":
    main()
