from repro.serving.batcher import BatchPromptFormatter
from repro.serving.engine import Request, ServingEngine, sample_tokens
from repro.serving.generation import GenerationConfig
from repro.serving.fault import (
    BreakerPolicy,
    CircuitBreaker,
    CircuitState,
    FaultTolerantInvoker,
    FlakyMember,
    ReplicaPolicy,
    ReplicaTracker,
    StragglerPolicy,
)
from repro.serving.online import (
    BudgetBucket,
    FakeClock,
    LiveArrivalSource,
    MonotonicClock,
    OnlineConfig,
    OnlineRequest,
    OnlineRobatchServer,
    ResponseCache,
    ServerStats,
    arrival_stream,
    poisson_arrivals,
)
from repro.serving.pool import ReplicaSet, ServedPoolMember, TextTask, replicate_simulated
from repro.serving.speculative import SpeculativeEngine
