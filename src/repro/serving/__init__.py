from repro.serving.engine import ServingEngine, Request
from repro.serving.batcher import BatchPromptFormatter
from repro.serving.pool import ServedPoolMember, TextTask
from repro.serving.fault import (
    BreakerPolicy, CircuitBreaker, CircuitState, FaultTolerantInvoker,
    FlakyMember, StragglerPolicy,
)
from repro.serving.online import (
    BudgetBucket, OnlineConfig, OnlineRequest, OnlineRobatchServer,
    ResponseCache, ServerStats, poisson_arrivals,
)
