from repro.serving.engine import ServingEngine, Request
from repro.serving.batcher import BatchPromptFormatter
from repro.serving.pool import ServedPoolMember
from repro.serving.fault import FaultTolerantInvoker, StragglerPolicy
