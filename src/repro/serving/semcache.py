"""Semantic response cache in the router's embedding space.

The exact-match :class:`repro.serving.online.ResponseCache` only collapses
*identical* queries; at millions-of-users scale the dominant residual cost is
re-answering *near-duplicates*.  This module adds the embedding-similarity
layer the ROADMAP calls for, with two design commitments:

* **No new model.**  Queries are embedded with the SAME fitted space the KNN
  router already uses — ``Workload.embeddings``, the (L2-normalized) vectors
  :class:`repro.core.router.KNNRouter` computes cosine similarities over.
  The cache is built from the shared modeling artifacts
  (:class:`repro.core.robatch.Robatch`, handed around via ``Gateway.fit()`` /
  ``SchedulingPolicy.fit(artifacts=...)``), so a hit is judged in exactly the
  geometry the router routes in.

* **A hit is priced, not assumed free-of-error.**  Serving a cached answer
  for a *similar* (not identical) query costs zero dollars but risks utility.
  :class:`EpsilonModel` calibrates that risk offline — ε(sim), the expected
  relative utility loss of reusing an answer across a query pair at cosine
  similarity ``sim``, fitted on held-out labeled pairs from the router's
  training split and forced monotone non-increasing in ``sim`` — so the
  online plane can account a hit as a (cost = 0, utility = u·(1−ε(sim)))
  assignment next to the scheduler's real ones
  (:func:`repro.core.scheduler.attach_free_assignments`).

Lookup is exact brute-force top-1 over the stored keys (one ``jnp`` matmul —
the store is small by construction), with an optional bucketed
random-hyperplane (LSH) index for large stores that trades a little recall
for sublinear candidate sets.  Entries carry a TTL and are LRU-evicted under
a byte budget, mirroring the exact cache's boundedness.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["SemanticCacheConfig", "EpsilonModel", "SemanticCache", "SemHit"]

# fixed per-entry overhead charged against the byte budget on top of the
# answer text: the stored embedding reference, floats, dict slots
_ENTRY_OVERHEAD_BYTES = 96


@dataclass(frozen=True)
class SemanticCacheConfig:
    """Knobs for the semantic cache (``OnlineConfig.semantic_cache``).

    ``sim_threshold=inf`` keeps the cache structurally in place but makes a
    hit impossible (cosine ≤ 1) — the bench gate uses it to prove the wired
    server is bit-identical to one with no semantic cache at all."""

    sim_threshold: float = 0.92       # cosine hit threshold; inf disables hits
    max_bytes: int = 1 << 20          # byte budget for cached answers (LRU)
    ttl_s: float = float("inf")       # entry lifetime on the serving timeline
    calib_pairs: int = 4096           # labeled pairs for the ε(sim) fit
    calib_bins: int = 12              # similarity bins of the ε(sim) fit
    calib_seed: int = 0
    index: str = "brute"              # brute | lsh
    lsh_planes: int = 8               # hyperplanes of the optional LSH index


@dataclass
class EpsilonModel:
    """Calibrated utility-loss estimate ε(sim) ∈ [0, 1].

    Fitted from held-out labeled pairs: for queries i, j with ground-truth
    per-model utility rows U_i, U_j (the router's b=1 training labels), the
    loss proxy of answering i with j's cached answer is the mean per-model
    utility disagreement ``|U_i − U_j|.mean()``.  Pairs are binned by cosine
    similarity; bin means are made monotone non-increasing in sim (a running
    minimum low→high), so for any threshold τ, ``ε(sim) ≤ ε(τ)`` whenever
    ``sim ≥ τ`` — the property the bench gate's loss bound leans on.
    """

    sim_grid: np.ndarray              # (B,) ascending bin centers
    eps_grid: np.ndarray              # (B,) monotone non-increasing losses

    def __call__(self, sim: float) -> float:
        if not np.isfinite(sim):
            return 0.0
        return float(np.clip(np.interp(sim, self.sim_grid, self.eps_grid),
                             0.0, 1.0))

    @classmethod
    def fit(cls, embeddings: np.ndarray, utilities: np.ndarray,
            n_pairs: int = 4096, n_bins: int = 12,
            seed: int = 0) -> "EpsilonModel":
        """``embeddings`` (n, d) L2-normalized, ``utilities`` (n, K) per-model
        ground truth in [0, 1] for the same rows."""
        emb = np.asarray(embeddings, dtype=np.float32)
        util = np.asarray(utilities, dtype=np.float64)
        n = len(emb)
        assert n >= 2 and len(util) == n
        rng = np.random.default_rng(seed)
        i = rng.integers(0, n, size=n_pairs)
        j = rng.integers(0, n, size=n_pairs)
        keep = i != j
        i, j = i[keep], j[keep]
        # random pairs undersample the high-similarity region a threshold
        # actually operates in; add every row's nearest neighbor as a pair so
        # the top bins are populated by pairs that look like real cache hits
        sample = (np.arange(n) if n <= 4096
                  else rng.choice(n, size=4096, replace=False))
        gram = emb[sample] @ emb.T
        gram[np.arange(len(sample)), sample] = -np.inf
        i = np.concatenate([i, sample])
        j = np.concatenate([j, np.argmax(gram, axis=1)])
        sims = np.sum(emb[i] * emb[j], axis=1)
        loss = np.abs(util[i] - util[j]).mean(axis=1)
        # quantile bin edges keep every bin populated whatever the sim
        # distribution looks like (random pairs pile up near 0, near-dup
        # pairs near 1)
        qs = np.linspace(0.0, 1.0, n_bins + 1)
        edges = np.unique(np.quantile(sims, qs))
        if len(edges) < 3:            # degenerate similarity spread
            return cls(sim_grid=np.array([0.0, 1.0]),
                       eps_grid=np.array([float(loss.mean())] * 2))
        which = np.clip(np.searchsorted(edges, sims, side="right") - 1,
                        0, len(edges) - 2)
        centers, means = [], []
        for b in range(len(edges) - 1):
            sel = which == b
            if sel.any():
                centers.append(float(sims[sel].mean()))
                means.append(float(loss[sel].mean()))
        # monotone non-increasing in sim: ε at higher similarity never exceeds
        # ε at lower similarity (running min, low→high)
        mono = np.minimum.accumulate(np.asarray(means))
        return cls(sim_grid=np.asarray(centers), eps_grid=mono)


@dataclass(frozen=True)
class SemHit:
    """One thresholded nearest-neighbor hit, fully priced."""

    source_idx: int                   # the stored query whose answer is reused
    similarity: float
    utility_raw: float                # the cached answer's judged utility
    utility: float                    # u · (1 − ε(sim)) — what the hit serves
    utility_loss: float               # u · ε(sim) — the discounted estimate
    epsilon: float                    # ε(sim)
    model: int
    content: Optional[str]


@dataclass
class _Entry:
    utility: float
    model: int
    content: Optional[str]
    n_bytes: int
    expires_at: float


class _LshIndex:
    """Optional bucketed index: sign-pattern buckets over seeded random
    hyperplanes.  Lookup probes the query's bucket plus all Hamming-distance-1
    neighbors — approximate (a near-dup in a distant bucket is missed), but
    the candidate set stays small for large stores."""

    def __init__(self, dim: int, n_planes: int, seed: int):
        rng = np.random.default_rng(seed)
        self.planes = rng.normal(size=(dim, n_planes)).astype(np.float32)
        self.buckets: dict[int, set[int]] = {}

    def _code(self, emb: np.ndarray) -> int:
        bits = (emb @ self.planes) >= 0.0
        return int(sum(1 << b for b, on in enumerate(bits) if on))

    def add(self, key: int, emb: np.ndarray) -> None:
        self.buckets.setdefault(self._code(emb), set()).add(key)

    def remove(self, key: int, emb: np.ndarray) -> None:
        code = self._code(emb)
        bucket = self.buckets.get(code)
        if bucket is not None:
            bucket.discard(key)
            if not bucket:
                del self.buckets[code]

    def candidates(self, emb: np.ndarray) -> list[int]:
        code = self._code(emb)
        probe = [code] + [code ^ (1 << b)
                          for b in range(self.planes.shape[1])]
        out: list[int] = []
        for c in probe:
            out.extend(self.buckets.get(c, ()))
        return out


class SemanticCache:
    """Embedding-similarity response cache over workload query indices.

    ``embeddings`` is the fitted space (rows indexed by workload query id);
    :meth:`from_artifacts` builds both it and the ε(sim) calibration from a
    fitted :class:`repro.core.robatch.Robatch`.  All times are the serving
    timeline the online server ticks on (virtual or wall-relative seconds).
    """

    def __init__(self, config: SemanticCacheConfig, embeddings: np.ndarray,
                 eps_model: EpsilonModel):
        self.cfg = config
        emb = np.asarray(embeddings, dtype=np.float32)
        self._emb = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-8)
        self.eps_model = eps_model
        self._entries: "OrderedDict[int, _Entry]" = OrderedDict()
        self._index = (_LshIndex(emb.shape[1], config.lsh_planes,
                                 config.calib_seed)
                       if config.index == "lsh" else None)
        self._key_matrix: Optional[jnp.ndarray] = None  # brute-force cache
        self._key_order: list[int] = []
        self.total_bytes = 0
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.expirations = 0
        self.utility_loss = 0.0       # Σ u·ε(sim) over all hits served

    # ------------------------------------------------------------- internals
    def _entry_bytes(self, content: Optional[str]) -> int:
        return (len(content.encode()) if content else 0) + _ENTRY_OVERHEAD_BYTES

    def _drop(self, key: int, counter: Optional[str] = None) -> None:
        entry = self._entries.pop(key)
        self.total_bytes -= entry.n_bytes
        if self._index is not None:
            self._index.remove(key, self._emb[key])
        self._key_matrix = None
        if counter is not None:
            setattr(self, counter, getattr(self, counter) + 1)

    def _expire(self, now: float) -> None:
        dead = [k for k, e in self._entries.items() if e.expires_at <= now]
        for k in dead:
            self._drop(k, "expirations")

    def _top1(self, q: np.ndarray) -> tuple[Optional[int], float]:
        """Exact brute-force top-1 (jnp matmul) or LSH-bucketed top-1."""
        if self._index is not None:
            cand = self._index.candidates(q)
            if not cand:
                return None, -1.0
            sims = self._emb[cand] @ q
            best = int(np.argmax(sims))
            return cand[best], float(sims[best])
        if self._key_matrix is None:
            self._key_order = list(self._entries)
            self._key_matrix = jnp.asarray(self._emb[self._key_order])
        sims = jnp.matmul(self._key_matrix, jnp.asarray(q))
        best = int(jnp.argmax(sims))
        return self._key_order[best], float(sims[best])

    # ------------------------------------------------------------------- api
    @classmethod
    def from_artifacts(cls, rb, config: SemanticCacheConfig) -> "SemanticCache":
        """Reuse the router's fitted embedding space + labels: the workload
        embeddings the KNN router measures cosine similarity in, and its b=1
        ground-truth labels as the ε(sim) calibration pairs."""
        assert rb.router is not None, "Robatch must be fitted first"
        emb = np.asarray(rb.wl.embeddings, dtype=np.float32)
        tr = np.asarray(rb._train_idx)
        emb_n = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-8)
        eps = EpsilonModel.fit(emb_n[tr], rb.train_labels,
                               n_pairs=config.calib_pairs,
                               n_bins=config.calib_bins,
                               seed=config.calib_seed)
        return cls(config, emb, eps)

    def lookup(self, query_idx: int, now: float = 0.0) -> Optional[SemHit]:
        """Thresholded nearest-neighbor lookup; a hit refreshes LRU recency
        and accrues the calibrated utility-loss estimate."""
        if not np.isfinite(self.cfg.sim_threshold):
            return None               # cache off: not even a counted miss
        self._expire(now)
        if not self._entries:
            self.misses += 1
            return None
        key, sim = self._top1(self._emb[int(query_idx)])
        if key is None or sim < self.cfg.sim_threshold:
            self.misses += 1
            return None
        entry = self._entries[key]
        self._entries.move_to_end(key)
        eps = self.eps_model(sim)
        loss = entry.utility * eps
        self.hits += 1
        self.utility_loss += loss
        return SemHit(source_idx=key, similarity=sim,
                      utility_raw=entry.utility,
                      utility=entry.utility * (1.0 - eps),
                      utility_loss=loss, epsilon=eps,
                      model=entry.model, content=entry.content)

    def insert(self, query_idx: int, utility: float, model: int,
               content: Optional[str], now: float = 0.0) -> None:
        """Store a served answer; TTL from ``now``, LRU-evict past the byte
        budget.  An entry larger than the whole budget is simply not stored."""
        if not np.isfinite(self.cfg.sim_threshold):
            return
        key = int(query_idx)
        n_bytes = self._entry_bytes(content)
        if n_bytes > self.cfg.max_bytes:
            return
        if key in self._entries:
            self._drop(key)               # replace: refresh value + recency
        self._entries[key] = _Entry(utility=float(utility), model=int(model),
                                    content=content, n_bytes=n_bytes,
                                    expires_at=now + self.cfg.ttl_s)
        self.total_bytes += n_bytes
        if self._index is not None:
            self._index.add(key, self._emb[key])
        self._key_matrix = None
        self.insertions += 1
        while self.total_bytes > self.cfg.max_bytes and len(self._entries) > 1:
            self._drop(next(iter(self._entries)), "evictions")

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        return dict(entries=len(self._entries), bytes=self.total_bytes,
                    hits=self.hits, misses=self.misses,
                    insertions=self.insertions, evictions=self.evictions,
                    expirations=self.expirations,
                    utility_loss=self.utility_loss)
