"""Unified generation configuration.

One frozen dataclass carries every per-request generation knob — token
budget, temperature/top-k/top-p sampling, PRNG seed — from the HTTP front
end through the gateway and pool down to the engine, replacing the scattered
``max_new=`` / greedy-flag kwargs.  JSON/dict round-trip mirrors
``repro.api.specs`` (unknown keys are rejected loudly, so a typo'd field
never silently falls back to a default).

Determinism contract: the token at stream position ``t`` of a request is a
pure function of ``(seed, t)`` — the engine folds the per-request base key
(``jax.random.PRNGKey(seed)``) with the position counter, never with the
dispatch step — so outputs are bit-identical across ``decode_block`` sizes,
slot assignments, replica counts, and the fused/stepwise/speculative
drivers.  ``temperature=0`` short-circuits to greedy argmax and is
bit-identical to the pre-sampling engine.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, fields, replace


def _from_known_fields(cls, d: dict):
    names = {f.name for f in fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(f"{cls.__name__}: unknown field(s) {sorted(unknown)}; "
                         f"known: {sorted(names)}")
    return cls(**d)


@dataclass(frozen=True)
class GenerationConfig:
    """Per-request generation knobs.

    ``max_new``      — output token budget (prefill's first token included).
    ``temperature``  — 0 (default) is greedy argmax; > 0 samples from the
                       temperature-scaled distribution.
    ``top_k``        — keep only the k highest-probability tokens (0 = off).
    ``top_p``        — nucleus sampling: keep the smallest prefix of the
                       sorted distribution with cumulative mass ≥ top_p
                       (1.0 = off; the argmax token is always kept).
    ``seed``         — per-request PRNG seed; same seed ⇒ bit-identical
                       streams regardless of batching/replica placement.
    ``decode_block`` — engine fused-scan depth K (0 = keep the engine's
                       configured value; honored at engine construction when
                       threaded through ``PoolSpec``, not per request — K is
                       jit-static).
    """
    max_new: int = 32
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    decode_block: int = 0

    def __post_init__(self):
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.decode_block < 0:
            raise ValueError(f"decode_block must be >= 0, got {self.decode_block}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def with_(self, **kw) -> "GenerationConfig":
        return replace(self, **kw)

    # ---------------- dict / JSON round-trip ----------------
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "GenerationConfig":
        return _from_known_fields(cls, dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "GenerationConfig":
        return cls.from_dict(json.loads(s))
