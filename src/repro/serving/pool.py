"""Real served pool: adapts ServingEngines to the scheduler's PoolMember
protocol, so Robatch routes across *actually running* models.

The pool-member protocol (docs/architecture.md) is what lets the calibrated
simulator (:mod:`repro.data.simulator`) and this real pool interchange:

    name: str; c_in, c_out: float ($/1M tokens); context_len: int
    invoke_batch(workload, batch_idx) -> BatchResult
    evaluate(workload, idx, batch_size) -> per-query utilities

A ``TextTask`` supplies the query/answer text for a Workload (the numeric
Workload drives the scheduler; the TextTask drives real token-level serving).
Utilities come from judging the parsed batched generations — accuracy
degradation with batch size emerges from the models themselves, not a
simulator.

Members are safe to invoke from the online dispatcher's worker threads: each
member serializes access to its engine (the KV-cache slots are mutable state),
while different members run genuinely concurrently.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from repro.data.simulator import BatchResult
from repro.data.workload import Workload
from repro.serving.batcher import BatchPromptFormatter
from repro.serving.engine import Request, ServingEngine


@dataclass
class TextTask:
    """Parallel text view of a workload: query/answer strings by index."""

    queries: Sequence[str]
    answers: Sequence[str]
    judge: Callable[[str, str], float] = None   # (prediction, gold) -> utility

    def __post_init__(self):
        if self.judge is None:
            self.judge = lambda pred, gold: float(pred.strip() == gold.strip())


class ServedPoolMember:
    """One pool member backed by a live ServingEngine."""

    def __init__(self, name: str, engine: ServingEngine, formatter: BatchPromptFormatter,
                 task: TextTask, c_in: float, c_out: float, context_len: int,
                 max_answer_tokens: int = 8):
        self.name = name
        self.engine = engine
        self.formatter = formatter
        self.task = task
        self.c_in = c_in
        self.c_out = c_out
        self.context_len = context_len
        self.max_answer_tokens = max_answer_tokens
        self._lock = threading.Lock()
        self._rid = itertools.count()   # monotonic per-member invocation id

    def invoke_batch(self, wl: Workload, batch_idx: np.ndarray) -> BatchResult:
        b = len(batch_idx)
        queries = [self.task.queries[int(i)] for i in batch_idx]
        prompt = self.formatter.format(queries)
        t0 = time.perf_counter()
        # each physical invocation gets a fresh rid so engine-level logs and
        # traces can tell invocations apart (next() is atomic under the GIL)
        req = Request(rid=next(self._rid), tokens=prompt,
                      max_new=self.max_answer_tokens * b + b)
        with self._lock:              # one engine, one in-flight batch
            self.engine.serve([req])
        latency = time.perf_counter() - t0
        tok = self.formatter.tokenizer
        out_ids = req.out_tokens
        if self.engine.eos_id in out_ids:
            out_ids = out_ids[: out_ids.index(self.engine.eos_id)]
        text = tok.decode(out_ids)
        answers = self.formatter.parse(text, b)
        util = np.array([self.task.judge(a, self.task.answers[int(i)])
                         for a, i in zip(answers, batch_idx)])
        return BatchResult(utilities=util, in_tokens=len(prompt),
                           out_tokens=len(req.out_tokens), latency_s=latency)

    def evaluate(self, wl: Workload, idx: np.ndarray, batch_size: int,
                 rng=None) -> np.ndarray:
        idx = np.asarray(idx)
        out = np.zeros(len(idx))
        for s in range(0, len(idx), batch_size):
            chunk = idx[s:s + batch_size]
            out[s:s + len(chunk)] = self.invoke_batch(wl, chunk).utilities
        return out
