"""Real served pool: adapts ServingEngines to the scheduler's PoolMember
protocol, so Robatch routes across *actually running* models.

The pool-member protocol (docs/architecture.md) is what lets the calibrated
simulator (:mod:`repro.data.simulator`) and this real pool interchange:

    name: str; c_in, c_out: float ($/1M tokens); context_len: int
    invoke_batch(workload, batch_idx) -> BatchResult
    evaluate(workload, idx, batch_size) -> per-query utilities

A ``TextTask`` supplies the query/answer text for a Workload (the numeric
Workload drives the scheduler; the TextTask drives real token-level serving).
Utilities come from judging the parsed batched generations — accuracy
degradation with batch size emerges from the models themselves, not a
simulator.

Members are safe to invoke from the online dispatcher's worker threads: each
member serializes access to its engine (the KV-cache slots are mutable state),
while different members run genuinely concurrently.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from repro.data.simulator import BatchResult, evaluate_chunked
from repro.data.workload import Workload
from repro.serving.batcher import BatchPromptFormatter
from repro.serving.engine import Request, ServingEngine
from repro.serving.fault import ReplicaPolicy, ReplicaTracker
from repro.serving.generation import GenerationConfig


class DispatchTimeout(RuntimeError):
    """A replica dispatch exceeded ``ReplicaSet.dispatch_timeout_s`` — the
    dispatching thread abandoned the (possibly hung) engine call and failed
    over to a sibling replica."""


@dataclass
class TextTask:
    """Parallel text view of a workload: query/answer strings by index."""

    queries: Sequence[str]
    answers: Sequence[str]
    judge: Callable[[str, str], float] = None   # (prediction, gold) -> utility

    def __post_init__(self):
        if self.judge is None:
            self.judge = lambda pred, gold: float(pred.strip() == gold.strip())


class ServedPoolMember:
    """One pool member backed by a live ServingEngine."""

    supports_streams = True
    # ^ invoke_batch accepts ``streams`` (per-position live subscriber sinks);
    #   the online dispatcher feature-detects this attribute before forwarding
    supports_generation = True
    # ^ invoke_batch accepts ``gen`` (a GenerationConfig); same feature probe

    def __init__(self, name: str, engine: ServingEngine, formatter: BatchPromptFormatter,
                 task: TextTask, c_in: float, c_out: float, context_len: int,
                 max_answer_tokens: int = 8,
                 generation: Optional[GenerationConfig] = None):
        self.name = name
        self.engine = engine
        self.formatter = formatter
        self.task = task
        self.c_in = c_in
        self.c_out = c_out
        self.context_len = context_len
        self.max_answer_tokens = max_answer_tokens
        self.generation = generation    # member-default gen (None → greedy)
        self._lock = threading.Lock()
        self._rid = itertools.count()   # monotonic per-member invocation id

    def _stream_demux(self, b: int, streams: dict):
        """Per-decode-block demultiplexer for the batch-prompt wire format.

        The engine's ``Request.on_tokens`` hook fires once per fused
        ``decode_block`` dispatch with the freshly appended token ids; this
        closure accumulates them, splits the byte stream on the answer
        separator, and pushes each subscribed position's *text delta* to its
        sinks — so SSE chunks flow mid-generation at decode-block cadence.

        Splitting happens on raw bytes (the separator is one byte), so
        position boundaries are exact even when a multi-byte UTF-8 character
        straddles two decode blocks.  While a part is still open, only its
        longest cleanly decodable prefix is emitted and trailing whitespace is
        held back; when the part closes (a later separator, EOS, or the
        generation ending) the final text is the same ``strip()``-ed answer
        :meth:`BatchPromptFormatter.parse` produces — so the concatenated
        deltas always equal the request's non-streamed answer.
        """
        sep = self.formatter.sep.encode()
        eos = self.engine.eos_id
        acc: list[int] = []
        emitted = ["" for _ in range(b)]
        closed = [False] * b

        def clean_prefix(raw: bytes) -> str:
            try:
                return raw.decode("utf-8")
            except UnicodeDecodeError as e:
                return raw[: e.start].decode("utf-8", errors="ignore")

        def push(pos: int, text: str) -> None:
            delta = text[len(emitted[pos]):] if text.startswith(emitted[pos]) \
                else text          # defensive: never retract, re-push whole
            if delta:
                emitted[pos] += delta
                for sink in streams[pos]:
                    sink.push(delta)

        def on_tokens(new_ids: list[int], done: bool) -> None:
            acc.extend(new_ids)
            ids, ended = acc, done
            if eos in ids:
                ids, ended = ids[: ids.index(eos)], True
            raw = bytes(i for i in ids if i < 256)
            parts = raw.split(sep)
            for pos in streams:
                if pos >= b or pos >= len(parts) or closed[pos]:
                    continue
                if pos < len(parts) - 1 or ended:
                    closed[pos] = True
                    push(pos, parts[pos].decode("utf-8", errors="replace").strip())
                else:
                    push(pos, clean_prefix(parts[pos]).strip())

        return on_tokens

    def invoke_batch(self, wl: Workload, batch_idx: np.ndarray,
                     streams: Optional[dict] = None,
                     gen: Optional[GenerationConfig] = None) -> BatchResult:
        b = len(batch_idx)
        queries = [self.task.queries[int(i)] for i in batch_idx]
        prompt = self.formatter.format(queries)
        t0 = time.perf_counter()
        effective = gen if gen is not None else self.generation
        if effective is not None:
            # the batch needs room for every co-batched answer: the caller's
            # max_new acts as a per-query cap on the member's answer sizing,
            # scaled to the batch (sampling params/seed pass through as-is)
            per_q = min(self.max_answer_tokens, effective.max_new)
            effective = effective.with_(max_new=per_q * b + b)
        # each physical invocation gets a fresh rid so engine-level logs and
        # traces can tell invocations apart (next() is atomic under the GIL)
        req = Request(rid=next(self._rid), tokens=prompt,
                      max_new=self.max_answer_tokens * b + b, gen=effective)
        if streams:
            req.on_tokens = self._stream_demux(b, streams)
        with self._lock:              # one engine, one in-flight batch
            self.engine.serve([req])
        latency = time.perf_counter() - t0
        tok = self.formatter.tokenizer
        out_ids = req.out_tokens
        if self.engine.eos_id in out_ids:
            out_ids = out_ids[: out_ids.index(self.engine.eos_id)]
        text = tok.decode(out_ids)
        answers = self.formatter.parse(text, b)
        util = np.array([self.task.judge(a, self.task.answers[int(i)])
                         for a, i in zip(answers, batch_idx)])
        return BatchResult(utilities=util, in_tokens=len(prompt),
                           out_tokens=len(req.out_tokens), latency_s=latency,
                           answers=answers)

    def evaluate(self, wl: Workload, idx: np.ndarray, batch_size: int,
                 rng=None) -> np.ndarray:
        return evaluate_chunked(self, wl, idx, batch_size)

    def kv_occupancy(self) -> dict:
        """KV memory telemetry of the backing engine (see
        :meth:`repro.serving.engine.ServingEngine.kv_occupancy`)."""
        return self.engine.kv_occupancy()


class ReplicaSet:
    """N interchangeable replicas behind ONE pool-member facade.

    The scheduler and the online server see a single member — one name, one
    price, one circuit breaker, one column family in the candidate space — of
    capacity ``n_replicas`` concurrent batch-groups (the per-window cap the
    scheduler enforces, see ``group_caps`` in
    :func:`repro.core.scheduler.greedy_schedule_window`).  Each invocation is
    dispatched to the least-loaded *healthy* replica (in-flight count, index
    as tie-break); a replica fault is retried on the next-healthiest sibling
    while :class:`repro.serving.fault.ReplicaTracker` records the failure, so
    a single-replica outage degrades the set's capacity instead of tripping
    the member's breaker.  Only when every replica has failed does
    ``invoke_batch`` raise — that is the signal the member-level breaker
    consumes.

    Replicas must be interchangeable pool members (same pricing/behaviour):
    distinct engines over shared trained weights for the real pool
    (:func:`repro.serving.tinypool.build_tiny_pool`), dataclass copies for the
    simulator.  ``thread_safe`` tells the online dispatcher to skip its
    per-member serialization lock — replicas serialize themselves, so groups
    bound for different replicas genuinely run concurrently.

    **Autoscaling.**  ``factory`` is a zero-arg callable producing one more
    interchangeable replica; with one attached, :meth:`scale_to` grows the
    set on demand (un-parking previously drained replicas before building new
    ones) and shrinks it by drain-then-eject: the victim replica is retired in
    the :class:`~repro.serving.fault.ReplicaTracker` (no new dispatch; its
    in-flight batch finishes normally) rather than torn down mid-batch, so a
    scale-down never fails a query.  Retired replicas stay attached and are
    the first capacity a later scale-up restores.

    **Async warm attach.**  With ``async_build=True`` a grow that needs the
    factory runs it on a background thread instead of inside the caller: a
    tiny-pool factory constructs (and jit-warms) a whole
    :class:`~repro.serving.engine.ServingEngine`, and the autoscaler fires
    ``scale_to`` from the serving loop — building inline would stretch the
    very window that detected the backlog.  ``scale_to`` returns the current
    active count immediately, the build lands in a ready buffer, and the
    finished replica *joins at the next window boundary*: ``n_available()``
    (what the server's per-window ``caps()`` reads) and ``n_replicas``
    attach any completed builds before reporting.  ``n_pending_builds``
    counts launched-but-unattached builds so repeated breaches never
    double-build.  A shrink does not cancel in-flight builds — they attach
    and are then eligible victims for the next scale-down.
    """

    thread_safe = True

    def __init__(self, replicas: Sequence, *, name: Optional[str] = None,
                 policy: Optional[ReplicaPolicy] = None,
                 clock: Callable[[], float] = time.monotonic,
                 factory: Optional[Callable[[], object]] = None,
                 async_build: bool = False,
                 dispatch_timeout_s: Optional[float] = None,
                 max_dispatch_retries: int = 0,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 1.0):
        if not replicas:
            raise ValueError("ReplicaSet needs at least one replica")
        self.replicas = list(replicas)
        self.name = name if name is not None else self.replicas[0].name
        self.tracker = ReplicaTracker(len(self.replicas), policy, clock)
        self.factory = factory
        self.async_build = bool(async_build)
        # dispatch hardening (docs/robustness.md): a per-dispatch wall-clock
        # deadline (None = legacy direct call, no watcher thread) and a
        # bounded same-replica retry ladder for ordinary faults.  Timeouts
        # never retry the same replica — a hung engine stays hung — they
        # record a failure and fail over to a sibling immediately.
        self.dispatch_timeout_s = dispatch_timeout_s
        self.max_dispatch_retries = int(max_dispatch_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.n_timeouts = 0
        self.n_dispatch_retries = 0
        self._inflight = [0] * len(self.replicas)
        self._lock = threading.Lock()
        self._ready: list = []          # built off-thread, awaiting attach
        self._pending_builds = 0        # launched factory builds not yet attached

    @property
    def n_replicas(self) -> int:
        """Active (non-retired) replica count — the member's nominal size.
        Attaches any finished async builds first (the window-boundary join)."""
        self._join_ready()
        return self.tracker.n_active()

    @property
    def n_pending_builds(self) -> int:
        """Async factory builds launched but not yet attached."""
        with self._lock:
            return self._pending_builds + len(self._ready)

    def _spawn_build(self) -> None:
        def work():
            try:
                replica = self.factory()
            except BaseException:
                # a failed build must release its pending slot, or the
                # phantom count suppresses every future scale-up
                with self._lock:
                    self._pending_builds -= 1
                raise               # surface the fault on the thread's stderr
            with self._lock:
                self._pending_builds -= 1
                self._ready.append(replica)

        threading.Thread(target=work, daemon=True,
                         name=f"{self.name}-replica-build").start()

    def _join_ready(self) -> None:
        """Attach replicas whose background build finished (never blocks)."""
        with self._lock:
            ready, self._ready = self._ready, []
            for replica in ready:
                self.replicas.append(replica)
                self._inflight.append(0)
                self.tracker.add_replica()

    def scale_to(self, n: int) -> int:
        """Grow or shrink the active replica count toward ``n``; returns the
        count actually reached (growth stops at the attached replicas when no
        ``factory`` is set; the floor is always 1).

        Grow: retired replicas are restored first (clean health slate), then
        ``factory()`` attaches brand-new ones — inline, or launched on a
        background thread with ``async_build`` (the call then returns the
        still-current count and the new replica joins at the next
        ``n_available()``/``n_replicas`` read).  Shrink: victims — preferring
        already-unhealthy, then idle, then highest-index replicas — are
        *retired* in the tracker, which removes them from dispatch while any
        in-flight batch drains to completion.
        """
        n = max(1, int(n))
        self._join_ready()
        while True:
            with self._lock:
                states = self.tracker.replicas
                active = self.tracker.n_active()
                if active < n:
                    parked = [r for r, st in enumerate(states) if st.retired]
                    if parked:
                        self.tracker.restore(parked[0])
                        continue
                    if self.factory is None:
                        return active
                    if self.async_build:
                        deficit = (n - active - self._pending_builds
                                   - len(self._ready))
                        for _ in range(max(0, deficit)):
                            self._pending_builds += 1
                            self._spawn_build()
                        return active
                elif active > n:
                    alive = [r for r, st in enumerate(states) if not st.retired]
                    victim = max(alive,
                                 key=lambda r: (not self.tracker.healthy(r),
                                                -self._inflight[r], r))
                    self.tracker.retire(victim)
                    continue
                else:
                    return active
            # build OUTSIDE the dispatch lock: a tiny-pool factory constructs
            # a whole ServingEngine, and in-flight batches must not stall on
            # (or be unable to release their slot during) the construction
            replica = self.factory()
            with self._lock:
                self.replicas.append(replica)
                self._inflight.append(0)
                self.tracker.add_replica()

    def n_available(self) -> int:
        """Healthy-replica count — the member's CURRENT group capacity (the
        online server re-reads this every window, so an ejected replica
        shrinks the caps the scheduler plans against, and a finished async
        build joins here — at the window boundary).  Never 0: a fully
        ejected set still gets one probe group, and the member-level breaker
        owns the remove-from-space decision."""
        self._join_ready()
        return max(1, self.tracker.n_healthy())

    @property
    def c_in(self) -> float:
        return self.replicas[0].c_in

    @property
    def c_out(self) -> float:
        return self.replicas[0].c_out

    @property
    def context_len(self) -> int:
        return self.replicas[0].context_len

    def loads(self) -> list[int]:
        with self._lock:
            return list(self._inflight)

    def _acquire(self, exclude: set[int]) -> Optional[int]:
        """Least-loaded healthy replica (falls back to ejected ones only when
        every non-excluded replica is ejected — a last-ditch probe beats
        failing a batch that might still be servable).  Retired replicas
        (scale-down drain) never take new work."""
        with self._lock:
            ranked = [r for r in range(len(self.replicas))
                      if r not in exclude and not self.tracker.replicas[r].retired]
            if not ranked:
                return None
            healthy = [r for r in ranked if self.tracker.healthy(r)]
            r = min(healthy or ranked, key=lambda i: (self._inflight[i], i))
            self._inflight[r] += 1
            return r

    @property
    def supports_streams(self) -> bool:
        """Live token streaming is offered iff the replicas offer it (the set
        merely routes the ``streams`` subscription to whichever replica wins
        dispatch)."""
        return bool(getattr(self.replicas[0], "supports_streams", False))

    @property
    def supports_generation(self) -> bool:
        """GenerationConfig forwarding, same feature-probe contract as
        :attr:`supports_streams`."""
        return bool(getattr(self.replicas[0], "supports_generation", False))

    def _dispatch(self, r: int, wl: Workload, batch_idx: np.ndarray,
                  kw: dict) -> BatchResult:
        """One physical dispatch to replica ``r``, under the per-dispatch
        deadline when one is configured.  The timed path runs the invocation
        on a fresh daemon thread and abandons it on expiry — leaking the hung
        thread is the point: the *serving* thread unwedges and fails over
        while the stuck engine call is left to die with the process."""
        if self.dispatch_timeout_s is None:
            return self.replicas[r].invoke_batch(wl, batch_idx, **kw)
        box: dict = {}
        done = threading.Event()

        def work():
            try:
                box["out"] = self.replicas[r].invoke_batch(wl, batch_idx, **kw)
            except BaseException as e:    # noqa: BLE001 — carried to the caller
                box["err"] = e
            finally:
                done.set()

        threading.Thread(target=work, daemon=True,
                         name=f"{self.name}-dispatch-r{r}").start()
        if not done.wait(self.dispatch_timeout_s):
            with self._lock:
                self.n_timeouts += 1
            raise DispatchTimeout(
                f"{self.name}: replica {r} dispatch exceeded "
                f"{self.dispatch_timeout_s}s deadline")
        if "err" in box:
            raise box["err"]
        return box["out"]

    def invoke_batch(self, wl: Workload, batch_idx: np.ndarray,
                     streams: Optional[dict] = None,
                     gen: Optional[GenerationConfig] = None) -> BatchResult:
        tried: set[int] = set()
        last: Optional[Exception] = None
        while True:
            r = self._acquire(tried)
            if r is None:
                raise RuntimeError(
                    f"{self.name}: all {self.n_replicas} replicas failed") from last
            kw = {"streams": streams} if streams and getattr(
                self.replicas[r], "supports_streams", False) else {}
            if gen is not None and getattr(self.replicas[r],
                                           "supports_generation", False):
                kw["gen"] = gen
            try:
                for attempt in range(self.max_dispatch_retries + 1):
                    t0 = time.perf_counter()
                    try:
                        out = self._dispatch(r, wl, batch_idx, kw)
                    except DispatchTimeout as e:
                        # a hung replica stays hung: no same-replica retry,
                        # record the failure and fail over to a sibling
                        last = e
                        self.tracker.record_failure(r)
                        tried.add(r)
                        break
                    except Exception as e:    # noqa: BLE001 — replica fault
                        last = e
                        self.tracker.record_failure(r)
                        if attempt < self.max_dispatch_retries:
                            with self._lock:
                                self.n_dispatch_retries += 1
                            time.sleep(min(self.backoff_cap_s,
                                           self.backoff_base_s * 2 ** attempt))
                            continue
                        tried.add(r)
                        break
                    else:
                        self.tracker.record_success(r, time.perf_counter() - t0)
                        return out
            finally:
                with self._lock:
                    self._inflight[r] -= 1

    def evaluate(self, wl: Workload, idx: np.ndarray, batch_size: int,
                 rng=None) -> np.ndarray:
        return evaluate_chunked(self, wl, idx, batch_size)

    def kv_occupancy(self) -> dict:
        """Aggregate KV telemetry over replicas that expose it: sums bytes
        and page counters so the set reads as one member (simulated replicas
        report nothing and contribute zeros)."""
        total: dict = {}
        for rep in self.replicas:
            fn = getattr(rep, "kv_occupancy", None)
            if fn is None:
                continue
            for k, v in fn().items():
                if isinstance(v, bool):
                    total[k] = total.get(k, False) or v
                elif isinstance(v, (int, float)):
                    total[k] = total.get(k, 0) + v
        return total


def replicate_simulated(member, n: int, **kwargs) -> ReplicaSet:
    """ReplicaSet of ``n`` dataclass copies of a simulated member (copies are
    deterministic-identical, so replication changes capacity, not outcomes).
    The copy constructor doubles as the set's autoscaling ``factory``, so the
    :class:`~repro.serving.autoscale.Autoscaler` can grow it past ``n``."""
    from dataclasses import replace

    kwargs.setdefault("factory", lambda: replace(member))
    return ReplicaSet([replace(member) for _ in range(n)],
                      name=member.name, **kwargs)
