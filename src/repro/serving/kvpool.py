"""Host-side paged KV-cache management: refcounted block pool + per-slot tables.

The serving engine's paged mode replaces the contiguous ``(max_slots, max_len,
...)`` KV pytree with a device-resident pool of fixed-size pages plus a
per-slot *block table* of physical page indices.  This module is the host
brain of that layout; no jax in here — the engine owns the device arrays and
asks the allocator which page goes where.

Why paging: batch prompting amortizes the shared system prompt in dollars
(every query in a batch rides one prefix); paging amortizes it in *memory*.
Sibling requests admitted together map their common-prefix pages onto the
same physical pages (refcount > 1), and a slot only gets a private copy of a
shared page at the moment it first needs to write into one — copy-on-write,
triggered exactly when decode appends into a partially-filled shared boundary
page.  A retired slot returns only the pages nobody else still references.

Two layers:

* :class:`BlockAllocator` — the refcounted free-list.  ``alloc`` / ``share``
  / ``fork`` (CoW) / ``release``, with hard failures on double-free and
  over-release, and the occupancy counters the serving plane reports
  (pages used / shared / CoW forks / peak).  Pure bookkeeping: this is the
  object the property-based tests drive.
* :class:`PagedCacheManager` — per-slot page lists + the ``(max_slots,
  pages_per_slot)`` int32 block table (sentinel ``n_pages`` marks unmapped
  entries; device scatters use ``mode="drop"``, gathers clip + mask).

Sizing: ``n_pages = max_slots * ceil(max_len / page_size)`` is sufficient by
construction — sharing only ever *reduces* distinct pages, and a CoW fork
requires a shared page, which implies at least one page of headroom.  The
allocator therefore never needs eviction.
"""
from __future__ import annotations

import numpy as np

__all__ = ["OutOfPages", "BlockAllocator", "PagedCacheManager"]


class OutOfPages(RuntimeError):
    """The pool has no free page (cannot happen with default sizing)."""


class BlockAllocator:
    """Refcounted fixed-size page pool (host bookkeeping only)."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError(f"need positive pool: n_pages={n_pages} "
                             f"page_size={page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO free list: recently freed pages are re-used first (their old
        # contents are dead — every consumer masks reads beyond ``len``)
        self._free: list[int] = list(range(self.n_pages - 1, -1, -1))
        self._ref = np.zeros(self.n_pages, np.int32)
        # lifetime counters (telemetry + tests)
        self.n_allocs = 0          # fresh pages handed out (fork included)
        self.n_shares = 0          # refcount bumps from prefix sharing
        self.n_forks = 0           # CoW forks performed
        self.n_frees = 0           # pages fully returned to the free list
        self.peak_pages = 0        # high-water mark of pages_in_use

    # ---- queries ------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_shared(self) -> int:
        """Pages currently referenced by more than one table entry."""
        return int((self._ref > 1).sum())

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    # ---- transitions --------------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            raise OutOfPages(f"all {self.n_pages} pages in use")
        page = self._free.pop()
        assert self._ref[page] == 0, f"free page {page} had refcount {self._ref[page]}"
        self._ref[page] = 1
        self.n_allocs += 1
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return page

    def alloc_n(self, n: int) -> list[int]:
        return [self.alloc() for _ in range(n)]

    def share(self, page: int) -> int:
        """One more table entry references ``page``; returns it for chaining."""
        if self._ref[page] <= 0:
            raise ValueError(f"cannot share unreferenced page {page}")
        self._ref[page] += 1
        self.n_shares += 1
        return page

    def fork(self, page: int) -> int:
        """Copy-on-write: detach one reference of shared ``page`` onto a fresh
        private page.  The caller owns copying the device contents and
        repointing its table entry; the remaining sharers keep ``page``."""
        if self._ref[page] < 2:
            raise ValueError(f"fork of non-shared page {page} "
                             f"(refcount {self._ref[page]})")
        new = self.alloc()
        self._ref[page] -= 1
        self.n_forks += 1
        return new

    def release(self, page: int) -> bool:
        """Drop one reference; returns True iff the page went back to the
        free list (refcount hit zero)."""
        if self._ref[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free.append(page)
            self.n_frees += 1
            return True
        return False

    # ---- invariants ---------------------------------------------------
    def check(self, tables=None) -> None:
        """Assert internal consistency (tests call this after every step).

        ``tables``: optional iterable of page-index lists (one per live slot);
        when given, every refcount must equal the number of table references.
        """
        assert (self._ref >= 0).all(), "negative refcount"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate page in free list"
        for p in self._free:
            assert self._ref[p] == 0, f"free page {p} has refcount {self._ref[p]}"
        in_use = {p for p in range(self.n_pages) if self._ref[p] > 0}
        assert not (in_use & free_set), "page both free and referenced"
        assert self.pages_in_use <= self.n_pages
        if tables is not None:
            want = np.zeros(self.n_pages, np.int32)
            for pages in tables:
                for p in pages:
                    want[p] += 1
            assert (want == self._ref).all(), (
                f"refcounts {self._ref.tolist()} != table references "
                f"{want.tolist()}")


class PagedCacheManager:
    """Per-slot block tables over one :class:`BlockAllocator`.

    The table is a host numpy array mirrored to the device each decode
    dispatch (``max_slots × pages_per_slot`` int32 — trivially small).  The
    sentinel value ``n_pages`` marks unmapped entries: device scatters drop
    them (``mode="drop"``), gathers clip them and rely on the length mask.
    """

    def __init__(self, max_slots: int, max_len: int, page_size: int,
                 n_pages: int | None = None):
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.pages_per_slot = -(-self.max_len // self.page_size)   # ceil
        if n_pages is None:
            n_pages = self.max_slots * self.pages_per_slot
        self.alloc = BlockAllocator(n_pages, page_size)
        self.slot_pages: list[list[int]] = [[] for _ in range(self.max_slots)]
        self.table = np.full((self.max_slots, self.pages_per_slot),
                             self.alloc.n_pages, np.int32)

    # ---- slot lifecycle ----------------------------------------------
    def release_slot(self, slot: int) -> int:
        """Return the slot's references; frees exactly the pages no other
        slot still shares.  Returns how many pages actually went free."""
        freed = sum(self.alloc.release(p) for p in self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.table[slot, :] = self.alloc.n_pages
        return freed

    def map_slot(self, slot: int, pages: list[int]) -> None:
        """Point ``slot``'s table at ``pages`` (already alloc'd/shared)."""
        assert len(pages) <= self.pages_per_slot
        self.slot_pages[slot] = list(pages)
        self.table[slot, :] = self.alloc.n_pages
        self.table[slot, :len(pages)] = pages

    def extend_slot(self, slot: int, n_pages_total: int) -> list[int]:
        """Grow the slot's table to ``n_pages_total`` pages; returns the
        freshly allocated (private) pages."""
        pages = self.slot_pages[slot]
        new = []
        while len(pages) < min(n_pages_total, self.pages_per_slot):
            p = self.alloc.alloc()
            self.table[slot, len(pages)] = p
            pages.append(p)
            new.append(p)
        return new

    def truncate_slot(self, slot: int, n_tokens: int) -> int:
        """Shrink ``slot``'s table to cover exactly ``n_tokens`` positions,
        releasing every page past ``ceil(n_tokens / page_size)`` — the
        speculative-decode rollback: a rejected draft suffix disappears by
        dropping table references, never by copying KV bytes.  Returns how
        many pages actually went back to the free list (shared pages only
        drop a reference)."""
        keep = -(-n_tokens // self.page_size) if n_tokens > 0 else 0
        pages = self.slot_pages[slot]
        freed = 0
        while len(pages) > keep:
            p = pages.pop()
            freed += self.alloc.release(p)
            self.table[slot, len(pages)] = self.alloc.n_pages
        return freed

    def fork_for_write(self, slot: int, first_pos: int, last_pos: int):
        """Make every page covering positions ``[first_pos, last_pos)`` of
        ``slot`` private, forking shared ones.  Returns ``(src, dst)`` page
        lists for the device copy (empty when nothing was shared)."""
        pages = self.slot_pages[slot]
        lo = first_pos // self.page_size
        hi = min(-(-last_pos // self.page_size), len(pages))
        src, dst = [], []
        for j in range(lo, hi):
            if self.alloc.refcount(pages[j]) > 1:
                new = self.alloc.fork(pages[j])
                src.append(pages[j])
                dst.append(new)
                pages[j] = new
                self.table[slot, j] = new
        return src, dst

    # ---- telemetry ----------------------------------------------------
    def occupancy(self) -> dict:
        a = self.alloc
        return {
            "n_pages": a.n_pages, "page_size": a.page_size,
            "pages_used": a.pages_in_use, "pages_shared": a.pages_shared,
            "peak_pages": a.peak_pages, "cow_forks": a.n_forks,
            "prefix_shares": a.n_shares,
        }
