"""Routed speculative decoding: a cheap pool member drafts, the routed
(expensive) model verifies — the strong/weak pair the router already holds
becomes a latency optimization, not only a cost one.

One :class:`SpeculativeEngine` wraps TWO paged :class:`ServingEngine`s over
the same token stream: the *draft* engine runs its fused K+1-step scan to
propose ``spec_k`` tokens, then the *target* engine scores all of them in a
single fused span dispatch (:meth:`Model.decode_span` — one GEMM over K+1
positions instead of K+1 sequential decode steps; that batching is the whole
speedup).  Both engines keep their own paged KV over the PR 6 machinery, so
batch-prompt prefixes share pages on each side and a rejected draft suffix
rolls back by *block-table truncation* (``PagedCacheManager.truncate_slot``
plus one donated per-slot length reset) — no KV bytes are ever copied back.

Acceptance rule (deterministic-match): the verify pass computes the target's
OWN next token at every draft position — greedy argmax, or, for sampled
requests, :func:`sample_tokens` with the identical position-folded key the
target-only engine would use.  Draft token ``d_i`` is accepted iff it equals
that choice; the first mismatch emits the target's choice instead (the
"fallback resample", realized as the target's own reproducible sample), and
a fully accepted window emits the target's K+1-th token as a bonus.  The
emitted stream is therefore *literally* the target-only stream — greedy AND
sampled speculative outputs are bit-identical to target-only decoding by
construction (``Model.decode_span`` is bitwise-equal to sequential
``decode_step``s; parity-tested), and the draft model only ever moves the
accept rate, never the text.

Cadence invariant between rounds: with ``n`` tokens emitted, both engines
hold KV for positions ``[0, prompt + n − 1)`` — the last emitted token is
fed (and its KV written) by the next round's dispatches.  The draft scan
runs K+1 steps so its cache also covers the accepted window; rollback
truncates both sides to the post-acceptance length.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.engine import (Request, ServingEngine, _fold_keys,
                                  sample_tokens)

__all__ = ["SpeculativeEngine"]


class SpeculativeEngine:
    """Draft/verify serving engine; drop-in for :class:`ServingEngine`.

    ``spec_k`` is the speculation depth: each round drafts ``spec_k`` tokens
    with the cheap model and verifies them (plus the bonus position) in one
    fused target dispatch.  Both inner engines are paged with
    ``decode_block = spec_k + 1`` — the write range each round is the K+1
    positions ``[prompt + n − 1, prompt + n + spec_k)``.

    The public serving surface matches :class:`ServingEngine` (``serve``,
    ``generate_text``, ``kv_occupancy``, the dispatch counters), so
    :class:`ServedPoolMember` and the replica factory treat it uniformly.
    """

    def __init__(self, model: Model, params, draft_model: Model, draft_params,
                 *, max_slots: int = 8, max_len: int = 1024, spec_k: int = 4,
                 page_size: int = 16, share_prefix: bool = True,
                 eos_id: int = ByteTokenizer.eos,
                 pad_id: int = ByteTokenizer.pad):
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.spec_k = int(spec_k)
        self.model = model              # target — replica factories rebuild
        self.params = params            # from these, like a plain engine
        self.draft_model = draft_model
        self.draft_params = draft_params
        self.max_slots = max_slots
        self.max_len = max_len
        self.paged = True
        self.page_size = int(page_size)
        self.share_prefix = bool(share_prefix)
        self.decode_block = self.spec_k + 1
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.target = ServingEngine(
            model, params, max_slots=max_slots, max_len=max_len,
            decode_block=self.spec_k + 1, paged=True, page_size=page_size,
            share_prefix=share_prefix, eos_id=eos_id, pad_id=pad_id)
        # the draft never retires on its own: eos_id=-1 suppresses EOS (and
        # admission-time retirement) — the target's stream decides lifecycle
        self.draft = ServingEngine(
            draft_model, draft_params, max_slots=max_slots, max_len=max_len,
            decode_block=self.spec_k + 1, paged=True, page_size=page_size,
            share_prefix=share_prefix, eos_id=-1, pad_id=pad_id)
        self.tok = self.target.tok
        # speculative telemetry
        self.n_rounds = 0               # draft+verify dispatch pairs
        self.n_drafted = 0              # draft tokens proposed (k per slot-round)
        self.n_accepted = 0             # draft tokens accepted by the target
        self.n_bonus = 0                # bonus tokens from fully accepted windows

        target_model = model
        n_slots = max_slots

        def _reset_lens(cache, lens):
            # fused KV-length rollback: every per-slot length leaf
            # ((..., max_slots) int32) snaps to the host-computed value —
            # runs INSIDE the draft/verify jits, so the rollback costs no
            # extra dispatch (pages were already dropped by table truncation)
            def fix(leaf):
                if (leaf.dtype == jnp.int32 and leaf.ndim >= 1
                        and leaf.shape[-1] == n_slots):
                    return jnp.broadcast_to(lens.astype(jnp.int32), leaf.shape)
                return leaf
            return jax.tree.map(fix, cache)

        dk = self.spec_k + 1

        @partial(jax.jit, static_argnames=("sample",), donate_argnums=(1,))
        def _draft_k(params, cache, table, lens, last, n_out, keys=None,
                     temp=None, top_k=None, top_p=None, *, sample=False):
            """K+1 fused draft steps: feed the last emitted token, then each
            proposal autoregressively.  No EOS/limit masking — the target's
            stream decides lifecycle; the final step only exists to write
            d_{K-1}'s KV (its proposal is discarded host-side).  ``lens``
            resets the per-slot KV lengths first (rollback from the previous
            round / fresh admission, fused into this dispatch)."""
            cache = _reset_lens(cache, lens)

            def step(carry, _):
                sc, lst, n = carry
                logits, sc = draft_model.decode_step(params, lst[:, None], sc,
                                                     table=table)
                if sample:
                    nxt = sample_tokens(logits[:, 0], _fold_keys(keys, n),
                                        temp, top_k, top_p)
                else:
                    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                return (sc, nxt, n + 1), nxt

            (cache, _, _), toks = jax.lax.scan(
                step, (cache, last, n_out), None, length=dk)
            return cache, toks                               # (K+1, slots)

        self._draft_k = _draft_k

        @partial(jax.jit, static_argnames=("sample",), donate_argnums=(1,))
        def _verify_k(params, cache, table, lens, xs, n_out, keys=None,
                      temp=None, top_k=None, top_p=None, *, sample=False):
            """One fused target dispatch scoring the whole draft window.

            ``xs``: (B, K+1) — the last emitted token then the K drafts.
            Returns the donated cache and the (K+1, B) tokens the TARGET
            would emit at each position (argmax, or the position-keyed
            sample) — span logits are bitwise-equal to sequential decode
            steps, so these are exactly the target-only stream.  ``lens``
            as in ``_draft_k``.
            """
            cache = _reset_lens(cache, lens)
            logits, cache = target_model.decode_span(params, xs, cache,
                                                     table=table)
            toks = []
            for i in range(xs.shape[1]):
                if sample:
                    t = sample_tokens(logits[:, i],
                                      _fold_keys(keys, n_out + i),
                                      temp, top_k, top_p)
                else:
                    t = jnp.argmax(logits[:, i], axis=-1).astype(jnp.int32)
                toks.append(t)
            return cache, jnp.stack(toks, axis=0)

        self._verify_k = _verify_k

    # ---- telemetry ----------------------------------------------------
    @property
    def n_decode_calls(self) -> int:
        return self.target.n_decode_calls + self.draft.n_decode_calls

    @property
    def n_decode_steps(self) -> int:
        return self.target.n_decode_steps + self.draft.n_decode_steps

    @property
    def n_prefill_calls(self) -> int:
        return self.target.n_prefill_calls + self.draft.n_prefill_calls

    def accept_rate(self) -> float:
        return self.n_accepted / max(self.n_drafted, 1)

    def kv_occupancy(self) -> dict:
        """Target-side paged occupancy plus the draft pool's footprint."""
        occ = self.target.kv_occupancy()
        docc = self.draft.kv_occupancy()
        occ["draft_kv_bytes"] = docc["kv_bytes"]
        occ["kv_bytes"] += docc["kv_bytes"]
        occ["peak_kv_bytes"] += docc["peak_kv_bytes"]
        return occ

    # ---- lifecycle ----------------------------------------------------
    def _sync_shadows(self):
        """Mirror freshly admitted target requests into the draft engine.

        The shadow request shares tokens, generation config (same seed ⇒
        the draft's sampled proposals draw with the target's position-folded
        keys — that is what makes sampled drafts agree when the two
        distributions do), and the target's first emitted token.  The draft
        admission writes prompt KV only, which is exactly the round
        invariant at n = 1 emitted token: cache covers ``prompt + n − 1``.
        """
        reqs, slots = [], []
        for i, req in enumerate(self.target.slot_req):
            if req is None or self.draft.slot_req[i] is not None:
                continue
            shadow = Request(rid=req.rid, tokens=list(req.tokens),
                             max_new=self.max_len, gen=req.gen)
            reqs.append((shadow, req))
            slots.append(i)
        if not reqs:
            return
        self.draft._admit_batch([s for s, _ in reqs], slots)
        for (shadow, req), slot in zip(reqs, slots):
            # the draft's own first token is discarded: the stream is the
            # target's; re-point the shadow at it (the draft's admission
            # wrote prompt KV only, so no rollback is needed here)
            shadow.out_tokens[:] = list(req.out_tokens)
            shadow.done = False
            assert self.draft.slot_req[slot] is shadow

    def _release_slot(self, slot: int):
        self.target._retire(slot)
        shadow = self.draft.slot_req[slot]
        if shadow is not None:
            self.draft._retire(slot)

    # ---- serving ------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Request]:
        """Continuous-batching speculative serving; the emitted streams are
        bit-identical to ``ServingEngine.serve`` on the target alone."""
        k = self.spec_k
        queue = list(requests)
        while queue or self.target._active_slots():
            self.target._admit_free(queue)
            self._sync_shadows()
            active = self.target._active_slots()
            if not active:
                continue
            last, act, n_out, limit = self.target._slot_state()
            sample, keys, temp, top_k, top_p = self.target._sampling_state()
            kw = {}
            if sample:
                kw = dict(keys=jnp.asarray(keys), temp=jnp.asarray(temp),
                          top_k=jnp.asarray(top_k), top_p=jnp.asarray(top_p),
                          sample=True)
            live = max(len(self.target.slot_req[i].tokens)
                       + len(self.target.slot_req[i].out_tokens)
                       for i in active)
            horizon = min(self.max_len,
                          self.target._bucket_len(live + k + 1))
            # host-side KV lengths at round entry: with n tokens emitted the
            # cache must cover [0, prompt + n − 1) — both dispatches reset
            # their length leaves to this (fused rollback; pages were already
            # dropped by truncate_slot at the end of the previous round)
            lens = np.zeros(self.max_slots, np.int32)
            for i in active:
                req = self.target.slot_req[i]
                lens[i] = len(req.tokens) + len(req.out_tokens) - 1
            lens_j = jnp.asarray(lens)
            # ---- draft: K proposals via the fused scan (K+1 steps — the
            # last one writes d_{K-1}'s KV; its proposal is discarded).
            # offset=-1 because the scan re-feeds the last emitted token
            # whose KV is not yet written.
            dtable = self.draft._prepare_paged(active, horizon, offset=-1)
            self.draft.cache, d_toks = self._draft_k(
                self.draft.params, self.draft.cache, dtable, lens_j,
                jnp.asarray(last), jnp.asarray(n_out), **kw)
            self.draft.n_decode_calls += 1
            self.draft.n_decode_steps += k + 1
            d_toks = np.asarray(d_toks)                      # (K+1, slots)
            # ---- verify: ONE fused target dispatch over the whole window
            xs = np.zeros((self.max_slots, k + 1), np.int32)
            xs[:, 0] = last
            xs[:, 1:] = d_toks[:k].T
            ttable = self.target._prepare_paged(active, horizon, offset=-1)
            self.target.cache, t_toks = self._verify_k(
                self.target.params, self.target.cache, ttable, lens_j,
                jnp.asarray(xs), jnp.asarray(n_out), **kw)
            self.target.n_decode_calls += 1
            self.target.n_decode_steps += k + 1
            t_toks = np.asarray(t_toks)                      # (K+1, slots)
            self.n_rounds += 1
            # ---- host accept/reject + lifecycle
            lens = np.zeros(self.max_slots, np.int32)
            for i in active:
                req = self.target.slot_req[i]
                n = int(n_out[i])
                lim = int(limit[i])
                block: list[int] = []
                done = False
                self.n_drafted += k
                for j in range(k + 1):
                    tt = int(t_toks[j, i])
                    match = j < k and tt == int(d_toks[j, i])
                    block.append(tt)
                    n += 1
                    if match:
                        self.n_accepted += 1
                    elif j == k:
                        self.n_bonus += 1       # fully accepted window
                    if tt == self.eos_id or n >= lim:
                        done = True
                        break
                    if not match:
                        # j < k: mismatch — the target's own token replaced
                        # the draft; j == k: the bonus token ends the window
                        break
                req.out_tokens.extend(block)
                shadow = self.draft.slot_req[i]
                shadow.out_tokens[:] = list(req.out_tokens)
                if done:
                    self._release_slot(i)
                else:
                    # roll back both KVs to the post-acceptance length: pages
                    # by table truncation now, length leaves by the fused
                    # reset at the next round's dispatch entry
                    keep = len(req.tokens) + len(req.out_tokens) - 1
                    self.target.kv.truncate_slot(i, keep)
                    self.draft.kv.truncate_slot(i, keep)
                if req.on_tokens is not None:
                    req.on_tokens(block, req.done)
        return requests

    # convenience --------------------------------------------------------
    def generate_text(self, prompts: list[str], max_new: int = 32,
                      gen=None) -> list[str]:
        if gen is not None:
            max_new = gen.max_new
        reqs = [Request(rid=i, tokens=self.tok.encode(p), max_new=max_new,
                        gen=gen)
                for i, p in enumerate(prompts)]
        self.serve(reqs)
        outs = []
        for r in reqs:
            ids = r.out_tokens
            if self.eos_id in ids:
                ids = ids[: ids.index(self.eos_id)]
            outs.append(self.tok.decode(ids))
        return outs
