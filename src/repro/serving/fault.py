"""Fault tolerance for pool invocations: deadlines, retries, straggler
re-dispatch, health tracking, circuit breaking, chaos injection.

At 1000+ node scale, a routing scheduler's batches land on many serving
replicas; slow or dead replicas must not stall the workload.  Two layers:

``FaultTolerantInvoker`` wraps any pool member and implements:

  * deadline-based straggler detection (p50-adaptive or fixed),
  * bounded retries with a backup replica (speculative re-dispatch),
  * consecutive-failure health ejection with cool-down re-admission,
  * an invocation journal so a crashed scheduler can re-enqueue in-flight
    batches on recovery (no query silently dropped).

``CircuitBreaker`` is the online-serving counterpart (closed → open →
half-open): an open breaker removes its model from the scheduler's candidate
space entirely (see :func:`repro.core.scheduler.restrict_space`), instead of
retrying per invocation.  ``FlakyMember`` injects failures deterministically
so tests and benchmarks can drive the trip/reroute/recovery paths.

``ReplicaTracker`` sits one level *below* the breaker: a
:class:`repro.serving.pool.ReplicaSet` is ONE member (one breaker, one entry
in the candidate space) made of N interchangeable replicas, and the tracker
keeps per-replica health — consecutive-failure ejection with cooldown
re-admission and latency stats — so least-loaded dispatch can route around a
dead replica while the set as a whole keeps serving (degraded, not broken).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Optional

import numpy as np


@dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0      # deadline = factor × running p50 latency
    min_deadline_s: float = 2.0
    max_retries: int = 2
    eject_after: int = 3              # consecutive failures before ejection
    cooldown_s: float = 30.0


@dataclass
class _Health:
    consecutive_failures: int = 0
    ejected_until: float = 0.0
    latencies: list = field(default_factory=list)

    def p50(self) -> float:
        return float(np.median(self.latencies)) if self.latencies else 0.0


class FaultTolerantInvoker:
    """Wraps pool members; ``invoke(member_idx, fn)`` runs fn with deadline +
    retry + journal semantics.  ``fn`` must be idempotent (batched LLM calls
    are: re-invoking re-bills but returns equivalent results)."""

    def __init__(self, n_members: int, policy: Optional[StragglerPolicy] = None,
                 backup_of: Optional[Callable[[int], Optional[int]]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy or StragglerPolicy()
        self.health = [_Health() for _ in range(n_members)]
        self.backup_of = backup_of or (lambda k: None)
        self.clock = clock
        self.journal: list[dict] = []     # in-flight + completed invocations
        self.n_redispatched = 0
        self.n_retries = 0

    def healthy(self, k: int) -> bool:
        return self.clock() >= self.health[k].ejected_until

    def _deadline(self, k: int) -> float:
        p50 = self.health[k].p50()
        return max(self.policy.min_deadline_s, self.policy.deadline_factor * p50)

    def invoke(self, k: int, fn: Callable[[], object], *, latency_of=None,
               tag: str = ""):
        """Run fn() against member k with fault handling.

        ``latency_of(result)``: extracts the (simulated or measured) latency;
        when it exceeds the deadline the invocation counts as a straggler and
        is re-dispatched to the backup member (if any) — the faster result
        wins, which is exactly speculative execution.
        """
        entry = {"member": k, "tag": tag, "state": "inflight", "t": self.clock()}
        self.journal.append(entry)
        attempts = 0
        last_err = None
        while attempts <= self.policy.max_retries:
            attempts += 1
            try:
                result = fn()
                lat = latency_of(result) if latency_of else 0.0
                h = self.health[k]
                h.latencies.append(lat)
                if len(h.latencies) > 256:
                    h.latencies.pop(0)
                if lat > self._deadline(k):
                    backup = self.backup_of(k)
                    if backup is not None and self.healthy(backup):
                        self.n_redispatched += 1
                        entry["state"] = "redispatched"
                        return self.invoke(backup, fn, latency_of=latency_of, tag=tag)
                h.consecutive_failures = 0
                entry["state"] = "done"
                return result
            except Exception as e:              # noqa: BLE001 — replica fault
                last_err = e
                self.n_retries += 1
                h = self.health[k]
                h.consecutive_failures += 1
                if h.consecutive_failures >= self.policy.eject_after:
                    h.ejected_until = self.clock() + self.policy.cooldown_s
                    backup = self.backup_of(k)
                    if backup is not None and self.healthy(backup):
                        entry["state"] = "redispatched"
                        return self.invoke(backup, fn, latency_of=latency_of, tag=tag)
        entry["state"] = "failed"
        raise RuntimeError(f"member {k} failed after {attempts} attempts") from last_err

    def inflight(self) -> list[dict]:
        """Batches to re-enqueue after a scheduler crash (recovery path)."""
        return [e for e in self.journal if e["state"] == "inflight"]


# ---------------------------------------------------------------------------
# per-replica health (ReplicaSet members)
# ---------------------------------------------------------------------------

@dataclass
class ReplicaPolicy:
    eject_after: int = 2              # consecutive failures before ejection
    cooldown_s: float = 30.0          # ejected → probe re-admission delay
    latency_window: int = 128         # per-replica latency samples retained


@dataclass
class _ReplicaState:
    n_ok: int = 0
    n_failures: int = 0
    n_ejections: int = 0
    consecutive_failures: int = 0
    ejected_until: float = 0.0
    retired: bool = False            # drained out by the autoscaler (scale-down)
    latencies: list = field(default_factory=list)

    def p50(self) -> float:
        return float(np.median(self.latencies)) if self.latencies else 0.0


class ReplicaTracker:
    """Per-replica health/latency inside one :class:`~repro.serving.pool.
    ReplicaSet` member.

    The member-level :class:`CircuitBreaker` decides whether the *set* is in
    the candidate space; this tracker decides which replica *within* the set
    may take the next batch.  Ejection mirrors half-open breaker semantics at
    replica granularity: ``eject_after`` consecutive failures remove a replica
    from dispatch for ``cooldown_s``, after which it is offered exactly one
    probe batch — a success re-admits it, another failure re-ejects it for a
    fresh cooldown (``consecutive_failures`` only resets on success).  The
    clock is injectable so virtual-time tests drive recovery deterministically.
    """

    def __init__(self, n_replicas: int, policy: Optional[ReplicaPolicy] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy or ReplicaPolicy()
        self.clock = clock
        self.replicas = [_ReplicaState() for _ in range(n_replicas)]

    def healthy(self, r: int) -> bool:
        st = self.replicas[r]
        return not st.retired and self.clock() >= st.ejected_until

    # -- autoscaling hooks (ReplicaSet.scale_to drives these) ----------------
    def add_replica(self) -> int:
        """Register a freshly attached replica; returns its index."""
        self.replicas.append(_ReplicaState())
        return len(self.replicas) - 1

    def retire(self, r: int) -> None:
        """Scale-down eject: the replica takes no new dispatch (in-flight work
        drains normally) until :meth:`restore` un-retires it."""
        self.replicas[r].retired = True

    def restore(self, r: int) -> None:
        """Re-admit a retired replica with a clean health slate (a parked
        replica's stale failure streak must not instantly re-eject it)."""
        st = self.replicas[r]
        st.retired = False
        st.consecutive_failures = 0
        st.ejected_until = 0.0

    def n_active(self) -> int:
        """Replicas not retired by scale-down (healthy or not)."""
        return sum(not st.retired for st in self.replicas)

    def record_success(self, r: int, latency_s: float = 0.0) -> None:
        st = self.replicas[r]
        st.n_ok += 1
        st.consecutive_failures = 0
        st.ejected_until = 0.0
        st.latencies.append(float(latency_s))
        if len(st.latencies) > self.policy.latency_window:
            st.latencies.pop(0)

    def record_failure(self, r: int) -> None:
        st = self.replicas[r]
        st.n_failures += 1
        st.consecutive_failures += 1
        if st.consecutive_failures >= self.policy.eject_after:
            st.ejected_until = self.clock() + self.policy.cooldown_s
            st.n_ejections += 1

    def n_healthy(self) -> int:
        return sum(self.healthy(r) for r in range(len(self.replicas)))

    def snapshot(self) -> list[dict]:
        """Per-replica health/latency rows (benchmark + debug surface)."""
        return [dict(replica=r, healthy=self.healthy(r), retired=st.retired,
                     n_ok=st.n_ok, n_failures=st.n_failures,
                     n_ejections=st.n_ejections, p50_latency_s=st.p50())
                for r, st in enumerate(self.replicas)]


# ---------------------------------------------------------------------------
# circuit breaking (online serving)
# ---------------------------------------------------------------------------

class CircuitState(str, Enum):
    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"


@dataclass
class BreakerPolicy:
    failure_threshold: int = 3        # consecutive failures before tripping
    recovery_time_s: float = 30.0     # open → half-open probe delay


class CircuitBreaker:
    """Per-model breaker: closed → (failures ≥ threshold) → open →
    (recovery time elapsed) → half-open → one probe decides.

    Unlike the invoker's per-call retry, the breaker acts at the *scheduling*
    level: while open, the model is absent from the candidate space and every
    query that would have landed on it is rescheduled onto survivors.  While
    half-open, the online server sends exactly one probe group per window
    (probe failures don't burn the queries' reroute budget).  The clock is
    injectable so the online server's virtual time drives recovery.
    """

    def __init__(self, policy: Optional[BreakerPolicy] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy or BreakerPolicy()
        self.clock = clock
        self.state = CircuitState.CLOSED
        self.failure_count = 0
        self.last_failure_at: Optional[float] = None
        self.n_trips = 0

    def allow_request(self) -> bool:
        if self.state == CircuitState.CLOSED:
            return True
        if self.state == CircuitState.OPEN:
            if (self.last_failure_at is not None
                    and self.clock() - self.last_failure_at >= self.policy.recovery_time_s):
                self.state = CircuitState.HALF_OPEN
                return True
            return False
        return True                    # HALF_OPEN: allow the probe

    def record_success(self) -> None:
        self.failure_count = 0
        self.state = CircuitState.CLOSED
        self.last_failure_at = None

    def record_failure(self) -> None:
        self.failure_count += 1
        self.last_failure_at = self.clock()
        if self.state == CircuitState.HALF_OPEN or \
                self.failure_count >= self.policy.failure_threshold:
            if self.state != CircuitState.OPEN:
                self.n_trips += 1
            self.state = CircuitState.OPEN


class FlakyMember:
    """Chaos wrapper around a pool member: raises on invocations in
    ``[fail_from, fail_until)`` (counted per wrapper), proxies otherwise.

    Deterministic by construction, so tests and benchmarks can script a
    mid-run outage (breaker trips, queries reroute) and — by bounding the
    span — a recovery (half-open probe succeeds, breaker closes).
    """

    def __init__(self, inner, fail_from: int = 0, fail_until: int = 10**9):
        self.inner = inner
        self.fail_from = fail_from
        self.fail_until = fail_until
        self.n_calls = 0
        self.n_faults = 0

    @property
    def name(self):
        return self.inner.name

    @property
    def c_in(self):
        return self.inner.c_in

    @property
    def c_out(self):
        return self.inner.c_out

    @property
    def context_len(self):
        return self.inner.context_len

    def invoke_batch(self, wl, batch_idx):
        call = self.n_calls
        self.n_calls += 1
        if self.fail_from <= call < self.fail_until:
            self.n_faults += 1
            raise RuntimeError(f"{self.name}: injected fault (call {call})")
        return self.inner.invoke_batch(wl, batch_idx)

    def evaluate(self, wl, idx, batch_size, rng=None):
        return self.inner.evaluate(wl, idx, batch_size, rng)


class ChaosMember:
    """Seeded fault injector around a pool member (docs/robustness.md).

    Where :class:`FlakyMember` scripts one hard outage window, ChaosMember
    composes the realistic degradation modes a robustness benchmark needs —
    all deterministic given ``seed`` and the wrapper's call sequence:

      * **latency noise** — each surviving call's reported ``latency_s``
        gains an Exp(``latency_noise_s``) draw (virtual: no wall sleep, so
        simulated-pool benchmarks stay fast);
      * **slow degrade** — call ``i`` additionally gains ``degrade_s * i``,
        modelling a replica that rots (memory pressure, thermal throttle);
      * **error bursts** — calls in ``[fail_from, fail_until)`` raise with
        probability ``error_rate`` (1.0 = hard outage, the FlakyMember case);
      * **hangs** — calls in ``[hang_from, hang_until)`` block the
        dispatching thread for ``hang_s`` *wall* seconds and then raise.
        This is the scenario :class:`repro.serving.pool.ReplicaSet`'s
        ``dispatch_timeout_s`` exists for: without a timeout a hung replica
        wedges the serving thread for the full hang.

    Counters (``n_calls``, ``n_faults``, ``n_hangs``) are exact given the
    windows, so benchmarks can gate on them bit-for-bit.  The wrapper is a
    full pool-member proxy (pricing, feature probes, ``evaluate``), so it
    nests anywhere a member does — including as a replica inside a
    ReplicaSet.
    """

    def __init__(self, inner, *, seed: int = 0,
                 latency_noise_s: float = 0.0, degrade_s: float = 0.0,
                 fail_from: int = 10**9, fail_until: int = 10**9,
                 error_rate: float = 1.0,
                 hang_from: int = 10**9, hang_until: int = 10**9,
                 hang_s: float = 5.0):
        self.inner = inner
        self.rng = np.random.default_rng(seed)
        self.latency_noise_s = float(latency_noise_s)
        self.degrade_s = float(degrade_s)
        self.fail_from, self.fail_until = int(fail_from), int(fail_until)
        self.error_rate = float(error_rate)
        self.hang_from, self.hang_until = int(hang_from), int(hang_until)
        self.hang_s = float(hang_s)
        self.n_calls = 0
        self.n_faults = 0
        self.n_hangs = 0

    @property
    def name(self):
        return self.inner.name

    @property
    def c_in(self):
        return self.inner.c_in

    @property
    def c_out(self):
        return self.inner.c_out

    @property
    def context_len(self):
        return self.inner.context_len

    @property
    def supports_streams(self):
        return bool(getattr(self.inner, "supports_streams", False))

    @property
    def supports_generation(self):
        return bool(getattr(self.inner, "supports_generation", False))

    def invoke_batch(self, wl, batch_idx, **kw):
        call = self.n_calls
        self.n_calls += 1
        if self.hang_from <= call < self.hang_until:
            self.n_hangs += 1
            time.sleep(self.hang_s)               # wall-clock: wedge the caller
            raise RuntimeError(f"{self.name}: injected hang (call {call})")
        if self.fail_from <= call < self.fail_until and \
                self.rng.random() < self.error_rate:
            self.n_faults += 1
            raise RuntimeError(f"{self.name}: injected fault (call {call})")
        out = self.inner.invoke_batch(wl, batch_idx, **kw)
        extra = self.degrade_s * call
        if self.latency_noise_s > 0.0:
            extra += float(self.rng.exponential(self.latency_noise_s))
        if extra > 0.0:
            from dataclasses import replace
            out = replace(out, latency_s=out.latency_s + extra)
        return out

    def evaluate(self, wl, idx, batch_size, rng=None):
        return self.inner.evaluate(wl, idx, batch_size, rng)
