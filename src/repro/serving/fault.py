"""Fault tolerance for pool invocations: deadlines, retries, straggler
re-dispatch, health tracking.

At 1000+ node scale, a routing scheduler's batches land on many serving
replicas; slow or dead replicas must not stall the workload.  The invoker
wraps any pool member and implements:

  * deadline-based straggler detection (p50-adaptive or fixed),
  * bounded retries with a backup replica (speculative re-dispatch),
  * consecutive-failure health ejection with cool-down re-admission,
  * an invocation journal so a crashed scheduler can re-enqueue in-flight
    batches on recovery (no query silently dropped).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class StragglerPolicy:
    deadline_factor: float = 3.0      # deadline = factor × running p50 latency
    min_deadline_s: float = 2.0
    max_retries: int = 2
    eject_after: int = 3              # consecutive failures before ejection
    cooldown_s: float = 30.0


@dataclass
class _Health:
    consecutive_failures: int = 0
    ejected_until: float = 0.0
    latencies: list = field(default_factory=list)

    def p50(self) -> float:
        return float(np.median(self.latencies)) if self.latencies else 0.0


class FaultTolerantInvoker:
    """Wraps pool members; ``invoke(member_idx, fn)`` runs fn with deadline +
    retry + journal semantics.  ``fn`` must be idempotent (batched LLM calls
    are: re-invoking re-bills but returns equivalent results)."""

    def __init__(self, n_members: int, policy: Optional[StragglerPolicy] = None,
                 backup_of: Optional[Callable[[int], Optional[int]]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy or StragglerPolicy()
        self.health = [_Health() for _ in range(n_members)]
        self.backup_of = backup_of or (lambda k: None)
        self.clock = clock
        self.journal: list[dict] = []     # in-flight + completed invocations
        self.n_redispatched = 0
        self.n_retries = 0

    def healthy(self, k: int) -> bool:
        return self.clock() >= self.health[k].ejected_until

    def _deadline(self, k: int) -> float:
        p50 = self.health[k].p50()
        return max(self.policy.min_deadline_s, self.policy.deadline_factor * p50)

    def invoke(self, k: int, fn: Callable[[], object], *, latency_of=None,
               tag: str = ""):
        """Run fn() against member k with fault handling.

        ``latency_of(result)``: extracts the (simulated or measured) latency;
        when it exceeds the deadline the invocation counts as a straggler and
        is re-dispatched to the backup member (if any) — the faster result
        wins, which is exactly speculative execution.
        """
        entry = {"member": k, "tag": tag, "state": "inflight", "t": self.clock()}
        self.journal.append(entry)
        attempts = 0
        last_err = None
        while attempts <= self.policy.max_retries:
            attempts += 1
            try:
                result = fn()
                lat = latency_of(result) if latency_of else 0.0
                h = self.health[k]
                h.latencies.append(lat)
                if len(h.latencies) > 256:
                    h.latencies.pop(0)
                if lat > self._deadline(k):
                    backup = self.backup_of(k)
                    if backup is not None and self.healthy(backup):
                        self.n_redispatched += 1
                        entry["state"] = "redispatched"
                        return self.invoke(backup, fn, latency_of=latency_of, tag=tag)
                h.consecutive_failures = 0
                entry["state"] = "done"
                return result
            except Exception as e:              # noqa: BLE001 — replica fault
                last_err = e
                self.n_retries += 1
                h = self.health[k]
                h.consecutive_failures += 1
                if h.consecutive_failures >= self.policy.eject_after:
                    h.ejected_until = self.clock() + self.policy.cooldown_s
                    backup = self.backup_of(k)
                    if backup is not None and self.healthy(backup):
                        entry["state"] = "redispatched"
                        return self.invoke(backup, fn, latency_of=latency_of, tag=tag)
        entry["state"] = "failed"
        raise RuntimeError(f"member {k} failed after {attempts} attempts") from last_err

    def inflight(self) -> list[dict]:
        """Batches to re-enqueue after a scheduler crash (recovery path)."""
        return [e for e in self.journal if e["state"] == "inflight"]
