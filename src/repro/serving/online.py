"""Online RoBatch serving: streaming admission, windowed scheduling under a
rolling budget, and concurrent cross-model dispatch.

The paper's routing stage (§5, Alg. 1) schedules a *fixed* query set against a
*fixed* budget.  This layer runs the same greedy budget/batch-size assignment
over a live arrival stream:

    arrivals ──► admission window (deadline) ──► response cache
        ──► policy.plan_window(...) against a token-bucket budget ($/s)
        ──► physical batch plan ──► concurrent dispatch
        ──► circuit breaking + rescheduling onto surviving models

Design points:

* **Pluggable policies.**  The per-window decision is any registered
  :class:`repro.api.SchedulingPolicy` — the server only consumes
  ``window_space`` (admission costs) and ``plan_window`` (the decision), so
  RoBatch's windowed Alg. 1, the adapted baselines' budget-aware two-point
  spaces and user strategies all serve interchangeably.

* **Deadline windows.**  Requests accumulate for ``window_s`` seconds, then
  one scheduling round assigns every pending query a (model, batch) state.
  Larger windows amortize the shared system prompt better (more queries per
  physical batch) at the price of queueing latency — the knob benchmarked by
  ``benchmarks/online_throughput.py``.
* **Rolling budget.**  A token bucket refills at ``budget_per_s`` dollars/s up
  to ``burst_s`` seconds of burst.  Each round schedules against the current
  balance; the *realized* (exact, Eq. 4) cost of dispatched batches is then
  drawn down, so estimate-vs-actual drift self-corrects next round.  A query
  whose cheapest state exceeds the bucket *capacity* can never be afforded and
  is shed immediately; one that is merely unaffordable *now* waits.
* **Circuit breaking.**  Each pool member carries a
  :class:`repro.serving.fault.CircuitBreaker`.  An open breaker removes the
  model from the candidate space (``restrict_space``) and the failed window's
  queries are rescheduled onto survivors next round.
* **Response cache.**  The batch-prompt wire format is a pure function of the
  query text (docs/batch_format.md), so responses are cacheable by query
  identity; a hit completes immediately and bills zero cost.  Duplicate
  queries *within* one window coalesce onto a single scheduled instance.
  ``OnlineConfig(semantic_cache=...)`` layers a second, embedding-space cache
  behind the exact one (:mod:`repro.serving.semcache`): near-duplicate queries
  above a cosine threshold reuse a cached answer at zero cost, discounted by a
  calibrated utility-loss estimate ε(sim) — see docs/caching.md.
* **Virtual time.**  The server is tick-driven on an injectable clock: service
  latencies come from ``BatchResult.latency_s`` (measured for real engines,
  simulated for the calibrated pool), so benchmarks never sleep.

* **Real time.**  ``OnlineConfig(realtime=True)`` paces the same tick loop
  against a wall clock instead: ``run`` sleeps to each window boundary (late
  windows are accounted in ``WindowReport.late_s``, never skipped), the
  ``BudgetBucket`` refills on elapsed wall seconds, and ``run_live`` fronts a
  :class:`LiveArrivalSource` thread that submits a seeded arrival stream at
  its wall-clock due times.  The time source is injectable
  (:class:`MonotonicClock` in production, :class:`FakeClock` in tests), and
  arrival *generation* is split from *pacing* (:func:`arrival_stream` vs. the
  pacer), so one seeded stream replays identically in both modes.

* **Live ingress.**  ``submit_request`` is the bridge the HTTP front-end
  (:mod:`repro.http`) sits on: it returns an :class:`OnlineRequest` carrying a
  ``done_event`` the caller blocks on and, for streamed responses, a
  :class:`StreamSink` the batch-prompt demultiplexer pushes per-decode-block
  text deltas into.  ``run_bridge`` paces the same windowed ``step()`` loop
  against the wall clock with no pre-generated arrival list — requests arrive
  concurrently from handler threads.  ``_complete`` finalizes every request
  with its answer text (``OnlineRequest.content``): the parsed generation for
  real engines, a deterministic synthesized line for calibrated simulators,
  the cached text on a cache hit.

* **Replica capacity.**  A replicated member
  (:class:`repro.serving.pool.ReplicaSet`) can run at most ``n_replicas``
  batch-groups concurrently, so the server threads per-member group caps into
  the windowed scheduler (``group_caps`` in
  :func:`repro.core.scheduler.greedy_schedule_window`).  Caps-aware policies
  take the caps into the frontier walk itself (the capacity-aware Δ-heap
  packs over-cap members into fewer, larger batches before deferring); the
  server's own per-group backstop holds whatever caps-unaware plans overflow
  — capacity backpressure composes with budget backpressure instead of
  silently queueing on one engine's lock.

* **Autoscaling.**  ``OnlineConfig(autoscale=AutoscalePolicy(...))`` attaches
  a :class:`repro.serving.autoscale.Autoscaler`: each window's backlog
  (capacity-held + packed queries, queue depth, realtime lateness) feeds a
  hysteresis/cooldown control loop that grows or shrinks every scalable
  member via ``ReplicaSet.scale_to`` — the new capacity lands in the caps the
  next window plans against.
"""
from __future__ import annotations

import inspect
import queue
import sys
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional, Sequence

import numpy as np

from repro.core.scheduler import attach_free_assignments, restrict_space, take_rows
from repro.serving.autoscale import Autoscaler, AutoscalePolicy
from repro.serving.fault import BreakerPolicy, CircuitBreaker, CircuitState
from repro.serving.generation import GenerationConfig
from repro.serving.semcache import SemanticCacheConfig

__all__ = ["OnlineRequest", "OnlineConfig", "BudgetBucket", "ResponseCache",
           "StreamSink", "WindowReport", "ServerStats", "OnlineRobatchServer",
           "MonotonicClock", "FakeClock", "LiveArrivalSource",
           "arrival_stream", "poisson_arrivals"]


class MonotonicClock:
    """Wall time: the production time source for ``realtime`` serving."""

    now = staticmethod(time.monotonic)
    sleep = staticmethod(time.sleep)


class FakeClock:
    """Deterministic time source: ``sleep`` advances ``now`` instantly, so
    real-time pacing logic runs under test without wall-clock waits.

    Single-threaded by design — with two sleepers sharing one fake clock
    (e.g. a pacer thread plus the serving loop) the unsynchronized advances
    would add instead of overlap.  Use it with ``run``/``run_paced``;
    ``run_live`` refuses it and needs a real clock."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)
        self.n_sleeps = 0

    def now(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.n_sleeps += 1
        self.t += max(0.0, float(dt))


class StreamSink:
    """Per-request live delta channel between the serving plane and a waiting
    consumer (an SSE handler thread, a test).

    The batch-prompt demultiplexer (:meth:`repro.serving.pool.ServedPoolMember.
    invoke_batch`) pushes text deltas as decode blocks land; ``_complete``
    seals the stream with the authoritative final answer — any tail the live
    deltas did not cover is pushed first, then a terminal ``("done", None)``
    event (or ``("error", reason)`` for a shed request).  Members that never
    generate text (calibrated simulators, cache hits) stream nothing live, so
    the seal splits their content into two deltas — every streamed completion
    carries ≥ 2 content chunks, whatever served it.

    Events on ``q``: ``("delta", text)``, ``("error", reason)``,
    ``("done", None)``.  push/finish are called from serving-side threads,
    the queue consumer from the subscriber's.
    """

    def __init__(self):
        self.q: "queue.Queue[tuple[str, Optional[str]]]" = queue.Queue()
        self.emitted = ""             # concatenation of all pushed deltas
        self.n_deltas = 0
        self.closed = False

    def push(self, delta: str) -> None:
        if not delta or self.closed:
            return
        self.emitted += delta
        self.n_deltas += 1
        self.q.put(("delta", delta))

    def finish(self, content: str, *, split: bool = False,
               error: Optional[str] = None) -> None:
        if self.closed:
            return
        if error is not None:
            self.q.put(("error", error))
        else:
            tail = content[len(self.emitted):] \
                if content.startswith(self.emitted) else content
            if split and not self.emitted and len(tail) > 1:
                mid = (len(tail) + 1) // 2
                self.push(tail[:mid])
                self.push(tail[mid:])
            elif tail:
                self.push(tail)
        self.closed = True
        self.q.put(("done", None))


@dataclass
class OnlineRequest:
    """One streamed query: a workload index plus serving lifecycle state."""

    rid: int
    query_idx: int
    arrived_at: float
    completed_at: Optional[float] = None
    utility: Optional[float] = None
    model: Optional[int] = None
    batch: Optional[int] = None
    cost: float = 0.0                 # this request's share of billed cost
    cache_hit: bool = False
    sem_hit: bool = False             # served by the semantic (embedding) cache
    sem_sim: float = 0.0              # cosine similarity of the semantic hit
    sem_loss: float = 0.0             # calibrated utility-loss estimate u·ε(sim)
    n_reroutes: int = 0
    dropped: bool = False
    content: Optional[str] = None     # final answer text (set at completion)
    stream: Optional[StreamSink] = None   # live delta channel (submit_request)
    done_event: Optional[threading.Event] = None  # set when _complete runs
    gen: Optional["GenerationConfig"] = None  # per-request sampling override
    #   (None → the server's OnlineConfig.generation, then the member default)

    @property
    def sampled(self) -> bool:
        return self.gen is not None and not self.gen.greedy

    @property
    def latency(self) -> float:
        return self.completed_at - self.arrived_at


class BudgetBucket:
    """Token bucket in dollars: refills at ``rate_per_s``, holds at most
    ``burst_s`` seconds of budget.  ``spend`` may overdraw slightly (realized
    cost of an already-dispatched batch exceeding its amortized estimate);
    the debt suppresses admission until refills cover it."""

    def __init__(self, rate_per_s: float, burst_s: float = 2.0):
        self.rate = float(rate_per_s)
        self.capacity = self.rate * burst_s
        self._balance = self.capacity
        self._last: Optional[float] = None
        self.total_spent = 0.0

    def balance(self, now: float) -> float:
        if self._last is not None and now > self._last:
            self._balance = min(self.capacity, self._balance + self.rate * (now - self._last))
        self._last = now
        return self._balance

    def spend(self, amount: float) -> None:
        self._balance -= amount
        self.total_spent += amount


class ResponseCache:
    """Bounded LRU cache keyed by query identity.

    The byte-level batch prompt is deterministic in the query text, so a
    repeated query is served from cache at zero cost.  Values are
    ``(utility, model_idx, content)`` — what the judge scored when the query
    was first served, where, and the answer text it got (``None`` when the
    member produced no text — the server re-synthesizes deterministically)."""

    def __init__(self, max_entries: int = 4096):
        self.max_entries = max_entries
        self._entries: OrderedDict[int, tuple[float, int, Optional[str]]] = \
            OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: int) -> Optional[tuple[float, int, Optional[str]]]:
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return self._entries[key]
        self.misses += 1
        return None

    def put(self, key: int, value: tuple[float, int, Optional[str]]) -> None:
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)


@dataclass
class OnlineConfig:
    budget_per_s: float               # rolling budget rate ($/s)
    window_s: float = 0.25            # admission deadline window
    burst_s: float = 2.0              # bucket capacity in seconds of budget
    max_window: int = 512             # queries per scheduling round (backpressure)
    max_reroutes: int = 3             # reschedules before a query is shed
    cache_entries: int = 4096
    breaker: BreakerPolicy = field(default_factory=BreakerPolicy)
    max_workers: Optional[int] = None # dispatch threads (default: total replicas)
    realtime: bool = False            # pace windows against the wall clock
    autoscale: Optional[AutoscalePolicy] = None
    # ^ backlog-driven replica autoscaling (repro.serving.autoscale); None
    #   keeps the pool fixed — only members exposing scale_to participate
    semantic_cache: Optional[SemanticCacheConfig] = None
    # ^ embedding-space near-duplicate cache (repro.serving.semcache) probed
    #   after the exact-match cache and ahead of admission; None (the
    #   default) keeps the serving path bit-identical to the cache-less plane
    generation: Optional[GenerationConfig] = None
    # ^ server-wide default GenerationConfig forwarded to real pool members;
    #   per-request OnlineRequest.gen overrides it, None keeps every member
    #   on its own default (the legacy greedy path, bit-identical)


@dataclass
class WindowReport:
    """One scheduling round's accounting (the server keeps the full list)."""

    t: float
    n_pending: int = 0                # queue depth entering the round
    n_cache_hits: int = 0
    n_sem_hits: int = 0               # semantic-cache (near-duplicate) hits
    sem_utility_loss: float = 0.0     # Σ u·ε(sim) the hits were discounted by
    n_coalesced: int = 0              # duplicate queries merged in-window
    n_admitted: int = 0               # scheduled this round
    n_deferred: int = 0               # unaffordable/over-cap, retried next round
    n_capacity_held: int = 0          # deferred specifically by replica caps
    n_cap_packed: int = 0             # re-packed into wider batches to fit caps
    n_shed: int = 0                   # can never afford → dropped
    n_failed: int = 0                 # queries whose dispatch group faulted
    n_groups: int = 0                 # physical batches dispatched
    avail: float = 0.0                # bucket balance when the round started
    est_cost: float = 0.0             # amortized cost the scheduler committed
    spent: float = 0.0                # realized billed cost (Eq. 4 semantics)
    open_models: tuple = ()           # breaker-open member names
    group_models: tuple = ()          # model index of each dispatched group
    late_s: float = 0.0               # realtime: how late past the boundary
    replica_counts: tuple = ()        # active replicas per member after the round
    held_by_member: tuple = ()        # ((member_idx, n_queries), ...) capacity
    #   holds keyed by the member whose cap pushed the work out — query
    #   granularity (coalesced duplicates count once), unlike the
    #   request-granular n_capacity_held; the bottleneck-member signal a
    #   later per-member autoscaler grows on
    packed_by_member: tuple = ()      # ((member_idx, n_queries), ...) Δ-heap
    #   packing moves keyed by the over-cap member that forced them
    kv_pages: tuple = ()              # ((member_idx, used, shared, forks), ...)
    #   paged-KV occupancy per member with a real engine behind it — the
    #   memory-headroom signal the autoscaler and the bench gate read; empty
    #   entries (simulated members) are omitted
    scale_events: tuple = ()          # ((member_name, from_n, to_n), ...) the
    #   autoscale actions fired on THIS round's control tick — the per-member
    #   attribution the metrics registry turns into
    #   robatch_scale_events_total{member, direction}

    @property
    def kv_occupancy(self) -> int:
        """Total live KV pages across members this round (0 when no member
        runs a paged engine)."""
        return sum(used for _, used, _s, _f in self.kv_pages)

    def summary(self) -> str:
        """One operator-readable line per scheduling round — includes the
        paged-KV occupancy that previously lived only in the dataclass."""
        line = (f"t={self.t:.2f}s pending={self.n_pending} "
                f"admitted={self.n_admitted} groups={self.n_groups} "
                f"deferred={self.n_deferred} held={self.n_capacity_held} "
                f"packed={self.n_cap_packed} shed={self.n_shed} "
                f"spent=${self.spent:.6f}")
        if self.late_s:
            line += f" late={self.late_s * 1e3:.0f}ms"
        if self.replica_counts:
            line += f" replicas={list(self.replica_counts)}"
        if self.kv_pages:
            per = " ".join(f"m{k}:{used}p/{shared}sh/{forks}cow"
                           for k, used, shared, forks in self.kv_pages)
            line += f" kv_pages[{self.kv_occupancy} live: {per}]"
        return line


@dataclass
class ServerStats:
    n_submitted: int
    n_completed: int
    n_cache_hits: int
    n_coalesced: int
    n_dropped: int
    n_reroutes: int
    duration_s: float
    qps: float
    latency_p50: float
    latency_p99: float
    mean_utility: float
    total_cost: float
    budget_allowance: float           # rate·duration + burst capacity
    n_sem_hits: int = 0               # semantic-cache completions
    sem_utility_loss: float = 0.0     # Σ u·ε(sim) across those completions
    windows: list = field(default_factory=list)

    def summary(self) -> str:
        cached = f"{self.n_cache_hits} cached"
        if self.n_sem_hits:
            cached += f" +{self.n_sem_hits} sem"
        return (f"served {self.n_completed - self.n_dropped}/{self.n_submitted} "
                f"({cached}, {self.n_dropped} dropped, "
                f"{self.n_reroutes} reroutes) in {self.duration_s:.1f}s · "
                f"{self.qps:.1f} qps · p50 {self.latency_p50:.2f}s "
                f"p99 {self.latency_p99:.2f}s · util {self.mean_utility:.3f} · "
                f"${self.total_cost:.5f} of ${self.budget_allowance:.5f} allowed")


class OnlineRobatchServer:
    """Streams queries through a pluggable :class:`repro.api.SchedulingPolicy`.

    ``policy`` is any fitted registered policy — the server only consumes the
    policy protocol (``window_space`` for admission + ``plan_window`` for the
    per-window decision), so RoBatch, the adapted baselines and user-written
    strategies all serve interchangeably.  A fitted
    :class:`repro.core.robatch.Robatch` is still accepted and wrapped in the
    ``robatch`` policy (legacy call sites keep working).

    ``pool`` is the member list the dispatcher bills and invokes — usually
    ``policy.exec_pool``, but it may wrap members (e.g.
    :class:`repro.serving.fault.FlakyMember`) as long as order matches, since
    plans refer to members by index.  A member exposing ``n_replicas`` (a
    :class:`repro.serving.pool.ReplicaSet`) caps its per-window batch-groups
    at that count; plain members keep the legacy unbounded-window semantics.

    ``clock`` is the real-time time source (``now()``/``sleep(dt)``); it is
    only consulted when ``config.realtime`` — virtual runs never sleep.
    """

    def __init__(self, policy, pool: Sequence, wl, config: OnlineConfig,
                 clock=None):
        if not hasattr(policy, "window_space"):    # a fitted Robatch (legacy)
            from repro.api.policies import RobatchPolicy

            assert policy.router is not None, "Robatch must be fitted before serving"
            policy = RobatchPolicy().fit(policy.pool, wl, artifacts=policy)
        assert policy.rb is not None, "policy must be fitted before serving"
        assert len(pool) == len(policy.exec_pool), \
            "pool must mirror the policy's exec_pool by index"
        self.policy = policy
        self.rb = policy.rb                        # shared modeling artifacts
        self.pool = list(pool)
        self.wl = wl
        self.cfg = config
        self.clock = clock if clock is not None else MonotonicClock()
        self.now = 0.0
        self.bucket = BudgetBucket(config.budget_per_s, config.burst_s)
        self.cache = ResponseCache(config.cache_entries)
        self.semcache = None
        if config.semantic_cache is not None:
            from repro.serving.semcache import SemanticCache

            self.semcache = SemanticCache.from_artifacts(
                self.rb, config.semantic_cache)
        self.breakers = [CircuitBreaker(config.breaker, clock=lambda: self.now)
                         for _ in self.pool]
        # replica trackers left on their default wall clock are rebound to the
        # serving timeline (virtual ticks or wall-relative seconds), so replica
        # cooldown/probe re-admission recovers on the SAME clock as the
        # member-level breakers; an explicitly injected tracker clock wins
        for m in self.pool:
            tracker = getattr(m, "tracker", None)
            if tracker is not None and tracker.clock is time.monotonic:
                tracker.clock = lambda: self.now
        self._pw_caps = "caps" in inspect.signature(policy.plan_window).parameters
        self.autoscaler = (Autoscaler(self.pool, config.autoscale)
                           if config.autoscale is not None else None)
        self.pending: deque[OnlineRequest] = deque()
        self.completed: list[OnlineRequest] = []
        self.windows: list[WindowReport] = []
        self._locks = [threading.Lock() for _ in self.pool]
        self._submit_lock = threading.Lock()
        workers = config.max_workers or max(
            1, sum(getattr(m, "n_replicas", 1) for m in self.pool),
            # autoscale can grow the pool past its initial size — size the
            # dispatch pool for the ceiling so scaled-up groups run concurrent
            len(self.pool) * (config.autoscale.max_replicas
                              if config.autoscale is not None else 0))
        self._pool_exec = ThreadPoolExecutor(max_workers=workers)
        self._next_rid = 0
        self.n_coalesced = 0
        self.pacer_leaked = False     # run_live: arrival thread outlived join
        # observability hooks (repro.http.metrics binds these): called from
        # the serving thread — keep them fast and non-blocking
        self.on_window = None         # fn(WindowReport) after every round
        self.on_complete = None       # fn(OnlineRequest) at every completion
        self._bridge_t0: Optional[float] = None   # run_bridge timeline origin

    # ------------------------------------------------------------- admission
    def submit(self, query_idx: int, at: Optional[float] = None) -> OnlineRequest:
        """Thread-safe: a LiveArrivalSource submits concurrently with step()."""
        with self._submit_lock:
            req = OnlineRequest(rid=self._next_rid, query_idx=int(query_idx),
                                arrived_at=self.now if at is None else at)
            self._next_rid += 1
            self.pending.append(req)
            return req

    def submit_request(self, query_idx: int, *, stream: bool = False,
                       at: Optional[float] = None,
                       gen: Optional[GenerationConfig] = None) -> OnlineRequest:
        """Live-ingress submit: the request carries a ``done_event`` the
        caller can block on, and (with ``stream=True``) a :class:`StreamSink`
        receiving per-decode-block text deltas.  Arrival time defaults to the
        bridge timeline when :meth:`run_bridge` is running, else the server's
        current tick.  ``gen`` overrides the server's default
        :class:`GenerationConfig` for this request (sampled requests bypass
        the response caches — a cached sample is not a fresh draw)."""
        if at is None and self._bridge_t0 is not None:
            at = self.clock.now() - self._bridge_t0
        with self._submit_lock:
            # sink and event attach BEFORE the request becomes visible to the
            # serving loop — a step() racing ahead must find them in place
            req = OnlineRequest(rid=self._next_rid, query_idx=int(query_idx),
                                arrived_at=self.now if at is None else at,
                                done_event=threading.Event(),
                                stream=StreamSink() if stream else None,
                                gen=gen)
            self._next_rid += 1
            self.pending.append(req)
            return req

    def allowed_models(self) -> list[int]:
        return [k for k, br in enumerate(self.breakers) if br.allow_request()]

    def caps(self) -> dict[int, int]:
        """Per-member batch-group concurrency caps for the NEXT window.

        A replicated member's cap is its healthy-replica count right now
        (``ReplicaSet.n_available``), so a replica outage shrinks what the
        scheduler may commit instead of silently queueing on the survivors;
        plain members are absent (uncapped — legacy single-engine semantics).
        """
        caps = {}
        for k, m in enumerate(self.pool):
            if hasattr(m, "n_available"):
                caps[k] = int(m.n_available())
            elif hasattr(m, "n_replicas"):
                caps[k] = int(m.n_replicas)
        return caps

    # -------------------------------------------------------------- serving
    def _default_content(self, req: OnlineRequest) -> str:
        """Deterministic answer text for members that produce none (the
        calibrated simulators): a pure function of (member, query, utility),
        so HTTP responses stay bit-identical across runs and serving paths."""
        if req.model is None:
            return ""
        return (f"[{self.pool[req.model].name}] q{req.query_idx} "
                f"utility={req.utility:.3f}")

    def _complete(self, req: OnlineRequest, *, at: float, utility: float,
                  model: Optional[int], batch: Optional[int], cost: float,
                  cache_hit: bool = False, dropped: bool = False,
                  content: Optional[str] = None) -> None:
        req.completed_at = at
        req.utility = utility
        req.model = model
        req.batch = batch
        req.cost = cost
        req.cache_hit = cache_hit
        req.dropped = dropped
        req.content = "" if dropped else (
            content if content is not None else self._default_content(req))
        if req.stream is not None:
            if dropped:
                req.stream.finish("", error="request shed (budget/reroute limit)")
            else:
                req.stream.finish(req.content, split=True)
        self.completed.append(req)
        if req.done_event is not None:
            req.done_event.set()
        if self.on_complete is not None:
            self.on_complete(req)

    def _sampled(self, req: OnlineRequest) -> bool:
        """Does this request decode stochastically?  Its own gen wins; with
        none attached the server-wide default decides."""
        if req.gen is not None:
            return not req.gen.greedy
        return (self.cfg.generation is not None
                and not self.cfg.generation.greedy)

    def _group_gen(self, members: np.ndarray, by_idx) -> Optional[GenerationConfig]:
        """The GenerationConfig one dispatched batch group decodes under: the
        first per-request override in FCFS order, else the server default.
        Coalesced duplicates and co-batched queries share the group's single
        generation (one batch prompt is one decode stream)."""
        for q in members:
            for req in by_idx[int(q)]:
                if req.gen is not None:
                    return req.gen
        return self.cfg.generation

    def _invoke(self, k: int, members: np.ndarray, streams=None, gen=None):
        kw = {"streams": streams} if streams else {}
        if gen is not None and getattr(self.pool[k], "supports_generation",
                                       False):
            kw["gen"] = gen
        if getattr(self.pool[k], "thread_safe", False):
            # ReplicaSets serialize per replica internally — concurrent groups
            # on one member are exactly what the replicas are for
            return self.pool[k].invoke_batch(self.wl, members, **kw)
        with self._locks[k]:          # engines are not thread-safe; members are
            return self.pool[k].invoke_batch(self.wl, members, **kw)

    def _finish_window(self, rep: WindowReport) -> WindowReport:
        """Seal one round: record per-member replica counts, give the
        autoscaler its control tick (its scale actions land in the caps the
        NEXT round plans against), and append the report."""
        rep.replica_counts = tuple(int(getattr(m, "n_replicas", 1))
                                   for m in self.pool)
        kv = []
        for k, m in enumerate(self.pool):
            fn = getattr(m, "kv_occupancy", None)
            occ = fn() if fn is not None else None
            if occ and occ.get("paged"):
                kv.append((k, int(occ.get("pages_used", 0)),
                           int(occ.get("pages_shared", 0)),
                           int(occ.get("cow_forks", 0))))
        rep.kv_pages = tuple(kv)
        if self.autoscaler is not None:
            fired = self.autoscaler.observe(rep, len(self.pending), rep.t)
            rep.scale_events = tuple((e.member, e.from_n, e.to_n) for e in fired)
            rep.replica_counts = tuple(int(getattr(m, "n_replicas", 1))
                                       for m in self.pool)
        self.windows.append(rep)
        if self.on_window is not None:
            self.on_window(rep)
        return rep

    def step(self, now: Optional[float] = None) -> WindowReport:
        """Run one scheduling round over the queries pending at ``now``."""
        self.now = self.now + self.cfg.window_s if now is None else now
        now = self.now
        rep = WindowReport(t=now, n_pending=len(self.pending))
        take = [self.pending.popleft()
                for _ in range(min(len(self.pending), self.cfg.max_window))]

        # 1. response cache: exact hits complete immediately and bill nothing;
        #    exact misses probe the semantic cache (embedding-space near
        #    duplicates), which completes at cost 0 with the discounted
        #    utility u·(1−ε(sim)) — anything left enters scheduling
        misses: list[OnlineRequest] = []
        sem_utils: list[float] = []
        for req in take:
            if self._sampled(req):
                # a cached answer is one past draw — sampled requests want a
                # fresh one, so they skip both caches (lookup AND insert)
                misses.append(req)
                continue
            hit = self.cache.get(req.query_idx)
            if hit is not None:
                u, k, text = hit
                self._complete(req, at=now, utility=u, model=k, batch=None,
                               cost=0.0, cache_hit=True, content=text)
                rep.n_cache_hits += 1
                continue
            sem = (self.semcache.lookup(req.query_idx, now=now)
                   if self.semcache is not None else None)
            if sem is not None:
                req.sem_hit = True
                req.sem_sim = sem.similarity
                req.sem_loss = sem.utility_loss
                self._complete(req, at=now, utility=sem.utility,
                               model=sem.model, batch=None, cost=0.0,
                               cache_hit=True, content=sem.content)
                rep.n_sem_hits += 1
                rep.sem_utility_loss += sem.utility_loss
                sem_utils.append(sem.utility)
            else:
                misses.append(req)

        # 2. coalesce duplicates: one scheduled instance answers them all
        by_idx: "OrderedDict[int, list[OnlineRequest]]" = OrderedDict()
        for req in misses:
            by_idx.setdefault(req.query_idx, []).append(req)
        rep.n_coalesced = len(misses) - len(by_idx)
        self.n_coalesced += rep.n_coalesced

        allowed = self.allowed_models()
        rep.open_models = tuple(self.pool[k].name for k, br in enumerate(self.breakers)
                                if br.state == CircuitState.OPEN)
        if not by_idx or not allowed:
            # requeue front-of-queue in FCFS order (iterate groups backwards)
            for reqs in reversed(list(by_idx.values())):
                self.pending.extendleft(reversed(reqs))
            rep.n_deferred = len(misses)
            return self._finish_window(rep)

        # 3. policy window space, restricted to surviving models
        idx = np.fromiter(by_idx.keys(), dtype=int)
        full = self.policy.window_space(idx)
        space = restrict_space(full, set(allowed))

        # 4. budget admission: affordable FCFS prefix at initial-state cost
        avail = rep.avail = self.bucket.balance(now)
        base = space.cost[:, space.initial_state]
        affordable = np.cumsum(base) <= max(avail, 0.0) + 1e-12
        n_adm = int(affordable.sum())
        if n_adm == 0 and float(full.cost[0].min()) > self.bucket.capacity + 1e-12:
            # head query can *never* be afforded at this budget rate — judged
            # against the FULL pool, so queries that are only expensive while
            # a breaker is open are deferred (and served after recovery), not
            # shed
            for req in by_idx[int(idx[0])]:
                self._complete(req, at=now, utility=0.0, model=None, batch=None,
                               cost=0.0, dropped=True)
                rep.n_shed += 1
            idx = idx[1:]
        deferred = idx[n_adm:]
        for q in deferred[::-1]:
            self.pending.extendleft(reversed(by_idx[int(q)]))
        rep.n_deferred = int(sum(len(by_idx[int(q)]) for q in deferred))
        idx = idx[:n_adm]
        rep.n_admitted = int(sum(len(by_idx[int(q)]) for q in idx))
        if n_adm == 0:
            return self._finish_window(rep)

        # 5. the policy's windowed decision against the bucket's current
        #    balance (the server restricted the space up front for admission
        #    control, so no further model mask is needed here); replica
        #    capacity caps ride along when the policy understands them
        caps = self.caps()
        cap_kw = {"caps": caps or None} if self._pw_caps else {}
        wplan = self.policy.plan_window(take_rows(space, np.arange(n_adm)), idx,
                                        avail, **cap_kw)
        if wplan.schedule is not None and sem_utils:
            # core-scheduler accounting: semantic hits enter the window's
            # ScheduleResult as (cost=0, utility=u·(1−ε)) assignments, so
            # frontier-level utility totals include what the cache served
            attach_free_assignments(wplan.schedule, sem_utils)
        held_by: dict[int, int] = {}
        packed_by: dict[int, int] = {}
        if wplan.schedule is not None:
            # capacity-packing pressure (greedy_schedule_capped) — an
            # autoscaler signal even when nothing is held outright
            rep.n_cap_packed = int(getattr(wplan.schedule, "n_packed", 0))
            for k, c in getattr(wplan.schedule, "deferred_by_member", {}).items():
                held_by[int(k)] = held_by.get(int(k), 0) + int(c)
            for k, c in getattr(wplan.schedule, "packed_by_member", {}).items():
                packed_by[int(k)] = packed_by.get(int(k), 0) + int(c)

        # half-open breakers get exactly ONE probe group: any further groups
        # scheduled on a recovering member are deferred to the next window
        # (without burning reroute budget) instead of risking a reroute storm
        half_open = {k for k, br in enumerate(self.breakers)
                     if br.state == CircuitState.HALF_OPEN}
        probed: set[int] = set()
        used: dict[int, int] = {}     # groups committed per member this window
        dispatch, held = [], []
        # queries the scheduler itself pushed out under replica-capacity caps
        if wplan.deferred_idx is not None:
            for q in wplan.deferred_idx:
                reqs = by_idx[int(q)]
                held.extend(reqs)
                rep.n_capacity_held += len(reqs)
        for (state, members), gcost in zip(wplan.groups, wplan.group_costs):
            k = int(state.model)
            if k in half_open:
                if k in probed:
                    held.extend(req for q in members for req in by_idx[int(q)])
                    continue
                probed.add(k)
            cap = caps.get(k)
            if cap is not None and used.get(k, 0) >= cap:
                # backstop for policies that pack caps-unaware plans: a member
                # never runs more concurrent groups than it has replicas
                grp = [req for q in members for req in by_idx[int(q)]]
                held.extend(grp)
                rep.n_capacity_held += len(grp)
                held_by[k] = held_by.get(k, 0) + len(members)
                continue
            used[k] = used.get(k, 0) + 1
            dispatch.append((state, members))
            rep.est_cost += float(gcost)   # committed cost: dispatched only
        rep.n_deferred += len(held)
        rep.n_admitted -= len(held)   # held groups were never attempted
        rep.held_by_member = tuple(sorted(held_by.items()))
        rep.packed_by_member = tuple(sorted(packed_by.items()))

        # 6. concurrent dispatch across pool members; members that generate
        #    text get the live per-position subscriber sinks so SSE deltas
        #    flow at decode-block cadence (simulators stream at completion)
        futures = {}
        for state, members in dispatch:
            k = int(state.model)
            streams = None
            if getattr(self.pool[k], "supports_streams", False):
                streams = {pos: sinks for pos, q in enumerate(members)
                           if (sinks := [r.stream for r in by_idx[int(q)]
                                         if r.stream is not None])}
            gen = self._group_gen(members, by_idx)
            fut = self._pool_exec.submit(self._invoke, k, members,
                                         streams or None, gen)
            futures[fut] = (state, members, gen)
        rep.n_groups = len(dispatch)
        rep.group_models = tuple(int(s.model) for s, _ in dispatch)

        requeue: list[OnlineRequest] = []
        for fut, (state, members, gen) in futures.items():
            k = int(state.model)
            try:
                out = fut.result()
            except Exception:         # noqa: BLE001 — member fault
                probe_failed = k in half_open     # expected-risk probe traffic
                self.breakers[k].record_failure()
                for q in members:
                    for req in by_idx[int(q)]:
                        rep.n_failed += 1
                        if not probe_failed:
                            req.n_reroutes += 1
                        if req.n_reroutes > self.cfg.max_reroutes:
                            self._complete(req, at=now, utility=0.0, model=None,
                                           batch=None, cost=0.0, dropped=True)
                        else:
                            requeue.append(req)
                continue
            self.breakers[k].record_success()
            cost = (out.in_tokens * self.pool[k].c_in
                    + out.out_tokens * self.pool[k].c_out) / 1e6
            self.bucket.spend(cost)
            rep.spent += cost
            done_at = now + float(out.latency_s)
            share = cost / max(1, len(members))
            answers = getattr(out, "answers", None)
            cacheable = gen is None or gen.greedy
            for pos, (q, u) in enumerate(zip(members, out.utilities)):
                text = answers[pos] if answers is not None else None
                if cacheable:      # one sample must not become every answer
                    self.cache.put(int(q), (float(u), k, text))
                    if self.semcache is not None:
                        self.semcache.insert(int(q), float(u), k, text,
                                             now=done_at)
                for req in by_idx[int(q)]:
                    self._complete(req, at=done_at, utility=float(u), model=k,
                                   batch=int(state.batch), cost=share,
                                   content=text)
        retry = sorted(requeue + held, key=lambda r: r.rid)
        if retry:                     # FCFS: oldest retried request re-enters first
            self.pending.extendleft(reversed(retry))
        return self._finish_window(rep)

    def run(self, arrivals: Sequence[tuple[float, int]], *,
            max_ticks: int = 100_000) -> ServerStats:
        """Drive a pre-generated arrival stream to completion.

        ``arrivals`` is a time-sorted list of ``(t, query_idx)``.  By default
        the clock is virtual: each tick advances ``window_s``, admits
        everything that has arrived, and runs one scheduling round; it keeps
        ticking until the stream is exhausted and the queue drains.  With
        ``config.realtime`` the same loop is paced against the injected wall
        clock instead (see :meth:`run_paced`) — the identical tick/admission
        structure is what makes one seeded stream replay identically in both
        modes.
        """
        arrivals = list(arrivals)
        if self.cfg.realtime:
            return self.run_paced(arrivals, max_ticks=max_ticks)
        pos = 0
        for _ in range(max_ticks):
            if pos >= len(arrivals) and not self.pending:
                break
            t = self.now + self.cfg.window_s
            while pos < len(arrivals) and arrivals[pos][0] <= t:
                at, q = arrivals[pos]
                self.submit(q, at=at)
                pos += 1
            self.step(t)
        return self.stats()

    def run_paced(self, arrivals: Sequence[tuple[float, int]], *,
                  max_ticks: int = 100_000) -> ServerStats:
        """Real-time drive of a pre-generated stream: sleep to each window
        boundary on the wall clock, admit what has (wall-)arrived, run one
        round.  A slow round never skips a window — the next rounds fire
        back-to-back and the overshoot lands in ``WindowReport.late_s``."""
        clock = self.clock
        t0 = clock.now()
        pos = 0
        for tick in range(1, max_ticks + 1):
            if pos >= len(arrivals) and not self.pending:
                break
            target = tick * self.cfg.window_s
            lag = target - (clock.now() - t0)
            if lag > 0:
                clock.sleep(lag)
            now = clock.now() - t0
            while pos < len(arrivals) and arrivals[pos][0] <= now:
                at, q = arrivals[pos]
                self.submit(q, at=at)
                pos += 1
            rep = self.step(now)
            rep.late_s = max(0.0, now - target)
        return self.stats()

    def run_bridge(self, stop_event: threading.Event, *,
                   max_ticks: int = 10_000_000, drain_ticks: int = 1000) -> None:
        """Live-ingress serving loop: no pre-generated arrival list — requests
        arrive concurrently via :meth:`submit_request` (e.g. from HTTP handler
        threads) while this loop fires one scheduling round per wall-clock
        window boundary, exactly like :meth:`run_paced`.

        On ``stop_event`` the loop stops admitting ticks and *drains*: pending
        requests get up to ``drain_ticks`` further rounds to complete (budget
        refills keep accruing on the bridge timeline), then any stragglers are
        completed as dropped — a waiter on ``done_event`` is never stranded.
        """
        clock = self.clock
        t0 = clock.now()
        self._bridge_t0 = t0
        try:
            for tick in range(1, max_ticks + 1):
                if stop_event.is_set():
                    break
                target = tick * self.cfg.window_s
                lag = target - (clock.now() - t0)
                if lag > 0:
                    # interruptible sleep: a shutdown mid-window wakes the
                    # loop instead of waiting the window out
                    stop_event.wait(lag)
                    if stop_event.is_set():
                        break
                now = clock.now() - t0
                rep = self.step(now)
                rep.late_s = max(0.0, now - target)
            for _ in range(drain_ticks):
                if not self.pending:
                    break
                self.step(clock.now() - t0)
            while self.pending:       # unaffordable stragglers: fail, don't hang
                req = self.pending.popleft()
                self._complete(req, at=clock.now() - t0, utility=0.0,
                               model=None, batch=None, cost=0.0, dropped=True)
        finally:
            self._bridge_t0 = None

    def run_live(self, arrivals: Sequence[tuple[float, int]], *,
                 duration_s: Optional[float] = None,
                 max_ticks: int = 100_000,
                 join_timeout_s: float = 5.0) -> ServerStats:
        """Real-time serving fronted by a live arrival thread.

        A :class:`LiveArrivalSource` replays the (seeded, pre-generated)
        stream against the wall clock, submitting each arrival as its
        timestamp comes due, while this loop fires one scheduling round per
        window boundary; after ``duration_s`` (default: the stream's horizon)
        it keeps ticking until the queue drains.

        The pacer thread is stopped and joined for ``join_timeout_s`` on the
        way out; a pacer that fails to exit by then (a stuck ``submit``, a
        wedged clock sleep) is a *leak* — it can keep submitting into a
        server the caller believes is finished.  The leak is recorded on
        :attr:`pacer_leaked` and warned to stderr rather than silently
        swallowed by the daemon flag."""
        assert self.cfg.realtime, "run_live needs OnlineConfig(realtime=True)"
        if isinstance(self.clock, FakeClock):
            raise ValueError("run_live shares the clock between the pacer "
                             "thread and the serving loop — FakeClock is "
                             "single-threaded; use run() for fake-clock "
                             "determinism tests")
        arrivals = list(arrivals)
        if duration_s is None:
            duration_s = arrivals[-1][0] if arrivals else 0.0
        clock = self.clock
        t0 = clock.now()
        source = LiveArrivalSource(self, arrivals, t0=t0)
        source.start()
        try:
            for tick in range(1, max_ticks + 1):
                target = tick * self.cfg.window_s
                lag = target - (clock.now() - t0)
                if lag > 0:
                    clock.sleep(lag)
                now = clock.now() - t0
                rep = self.step(now)
                rep.late_s = max(0.0, now - target)
                if now >= duration_s and not source.is_alive() and not self.pending:
                    break
        finally:
            source.stop()
            source.join(timeout=join_timeout_s)
            self.pacer_leaked = bool(source.is_alive())
            if self.pacer_leaked:
                print(f"run_live: WARNING pacer thread still alive "
                      f"{join_timeout_s}s after stop — arrival source leaked",
                      file=sys.stderr)
        return self.stats()

    # ------------------------------------------------------------- reporting
    def stats(self) -> ServerStats:
        done = self.completed
        served = [r for r in done if not r.dropped]
        lats = np.array([r.latency for r in served]) if served else np.array([0.0])
        t0 = min((r.arrived_at for r in done), default=0.0)
        dur = max(self.now - t0, 1e-9)
        return ServerStats(
            n_submitted=self._next_rid,
            n_completed=len(done),
            n_cache_hits=self.cache.hits,
            n_coalesced=self.n_coalesced,
            n_dropped=sum(r.dropped for r in done),
            n_reroutes=sum(r.n_reroutes for r in done),
            duration_s=dur,
            qps=len(served) / dur,
            latency_p50=float(np.percentile(lats, 50)),
            latency_p99=float(np.percentile(lats, 99)),
            mean_utility=float(np.mean([r.utility for r in served])) if served else 0.0,
            total_cost=self.bucket.total_spent,
            budget_allowance=self.bucket.rate * dur + self.bucket.capacity,
            n_sem_hits=self.semcache.hits if self.semcache is not None else 0,
            sem_utility_loss=(self.semcache.utility_loss
                              if self.semcache is not None else 0.0),
            windows=self.windows,
        )

    def close(self) -> None:
        self._pool_exec.shutdown(wait=True)


class LiveArrivalSource(threading.Thread):
    """Wall-clock pacer for a pre-generated arrival stream.

    Generation and pacing are deliberately separate concerns: the *stream* is
    a seeded ``[(t, query_idx)]`` list (:func:`poisson_arrivals`), and this
    thread only *replays* it — sleeping on the server's clock until each
    timestamp comes due, then calling ``server.submit(q, at=t)``.  The same
    list fed to a virtual-clock ``run`` therefore produces the identical
    request sequence (determinism-tested in ``tests/test_online_serving.py``).
    """

    def __init__(self, server: "OnlineRobatchServer",
                 arrivals: Iterable[tuple[float, int]],
                 t0: Optional[float] = None, poll_s: float = 0.05):
        super().__init__(daemon=True)
        self.server = server
        self.arrivals = list(arrivals)
        self.clock = server.clock
        self.t0 = self.clock.now() if t0 is None else t0
        self.poll_s = poll_s
        # NB: not ``_stop`` — threading.Thread uses that name internally
        self._stop_requested = threading.Event()
        self.n_submitted = 0

    def stop(self) -> None:
        self._stop_requested.set()

    def run(self) -> None:
        for t, q in self.arrivals:
            while not self._stop_requested.is_set():
                lag = t - (self.clock.now() - self.t0)
                if lag <= 0:
                    break
                self.clock.sleep(min(lag, self.poll_s))
            if self._stop_requested.is_set():
                return
            self.server.submit(int(q), at=float(t))
            self.n_submitted += 1


def arrival_stream(rng: np.random.Generator, qps: float, universe: np.ndarray,
                   repeat_frac: float = 0.0) -> Iterator[tuple[float, int]]:
    """Unbounded seeded Poisson ``(t, query_idx)`` generator over ``universe``
    indices; with probability ``repeat_frac`` an arrival re-asks an earlier
    query (drives cache hits).

    Pure *generation*: no run length, no pacing.  Bound it with
    :func:`poisson_arrivals`, replay it virtually with ``run`` or in wall time
    with :class:`LiveArrivalSource` — the draws depend only on the rng state,
    so one seed yields one stream everywhere.
    """
    t = 0.0
    seen: list[int] = []
    while True:
        t += float(rng.exponential(1.0 / qps))
        if seen and float(rng.random()) < repeat_frac:
            q = int(seen[int(rng.integers(0, len(seen)))])
        else:
            q = int(universe[int(rng.integers(0, len(universe)))])
            seen.append(q)
        yield (t, q)


def poisson_arrivals(rng: np.random.Generator, qps: float, duration_s: float,
                     universe: np.ndarray, repeat_frac: float = 0.0) -> list[tuple[float, int]]:
    """The arrivals of :func:`arrival_stream` falling before ``duration_s``."""
    out: list[tuple[float, int]] = []
    for t, q in arrival_stream(rng, qps, universe, repeat_frac):
        if t >= duration_s:
            return out
        out.append((t, q))
