"""Tiny *real* model pool builder: train the tiny-s/m/l byte-level LMs on an
addition task (batched-prompt examples in-distribution) and wrap them as
``ServedPoolMember``s.

Shared by ``examples/serve_pool.py`` and ``benchmarks/online_throughput.py``:
both need an actually-served pool whose accuracy-vs-batch-size behaviour is
emergent rather than simulated.  Architectures come from
``repro.configs.tiny_pool`` (tiny-s/m/l); prices follow the ascending
cost/capability convention the scheduler assumes (§3).
"""
from __future__ import annotations

import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShardingConfig, get_arch
from repro.data.workload import BenchmarkSpec, Workload
from repro.models.transformer import Model
from repro.serving.batcher import BatchPromptFormatter
from repro.serving.engine import ServingEngine
from repro.serving.pool import ReplicaSet, ServedPoolMember, TextTask
from repro.serving.speculative import SpeculativeEngine
from repro.training.optimizer import adamw

__all__ = ["SYSTEM_PROMPT", "TINY_PRICES", "gen_query",
           "format_training_example", "train_engines", "build_task_workload",
           "replica_factory", "build_tiny_pool"]

SYSTEM_PROMPT = ("You are a calculator. For each question output the last digit "
                 "of the sum, answers separated by ';'.")

# (c_in, c_out) $/1M tokens, ascending with capacity; context fits max_len.
TINY_PRICES = {"tiny-s": (0.1, 0.4), "tiny-m": (0.3, 1.2), "tiny-l": (0.8, 3.2)}


def gen_query(rng) -> tuple[str, str, float]:
    """Two-term addition with difficulty tiers by operand size.
    Answer = last digit of the sum (single token)."""
    tier = int(rng.integers(0, 3))               # 0 easy … 2 hard
    hi = (10, 50, 100)[tier]
    a_, b_ = int(rng.integers(0, hi)), int(rng.integers(0, hi))
    q = f"{a_}+{b_}"
    ans = str((a_ + b_) % 10)
    return q, ans, tier / 2.0


def format_training_example(rng, fmt: BatchPromptFormatter, max_b: int = 6):
    b = int(rng.integers(1, max_b + 1))
    qas = [gen_query(rng) for _ in range(b)]
    prompt = fmt.format([q for q, _, _ in qas])
    answer = ";".join(a for _, a, _ in qas)
    tok = fmt.tokenizer
    return prompt + tok.encode(answer, add_bos=False, add_eos=True)


def _make_batches(rng, fmt, batch_size, seq_len, n_steps):
    tok = fmt.tokenizer
    for _ in range(n_steps):
        seqs = [format_training_example(rng, fmt) for _ in range(batch_size)]
        tokens, lengths = tok.pad_batch(seqs, seq_len + 1)
        labels = tokens[:, 1:].copy()
        labels[labels == tok.pad] = -100
        yield {"tokens": jnp.asarray(tokens[:, :-1]),
               "labels": jnp.asarray(np.where(labels == -100, -100, labels))}


def train_engines(rng, fmt: BatchPromptFormatter, steps: int,
                  names=("tiny-s", "tiny-m", "tiny-l"), *, batch_size: int = 8,
                  seq_len: int = 192, max_slots: int = 4, max_len: int = 512,
                  replicas: int = 1, decode_block: int = 8, paged: bool = True,
                  page_size: int = 16,
                  verbose: bool = True) -> dict[str, list[ServingEngine]]:
    """Train the tiny architectures on the addition task; returns
    ``{name: [engine, ...]}`` with ``replicas`` engines per architecture.

    Each architecture trains ONCE — replica engines share the trained
    weights (params are immutable on the jax side) but hold their own
    KV-cache slots, so they serve genuinely concurrent batches.

    ``seq_len`` must cover the longest batched example: at the previous
    default of 160 the b=5/6 examples were silently truncated by
    ``pad_batch`` — cutting off exactly the answers they were meant to teach.

    Caveat for benchmark consumers: at smoke-scale step counts (a few
    hundred) these tiny byte-level LMs learn the *format* reliably but sit
    near the task's chance floor on the arithmetic itself, so measured
    utilities are low; the serving/routing machinery above them is exercised
    either way, and the calibrated simulator pool is the right target for
    utility-sensitive numbers."""
    engines = {}
    for name in names:
        cfg = get_arch(name)
        model = Model(cfg, ShardingConfig(remat="none"))
        params = model.init(jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31))
        opt = adamw(3e-3, grad_clip=1.0)
        state = opt.init(params)

        @jax.jit
        def step(params, state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        t0 = time.time()
        losses = []
        if verbose:
            print(f"training {name} ({model.param_count() / 1e6:.2f}M params)...",
                  flush=True)
        for batch in _make_batches(rng, fmt, batch_size, seq_len, steps):
            params, state, loss = step(params, state, batch)
            losses.append(float(loss))   # blocks: real per-step time on CPU
        if verbose:
            print(f"trained {name}: loss {losses[0]:.2f} -> "
                  f"{np.mean(losses[-20:]):.2f} "
                  f"({time.time() - t0:.0f}s, {len(losses)} steps)", flush=True)
        engines[name] = [ServingEngine(model, params, max_slots=max_slots,
                                       max_len=max_len, decode_block=decode_block,
                                       paged=paged, page_size=page_size)
                        for _ in range(replicas)]
    return engines


def build_task_workload(rng, fmt: BatchPromptFormatter, n_train: int,
                        n_test: int) -> tuple[Workload, TextTask]:
    """Addition-task workload + parallel text view (see examples/serve_pool.py)."""
    n = n_train + n_test
    queries, answers, difficulty = [], [], []
    for _ in range(n):
        q, a, d = gen_query(rng)
        queries.append(q)
        answers.append(a)
        difficulty.append(d)
    difficulty = np.array(difficulty, np.float32)
    # embeddings: simple text features (the real system would use a sentence
    # embedding model; tiny pool queries are fully described by these)
    feats = np.stack([
        [len(q), sum(int(c) for c in q if c.isdigit()) / 20.0,
         max(len(t) for t in q.split("+")), min(len(t) for t in q.split("+"))]
        for q in queries
    ]).astype(np.float32)
    feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)
    emb = np.concatenate([feats, rng.normal(0, 0.1, (n, 4)).astype(np.float32)], axis=1)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True) + 1e-8

    in_tokens = np.array([fmt.query_tokens(q) for q in queries], np.int32)
    spec = BenchmarkSpec("tiny-add", "reasoning", 10, fmt.sys_tokens,
                         (float(in_tokens.mean()), 0.2), (2, 0.1), (2.0, 2.0), 3, 5.0)
    wl = Workload(
        name="tiny-add", spec=spec, embeddings=emb, difficulty=difficulty,
        topic=np.zeros(n, np.int32), in_tokens=in_tokens,
        out_tokens=np.full(n, 2, np.int32), sys_tokens=fmt.sys_tokens,
        split={"train": np.arange(n_train),
               "val": np.arange(0),
               "test": np.arange(n_train, n)},
    )
    return wl, TextTask(queries=queries, answers=answers)


def _speculative_of(engine: ServingEngine, draft: ServingEngine,
                    spec_k: int) -> SpeculativeEngine:
    """Wrap a trained member engine so the draft member's model proposes its
    tokens (fresh KV slots on both sides; weights are shared jax-side)."""
    return SpeculativeEngine(engine.model, engine.params,
                             draft.model, draft.params,
                             max_slots=engine.max_slots,
                             max_len=engine.max_len, spec_k=spec_k,
                             page_size=engine.page_size,
                             share_prefix=engine.share_prefix)


def replica_factory(prototype: ServedPoolMember):
    """Zero-arg builder of one more interchangeable replica of a served
    member: a fresh :class:`ServingEngine` (its own KV-cache slots) over the
    SAME trained params — what :meth:`repro.serving.pool.ReplicaSet.scale_to`
    calls to grow a tiny-pool member without retraining.  A speculative
    prototype replicates as a fresh :class:`SpeculativeEngine` over the same
    target/draft weight pair."""
    proto_engine = prototype.engine

    def build() -> ServedPoolMember:
        if isinstance(proto_engine, SpeculativeEngine):
            engine = SpeculativeEngine(
                proto_engine.model, proto_engine.params,
                proto_engine.draft_model, proto_engine.draft_params,
                max_slots=proto_engine.max_slots,
                max_len=proto_engine.max_len, spec_k=proto_engine.spec_k,
                page_size=proto_engine.page_size,
                share_prefix=proto_engine.share_prefix)
        else:
            engine = ServingEngine(proto_engine.model, proto_engine.params,
                                   max_slots=proto_engine.max_slots,
                                   max_len=proto_engine.max_len,
                                   decode_block=proto_engine.decode_block,
                                   paged=proto_engine.paged,
                                   page_size=proto_engine.page_size,
                                   share_prefix=proto_engine.share_prefix)
        return ServedPoolMember(prototype.name, engine, prototype.formatter,
                                prototype.task, c_in=prototype.c_in,
                                c_out=prototype.c_out,
                                context_len=prototype.context_len,
                                max_answer_tokens=prototype.max_answer_tokens,
                                generation=prototype.generation)

    return build


def build_tiny_pool(rng, *, steps: int = 300, n_train: int = 48, n_test: int = 48,
                    replicas: int = 1, scalable: bool = False,
                    draft_member: str = "", spec_k: int = 4,
                    verbose: bool = True):
    """Everything the routing stack needs: (workload, pool, formatter).

    The returned members satisfy the pool-member protocol, so ``Robatch`` and
    ``OnlineRobatchServer`` use them exactly like the simulator.  With
    ``replicas > 1`` each member is a :class:`~repro.serving.pool.ReplicaSet`
    of that many engines over one set of trained weights — N-way concurrent
    serving without N training runs.  ``scalable=True`` wraps members in
    ReplicaSets even at ``replicas=1`` and attaches a shared-weight
    :func:`replica_factory`, so the autoscaler can grow them on demand.

    ``draft_member`` names the cheap member whose model drafts for every
    *more expensive* member (routed speculative decoding): those members'
    engines become :class:`SpeculativeEngine`\\ s verifying the draft's
    ``spec_k``-token proposals in one fused span dispatch.  Outputs are
    bit-identical to the plain engines — the draft only moves latency."""
    fmt = BatchPromptFormatter(SYSTEM_PROMPT)
    engines = train_engines(rng, fmt, steps, replicas=replicas, verbose=verbose)
    if draft_member:
        if draft_member not in engines:
            raise ValueError(f"draft_member {draft_member!r} is not in the "
                             f"pool: {sorted(engines)}")
        d_cost = TINY_PRICES[draft_member][1]
        draft0 = engines[draft_member][0]
        for name, engs in engines.items():
            if TINY_PRICES[name][1] > d_cost:
                engines[name] = [_speculative_of(e, draft0, spec_k)
                                 for e in engs]
    wl, task = build_task_workload(rng, fmt, n_train, n_test)

    def member(name: str, engine: ServingEngine) -> ServedPoolMember:
        return ServedPoolMember(name, engine, fmt, task,
                                c_in=TINY_PRICES[name][0],
                                c_out=TINY_PRICES[name][1], context_len=512)

    if replicas > 1 or scalable:
        # async_build: a scale-up's engine construction runs off the serving
        # thread and joins at the next window boundary, so an autoscaler grow
        # never stretches the window that detected the backlog
        def rset(name: str) -> ReplicaSet:
            members = [member(name, e) for e in engines[name]]
            return ReplicaSet(members, name=name,
                              factory=replica_factory(members[0]),
                              async_build=True)

        pool = [rset(name) for name in ("tiny-s", "tiny-m", "tiny-l")]
    else:
        pool = [member(name, engines[name][0])
                for name in ("tiny-s", "tiny-m", "tiny-l")]
    return wl, pool, fmt
