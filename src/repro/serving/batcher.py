"""Batch prompting at the token level: pack b queries behind one shared
system prompt, parse b answers back out (§2.2 made real).

Wire format (byte tokenizer; full spec + billing semantics in
docs/batch_format.md)::

    <bos>SYSTEM_PROMPT\\nQ1:<q1>\\nQ2:<q2>...\\nQb:<qb>\\nA:

The model is trained (examples/train_lm.py / serve_pool.py) to emit
``<a1>;<a2>;...;<ab><eos>`` — a single shared answer cue (``\\nA:``), with the
separator splitting the answers back out positionally.  The formatter also
*bills* the token counts so the cost model's C_sys / C_q split matches exactly
what was served.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.data.tokenizer import ByteTokenizer


@dataclass
class BatchPromptFormatter:
    system_prompt: str
    sep: str = ";"
    tokenizer: ByteTokenizer = None

    def __post_init__(self):
        if self.tokenizer is None:
            self.tokenizer = ByteTokenizer()

    @property
    def sys_tokens(self) -> int:
        return len(self.tokenizer.encode(self.system_prompt, add_bos=True))

    def format(self, queries: list[str]) -> list[int]:
        parts = [self.system_prompt]
        for i, q in enumerate(queries):
            parts.append(f"\nQ{i + 1}:{q}")
        parts.append("\nA:")
        return self.tokenizer.encode("".join(parts), add_bos=True)

    def query_tokens(self, query: str, idx: int = 0) -> int:
        return len(self.tokenizer.encode(f"\nQ{idx + 1}:{query}", add_bos=False))

    def parse(self, output: str, b: int) -> list[str]:
        """Split the generated text into b answers; missing answers -> ''."""
        parts = [p.strip() for p in output.split(self.sep)]
        parts = parts[:b]
        return parts + [""] * (b - len(parts))
