"""Backlog-driven replica autoscaling: the control loop that *sizes* the pool.

The windowed scheduler (``greedy_schedule_window``) walks the cost-utility
frontier under a FIXED set of per-member capacity caps; this module closes the
remaining loop — from the backlog each :class:`~repro.serving.online.
WindowReport` exposes back to :meth:`repro.serving.pool.ReplicaSet.scale_to`:

    signal    capacity pressure  = n_capacity_held  (queries the caps pushed
                                   out of the window entirely)
                                 + n_cap_packed     (queries the capacity-aware
                                   Δ-heap squeezed into wider batches to fit)
              queue depth        = requests still pending after the round
              late_s             = realtime window-pacing lag
    decision  hysteresis (``hold_windows`` consecutive breaches) + per-action
              ``cooldown_s``, so a one-window spike or a scale action's own
              transient never flaps the pool
    actuation ``ReplicaSet.scale_to(n ± step)`` within
              [``min_replicas``, ``max_replicas``] — grow attaches
              factory-built (or un-parks drained) replicas, shrink retires
              them drain-first through the ``ReplicaTracker``

Scaling acts on *capacity* signals only: budget-deferred work is excluded
from the pressure term, because adding replicas cannot buy budget.  The
server re-reads ``ReplicaSet.n_available()`` every window, so a scale action
reaches the scheduler's ``group_caps`` on the very next round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Sequence

__all__ = ["AutoscalePolicy", "ScaleEvent", "Autoscaler"]


@dataclass
class AutoscalePolicy:
    """Knobs of the control loop (see docs/architecture.md for the diagram).

    ``up_pressure``/``down_pressure`` bound the per-window capacity-pressure
    signal (held + packed queries); ``up_queue_depth`` catches backlogs that
    build as plain queue growth; ``late_high_s`` (realtime only, 0 disables)
    treats window-pacing lag as saturation.  ``hold_windows`` and
    ``cooldown_s`` are the hysteresis: a breach must persist, and actions
    must space out, before the pool moves.
    """

    min_replicas: int = 1
    max_replicas: int = 4
    up_pressure: int = 4              # held+packed queries/window to grow on
    down_pressure: int = 0            # pressure ≤ this is a shrink candidate
    up_queue_depth: int = 32          # post-round queue depth to grow on
    down_queue_depth: int = 4         # queue must also be ≤ this to shrink
    late_high_s: float = 0.0          # realtime lateness to grow on (0 = off)
    hold_windows: int = 2             # consecutive breaches before acting
    cooldown_s: float = 1.0           # min serving-time between actions
    step: int = 1                     # replicas added/removed per action


class ScaleEvent(NamedTuple):
    """One actuation, kept in :attr:`Autoscaler.events` (bench/debug trail)."""

    t: float
    member: str
    from_n: int
    to_n: int
    reason: str


@dataclass
class _Streaks:
    up: int = 0
    down: int = 0


class Autoscaler:
    """Grows/shrinks every scalable pool member against window backlog.

    The decision is pool-wide (the scheduler's packing pass already balances
    load *across* members; what backlog means is that the pool as a whole is
    short on concurrent batch-groups), the actuation per member: each member
    exposing ``scale_to`` moves ``step`` replicas toward the breach direction,
    clamped to [``min_replicas``, ``max_replicas``].

    Drive it with :meth:`observe` once per scheduling round — the online
    server does so automatically when ``OnlineConfig.autoscale`` is set.
    """

    def __init__(self, pool: Sequence, policy: AutoscalePolicy | None = None):
        self.policy = policy or AutoscalePolicy()
        self._indexed = [(k, m) for k, m in enumerate(pool)
                         if hasattr(m, "scale_to")]
        self.members = [m for _k, m in self._indexed]
        self.events: list[ScaleEvent] = []
        self._streaks = _Streaks()
        self._last_action_t: float | None = None
        # per-member capacity-pressure breakdown (WindowReport.held_by_member
        # + packed_by_member, accumulated): logged only for now — the breach
        # decision stays pool-wide; a later PR grows just the bottleneck key
        self.pressure_by_member: dict[int, int] = {}
        # floor the pool to min_replicas up front (a pool built at R=1 with
        # min_replicas=2 should not wait for a breach to reach its floor)
        for m in self.members:
            if m.n_replicas < self.policy.min_replicas:
                m.scale_to(self.policy.min_replicas)

    # ------------------------------------------------------------- signals
    def pressure(self, rep) -> int:
        """Capacity pressure of one window: queries held out by the caps plus
        queries the Δ-heap packed into wider batches to fit them."""
        return int(getattr(rep, "n_capacity_held", 0)
                   + getattr(rep, "n_cap_packed", 0))

    # ------------------------------------------------------------- control
    def observe(self, rep, queue_depth: int, now: float) -> list[ScaleEvent]:
        """One control tick: fold a finished window's report into the breach
        streaks and actuate when hysteresis + cooldown allow.  Returns the
        scale events fired this tick (usually empty)."""
        p = self.policy
        if not self.members:
            return []
        for field_name in ("held_by_member", "packed_by_member"):
            for k, c in getattr(rep, field_name, ()):
                self.pressure_by_member[int(k)] = \
                    self.pressure_by_member.get(int(k), 0) + int(c)
        pressure = self.pressure(rep)
        late = getattr(rep, "late_s", 0.0)
        breach_up = (pressure >= p.up_pressure
                     or queue_depth >= p.up_queue_depth
                     or (p.late_high_s > 0 and late >= p.late_high_s))
        # shrink needs genuinely unused capacity, not just absent backlog: a
        # member dispatching at its group cap is saturated even at pressure 0
        # (the caps themselves kept the backlog away), and shrinking it would
        # only re-create the pressure next window (flapping)
        groups = list(getattr(rep, "group_models", ()))
        under_utilized = all(groups.count(k) < m.n_replicas
                             for k, m in self._indexed)
        breach_down = (pressure <= p.down_pressure
                       and queue_depth <= p.down_queue_depth
                       and under_utilized
                       and not breach_up)
        self._streaks.up = self._streaks.up + 1 if breach_up else 0
        self._streaks.down = self._streaks.down + 1 if breach_down else 0

        in_cooldown = (self._last_action_t is not None
                       and now - self._last_action_t < p.cooldown_s)
        fired: list[ScaleEvent] = []
        if self._streaks.up >= p.hold_windows and not in_cooldown:
            fired = self._actuate(+p.step, now,
                                  f"pressure={pressure} queue={queue_depth} "
                                  f"late={late:.3f}s")
        elif self._streaks.down >= p.hold_windows and not in_cooldown:
            fired = self._actuate(-p.step, now,
                                  f"idle: pressure={pressure} queue={queue_depth}")
        if fired:
            self._last_action_t = now
            self._streaks = _Streaks()        # a fresh breach must rebuild
        return fired

    def _actuate(self, delta: int, now: float, reason: str) -> list[ScaleEvent]:
        p = self.policy
        fired = []
        for m in self.members:
            cur = int(m.n_replicas)
            # an async-building set (ReplicaSet(async_build=True)) counts its
            # in-flight factory builds toward the target, so a sustained
            # breach never double-builds while a warm engine is on its way
            pending = int(getattr(m, "n_pending_builds", 0))
            target = max(p.min_replicas, min(p.max_replicas, cur + pending + delta))
            if target == cur + pending:
                continue
            reached = int(m.scale_to(target))
            after = int(getattr(m, "n_pending_builds", 0))
            if reached != cur or after != pending:
                # from/to count in-flight builds: an async grow reads 1→2
                # when the warm engine is still constructing off-thread
                fired.append(ScaleEvent(t=now, member=m.name,
                                        from_n=cur + pending,
                                        to_n=reached + after,
                                        reason=reason + (" (async build)"
                                                         if after > pending else "")))
        self.events.extend(fired)
        return fired

    # ------------------------------------------------------------ reporting
    def replica_counts(self) -> tuple:
        return tuple(int(m.n_replicas) for m in self.members)

    def summary(self) -> str:
        ups = sum(e.to_n > e.from_n for e in self.events)
        downs = len(self.events) - ups
        by_member = ("" if not self.pressure_by_member else
                     ", pressure by member " + str(dict(sorted(
                         self.pressure_by_member.items()))))
        return (f"autoscaler: {len(self.events)} actions ({ups} up, {downs} "
                f"down), replicas now {self.replica_counts()}{by_member}")
