"""Backlog-driven replica autoscaling: the control loop that *sizes* the pool.

The windowed scheduler (``greedy_schedule_window``) walks the cost-utility
frontier under a FIXED set of per-member capacity caps; this module closes the
remaining loop — from the backlog each :class:`~repro.serving.online.
WindowReport` exposes back to :meth:`repro.serving.pool.ReplicaSet.scale_to`:

    signal    capacity pressure  = n_capacity_held  (queries the caps pushed
                                   out of the window entirely)
                                 + n_cap_packed     (queries the capacity-aware
                                   Δ-heap squeezed into wider batches to fit)
              queue depth        = requests still pending after the round
              late_s             = realtime window-pacing lag
    decision  per-member hysteresis (``hold_windows`` consecutive breaches)
              + per-member ``cooldown_s``, so a one-window spike or a scale
              action's own transient never flaps the pool
    actuation ``ReplicaSet.scale_to(n ± step)`` within
              [``min_replicas``, ``max_replicas``] — grow attaches
              factory-built (or un-parks drained) replicas, shrink retires
              them drain-first through the ``ReplicaTracker``

Scaling acts on *capacity* signals only: budget-deferred work is excluded
from the pressure term, because adding replicas cannot buy budget.  The
server re-reads ``ReplicaSet.n_available()`` every window, so a scale action
reaches the scheduler's ``group_caps`` on the very next round.

The decision is *bottleneck-aware*: ``pressure_by_member`` keeps a
recency-weighted (exponentially decayed) per-member trace of the held/packed
attribution the reports carry, and a pool-wide up-breach grows only the
member that trace names as the bottleneck.  Shrink is evaluated per member —
a member whose own pressure is gone and whose groups run below its replica
count drains independently of its siblings.  Reports without attribution
(plain scalar counters) fall back to the original pool-wide actuation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Sequence

__all__ = ["AutoscalePolicy", "ScaleEvent", "Autoscaler"]


@dataclass
class AutoscalePolicy:
    """Knobs of the control loop (see docs/robustness.md for the diagram).

    ``up_pressure``/``down_pressure`` bound the per-window capacity-pressure
    signal (held + packed queries); ``up_queue_depth`` catches backlogs that
    build as plain queue growth; ``late_high_s`` (realtime only, 0 disables)
    treats window-pacing lag as saturation.  ``hold_windows`` and
    ``cooldown_s`` are the hysteresis: a breach must persist, and actions
    must space out, before a member moves.  ``pressure_decay`` halves (by
    default) the per-member pressure trace every window, so a burst that
    ended stops biasing bottleneck selection after a few rounds.
    """

    min_replicas: int = 1
    max_replicas: int = 4
    up_pressure: int = 4              # held+packed queries/window to grow on
    down_pressure: int = 0            # pressure ≤ this is a shrink candidate
    up_queue_depth: int = 32          # post-round queue depth to grow on
    down_queue_depth: int = 4         # queue must also be ≤ this to shrink
    late_high_s: float = 0.0          # realtime lateness to grow on (0 = off)
    hold_windows: int = 2             # consecutive breaches before acting
    cooldown_s: float = 1.0           # min serving-time between actions
    step: int = 1                     # replicas added/removed per action
    pressure_decay: float = 0.5       # per-window decay of the member trace


class ScaleEvent(NamedTuple):
    """One actuation, kept in :attr:`Autoscaler.events` (bench/debug trail)."""

    t: float
    member: str
    from_n: int
    to_n: int
    reason: str


@dataclass
class _Streaks:
    up: int = 0
    down: int = 0


# trace entries below this are dropped after decay (keeps the dict — and the
# summary line — from carrying a tail of vanishing floats forever)
_TRACE_EPS = 1e-3


class Autoscaler:
    """Grows/shrinks scalable pool members against window backlog.

    The up-breach *signal* is pool-wide (pressure, queue depth, lateness are
    properties of the round), but the *actuation* targets the bottleneck:
    the member with the largest decayed ``pressure_by_member`` trace grows,
    its siblings do not.  Shrink decisions are per member.  Each member keeps
    its own breach streaks and cooldown clock; a scale action resets only the
    acting member's streaks and pressure trace.

    Drive it with :meth:`observe` once per scheduling round — the online
    server does so automatically when ``OnlineConfig.autoscale`` is set.
    """

    def __init__(self, pool: Sequence, policy: AutoscalePolicy | None = None):
        self.policy = policy or AutoscalePolicy()
        self._indexed = [(k, m) for k, m in enumerate(pool)
                         if hasattr(m, "scale_to")]
        self.members = [m for _k, m in self._indexed]
        self.events: list[ScaleEvent] = []
        self._streaks: dict[int, _Streaks] = {k: _Streaks()
                                              for k, _m in self._indexed}
        self._last_action_t: dict[int, float] = {}
        # per-member capacity-pressure trace (WindowReport.held_by_member +
        # packed_by_member, exponentially decayed each window): names the
        # bottleneck for grow decisions and is reset by that member's action
        self.pressure_by_member: dict[int, float] = {}
        # floor the pool to min_replicas up front (a pool built at R=1 with
        # min_replicas=2 should not wait for a breach to reach its floor)
        for m in self.members:
            if m.n_replicas < self.policy.min_replicas:
                m.scale_to(self.policy.min_replicas)

    # ------------------------------------------------------------- signals
    def pressure(self, rep) -> int:
        """Capacity pressure of one window: queries held out by the caps plus
        queries the Δ-heap packed into wider batches to fit them."""
        return int(getattr(rep, "n_capacity_held", 0)
                   + getattr(rep, "n_cap_packed", 0))

    def _fold_trace(self, rep) -> dict[int, int]:
        """Decay the per-member trace one window, fold in this report's
        attribution, and return the *undecayed* per-member counts of this
        window alone (the shrink signal)."""
        window_by: dict[int, int] = {}
        for field_name in ("held_by_member", "packed_by_member"):
            for k, c in getattr(rep, field_name, ()):
                window_by[int(k)] = window_by.get(int(k), 0) + int(c)
        decayed: dict[int, float] = {}
        for k, v in self.pressure_by_member.items():
            v *= self.policy.pressure_decay
            if v >= _TRACE_EPS:
                decayed[k] = v
        for k, c in window_by.items():
            decayed[k] = decayed.get(k, 0.0) + c
        self.pressure_by_member = decayed
        return window_by

    # ------------------------------------------------------------- control
    def observe(self, rep, queue_depth: int, now: float) -> list[ScaleEvent]:
        """One control tick: fold a finished window's report into the
        per-member breach streaks and actuate where hysteresis + cooldown
        allow.  Returns the scale events fired this tick (usually empty)."""
        p = self.policy
        if not self.members:
            return []
        window_by = self._fold_trace(rep)
        pressure = self.pressure(rep)
        late = getattr(rep, "late_s", 0.0)
        breach_up = (pressure >= p.up_pressure
                     or queue_depth >= p.up_queue_depth
                     or (p.late_high_s > 0 and late >= p.late_high_s))
        # grow only where the trace says the pressure lives; reports without
        # attribution (plain scalar counters) keep the legacy pool-wide grow
        scalable = [k for k, _m in self._indexed]
        trace = {k: v for k, v in self.pressure_by_member.items()
                 if k in scalable and v > 0}
        if not breach_up:
            up_members: set[int] = set()
        elif trace:
            up_members = {max(sorted(trace), key=trace.get)}
        else:
            up_members = set(scalable)
        attributed = bool(window_by) or pressure == 0
        groups = list(getattr(rep, "group_models", ()))

        fired: list[ScaleEvent] = []
        for k, m in self._indexed:
            # shrink needs genuinely unused capacity, not just absent
            # backlog: a member dispatching at its group cap is saturated
            # even at pressure 0 (the caps themselves kept the backlog
            # away), and shrinking it would only re-create the pressure
            # next window (flapping)
            member_p = window_by.get(k, 0) if attributed else pressure
            up_k = k in up_members
            down_k = (not up_k
                      and not (breach_up and not trace)
                      and member_p <= p.down_pressure
                      and queue_depth <= p.down_queue_depth
                      and groups.count(k) < m.n_replicas)
            st = self._streaks[k]
            st.up = st.up + 1 if up_k else 0
            st.down = st.down + 1 if down_k else 0
            last = self._last_action_t.get(k)
            in_cooldown = last is not None and now - last < p.cooldown_s
            if st.up >= p.hold_windows and not in_cooldown:
                ev = self._actuate_member(
                    m, +p.step, now,
                    f"pressure={pressure} queue={queue_depth} late={late:.3f}s")
            elif st.down >= p.hold_windows and not in_cooldown:
                ev = self._actuate_member(
                    m, -p.step, now,
                    f"idle: pressure={member_p} queue={queue_depth}")
            else:
                continue
            if ev is not None:
                fired.append(ev)
                self._last_action_t[k] = now
                self._streaks[k] = _Streaks()   # a fresh breach must rebuild
                self.pressure_by_member.pop(k, None)  # action resets the trace
        self.events.extend(fired)
        return fired

    def _actuate_member(self, m, delta: int, now: float,
                        reason: str) -> ScaleEvent | None:
        p = self.policy
        cur = int(m.n_replicas)
        # an async-building set (ReplicaSet(async_build=True)) counts its
        # in-flight factory builds toward the target, so a sustained
        # breach never double-builds while a warm engine is on its way
        pending = int(getattr(m, "n_pending_builds", 0))
        target = max(p.min_replicas, min(p.max_replicas, cur + pending + delta))
        if target == cur + pending:
            return None
        reached = int(m.scale_to(target))
        after = int(getattr(m, "n_pending_builds", 0))
        if reached == cur and after == pending:
            return None
        # from/to count in-flight builds: an async grow reads 1→2
        # when the warm engine is still constructing off-thread
        return ScaleEvent(t=now, member=m.name, from_n=cur + pending,
                          to_n=reached + after,
                          reason=reason + (" (async build)"
                                           if after > pending else ""))

    # ------------------------------------------------------------ reporting
    def replica_counts(self) -> tuple:
        return tuple(int(m.n_replicas) for m in self.members)

    def events_by_member(self) -> dict[str, tuple[int, int]]:
        """``{member name: (n up-events, n down-events)}`` over the run."""
        out: dict[str, tuple[int, int]] = {}
        for e in self.events:
            up, down = out.get(e.member, (0, 0))
            if e.to_n > e.from_n:
                up += 1
            else:
                down += 1
            out[e.member] = (up, down)
        return out

    def summary(self) -> str:
        ups = sum(e.to_n > e.from_n for e in self.events)
        downs = len(self.events) - ups
        by_member = ("" if not self.pressure_by_member else
                     ", pressure by member " + str({
                         k: round(v, 2) for k, v in
                         sorted(self.pressure_by_member.items())}))
        acted = ("" if not self.events else
                 ", actions by member " + str({
                     name: f"+{u}/-{d}" for name, (u, d) in
                     sorted(self.events_by_member().items())}))
        return (f"autoscaler: {len(self.events)} actions ({ups} up, {downs} "
                f"down), replicas now {self.replica_counts()}{by_member}{acted}")
