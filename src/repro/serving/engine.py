"""Serving engine: slot-based continuous batching over prefill/decode steps.

One engine serves one model.  The KV cache is a fixed (max_slots, ...) pytree;
requests are admitted into free slots (their prefilled single-request cache is
scattered into the slot), all active slots decode in lockstep, and finished
requests retire immediately so new ones can be admitted mid-stream — the vLLM
iteration-level scheduling idea, realized with jit-static shapes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model


@dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new: int = 32
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    enqueued_at: float = field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None


class ServingEngine:
    """Continuous-batching engine for a single model on the local device(s)."""

    def __init__(self, model: Model, params, *, max_slots: int = 8, max_len: int = 1024,
                 eos_id: int = ByteTokenizer.eos, pad_id: int = ByteTokenizer.pad):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.cache = model.init_cache(max_slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self._prefill_len_cache: dict[int, Callable] = {}

        @jax.jit
        def _decode(params, tokens, cache):
            return model.decode_step(params, tokens, cache)

        self._decode = _decode

        @partial(jax.jit, static_argnums=(3,))
        def _prefill_one(params, tokens, lengths, max_len):
            return model.prefill(params, tokens, max_len, lengths=lengths)

        self._prefill_one = _prefill_one

        @jax.jit
        def _insert(cache, one_cache, slot):
            def ins_axis(axis):
                def ins(dst, src):
                    return jax.lax.dynamic_update_slice_in_dim(
                        dst, src.astype(dst.dtype), slot, axis=axis)
                return ins
            out = {}
            for key, sub in cache.items():
                # "blocks" leaves are layer-stacked: batch dim is axis 1
                axis = 1 if key == "blocks" else 0
                out[key] = jax.tree.map(ins_axis(axis), sub, one_cache[key])
            return out

        self._insert = _insert

    # ------------------------------------------------------------------
    def _bucket_len(self, n: int) -> int:
        """Pad prompt lengths to power-of-two buckets to bound jit variants."""
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _admit(self, req: Request, slot: int):
        tok = ByteTokenizer()
        L = self._bucket_len(len(req.tokens))
        tokens, lengths = tok.pad_batch([req.tokens], L)
        logits, one_cache = self._prefill_one(self.params, jnp.asarray(tokens),
                                              jnp.asarray(lengths), self.max_len)
        self.cache = self._insert(self.cache, one_cache, slot)
        self.slot_req[slot] = req
        req.started_at = time.time()
        first = int(jnp.argmax(logits[0, 0]))
        req.out_tokens.append(first)
        if first == self.eos_id:
            self._retire(slot)

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
            req.finished_at = time.time()
        self.slot_req[slot] = None

    def _active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    # ------------------------------------------------------------------
    def serve(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        """Run all requests to completion with continuous batching."""
        queue = list(requests)
        while queue or self._active_slots():
            # admission: fill free slots
            for slot in range(self.max_slots):
                if self.slot_req[slot] is None and queue:
                    self._admit(queue.pop(0), slot)
            active = self._active_slots()
            if not active:
                continue
            # lockstep decode across all slots (inactive slots decode garbage
            # into their own slot state; they are reset at admission)
            last = np.full((self.max_slots, 1), self.pad_id, dtype=np.int32)
            for i in active:
                last[i, 0] = self.slot_req[i].out_tokens[-1]
            logits, self.cache = self._decode(self.params, jnp.asarray(last), self.cache)
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for i in active:
                req = self.slot_req[i]
                req.out_tokens.append(int(nxt[i]))
                total_len = len(req.tokens) + len(req.out_tokens)
                if (int(nxt[i]) == self.eos_id or len(req.out_tokens) >= req.max_new
                        or total_len >= self.max_len - 1):
                    self._retire(i)
        return requests

    # convenience --------------------------------------------------------
    def generate_text(self, prompts: list[str], max_new: int = 32) -> list[str]:
        tok = ByteTokenizer()
        reqs = [Request(rid=i, tokens=tok.encode(p), max_new=max_new)
                for i, p in enumerate(prompts)]
        self.serve(reqs)
        outs = []
        for r in reqs:
            ids = r.out_tokens
            if self.eos_id in ids:
                ids = ids[: ids.index(self.eos_id)]
            outs.append(tok.decode(ids))
        return outs
