"""Serving engine: slot-based continuous batching over prefill/decode steps.

One engine serves one model.  The KV cache is a fixed (max_slots, ...) pytree;
requests are admitted into free slots (their prefilled cache rows are
scattered into the slots), all active slots decode in lockstep, and finished
requests retire immediately so new ones can be admitted mid-stream — the vLLM
iteration-level scheduling idea, realized with jit-static shapes.

The generation path is **fused on-device**: one jitted ``jax.lax.scan``
(:attr:`ServingEngine._decode_k`) generates ``decode_block`` tokens per host
dispatch with on-device greedy sampling and per-slot active/EOS/max_new
masking, returning only a ``(K, max_slots)`` token block plus validity masks
to the host — the host syncs once per K tokens instead of once per token.
The KV cache is **donated** through the decode and insert jits
(``donate_argnums``), so decode updates the cache buffers in place instead of
copying the full ``(max_slots, max_len, ...)`` pytree every step.  Decode
attention reads only a power-of-two **horizon** slice of the cache covering
the longest live sequence plus the K-token block (the seq axis is bucketed
like prompt lengths, so jit variants stay bounded): on CPU the decode step is
memory-bound on the K/V read, and short streams in a long-``max_len`` engine
stop paying for buffer they have not filled.  Admission is **batched**: every
request admitted in one serving tick is padded to a shared length bucket,
prefilled in a single call, and scatter-inserted into its slot by one fused
``_insert_many``.

The pre-fusion driver survives as :meth:`ServingEngine.serve_stepwise` (one
host round-trip per token, per-request prefill) — the parity reference for
``tests/test_engine.py`` and the baseline leg of
``benchmarks/engine_decode.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model


@dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new: int = 32
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    enqueued_at: float = field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None


class ServingEngine:
    """Continuous-batching engine for a single model on the local device(s).

    ``decode_block`` is K, the number of tokens generated per host dispatch
    by the fused scan; K=1 degenerates to one sync per token (still fused
    sampling/masking on device).  Greedy outputs are bit-identical for every
    K (parity-tested) — K only trades host round-trips against up to K−1
    wasted lockstep steps on the final block of a stream.
    """

    def __init__(self, model: Model, params, *, max_slots: int = 8, max_len: int = 1024,
                 decode_block: int = 8,
                 eos_id: int = ByteTokenizer.eos, pad_id: int = ByteTokenizer.pad):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_block = max(1, int(decode_block))
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.cache = model.init_cache(max_slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.tok = ByteTokenizer()          # engine-owned: one instance, all paths
        # telemetry: host dispatches vs device steps (benchmarks/engine_decode.py)
        self.n_decode_calls = 0             # host→device decode dispatches
        self.n_decode_steps = 0             # device decode steps they executed
        self.n_prefill_calls = 0            # admission prefill dispatches

        @jax.jit
        def _decode(params, tokens, cache):
            # the pre-fusion reference step: deliberately NOT donated — one
            # full-cache copy per token is part of what serve_stepwise
            # baselines (benchmarks/engine_decode.py measures against it)
            return model.decode_step(params, tokens, cache)

        self._decode = _decode

        @partial(jax.jit, static_argnums=(3,))
        def _prefill(params, tokens, lengths, max_len):
            return model.prefill(params, tokens, max_len, lengths=lengths)

        self._prefill = _prefill

        @partial(jax.jit, donate_argnums=(0,))
        def _insert_many(cache, rows, slots):
            # scatter B freshly prefilled cache rows into their slots in one
            # fused update; a slot index of max_slots marks a padding row of
            # the admission bucket and mode="drop" discards it
            def ins_axis(axis):
                def ins(dst, src):
                    src = src.astype(dst.dtype)
                    if axis == 0:
                        return dst.at[slots].set(src, mode="drop")
                    return dst.at[:, slots].set(src, mode="drop")
                return ins
            out = {}
            for key, sub in cache.items():
                # "blocks" leaves are layer-stacked: batch dim is axis 1
                axis = 1 if key == "blocks" else 0
                out[key] = jax.tree.map(ins_axis(axis), sub, rows[key])
            return out

        self._insert_many = _insert_many

        def _seq_axis(leaf) -> Optional[int]:
            # K/V cache leaves are (..., seq, kv_heads, head_dim) with
            # seq == max_len for global attention (window/ring caches and
            # recurrent states are smaller and never match) — the only
            # leaves the decode horizon may shrink
            if leaf.ndim >= 3 and leaf.shape[-3] == self.max_len:
                return leaf.ndim - 3
            return None

        @partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
        def _decode_k(horizon, params, cache, last_tok, active, n_out, limit):
            """K decode steps fused in one dispatch.

            Device state per slot: ``last_tok`` (next input token), ``active``
            (still generating), ``n_out`` (tokens emitted so far, prefill
            first token included), ``limit`` (min(max_new, max_len−1−prompt)).
            Returns the updated cache (donated — in-place), the final active
            mask, the (K, max_slots) greedy token block and a validity mask
            (``valid[k, i]`` ⇔ slot i was active entering step k, i.e. token
            ``toks[k, i]`` belongs to its stream).  Inactive slots decode
            garbage into their own cache rows, exactly like the stepwise
            driver — admission overwrites the whole row.

            ``horizon`` (static) bounds the K/V positions attention can see:
            the scan runs on a ``[:horizon]`` slice of the seq axis and the
            slice is written back into the donated full buffer afterwards.
            The host guarantees horizon ≥ the largest live sequence length
            + K, so the restriction is exact (greedy outputs are parity-
            tested against the full-horizon stepwise path); a retired slot's
            garbage stream may run past the horizon, where its writes drop
            out of bounds — admission rebuilds the row from prefill anyway.
            """
            def shrink(leaf):
                ax = _seq_axis(leaf)
                if ax is None or horizon >= self.max_len:
                    return leaf
                return jax.lax.slice_in_dim(leaf, 0, horizon, axis=ax)

            def merge(full, small):
                if full.shape == small.shape:
                    return small
                return jax.lax.dynamic_update_slice_in_dim(
                    full, small, 0, axis=full.ndim - 3)

            small = jax.tree.map(shrink, cache)

            def step(carry, _):
                sc, last, act, n = carry
                logits, sc = model.decode_step(params, last[:, None], sc)
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                n = n + act.astype(jnp.int32)
                done = act & ((nxt == self.eos_id) | (n >= limit))
                last = jnp.where(act, nxt, last)
                return (sc, last, act & ~done, n), (nxt, act)

            (small, _last, act, _n), (toks, valid) = jax.lax.scan(
                step, (small, last_tok, active, n_out), None,
                length=self.decode_block)
            cache = jax.tree.map(merge, cache, small)
            return cache, act, toks, valid

        self._decode_k = _decode_k

    # ------------------------------------------------------------------
    def _bucket_len(self, n: int) -> int:
        """Pad prompt lengths to power-of-two buckets to bound jit variants."""
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _bucket_count(self, n: int) -> int:
        """Pad admission batch sizes to power-of-two buckets (≤ max_slots)."""
        b = 1
        while b < n:
            b *= 2
        return min(b, self.max_slots)

    def _admit_batch(self, reqs: list[Request], slots: list[int]):
        """Admit ``reqs`` into ``slots`` with ONE prefill + ONE insert: all
        prompts pad to a shared length bucket, the batch count pads to a
        power-of-two bucket (padding rows scatter out of bounds and drop)."""
        B = self._bucket_count(len(reqs))
        L = self._bucket_len(max(len(r.tokens) for r in reqs))
        seqs = [r.tokens for r in reqs] + [[self.pad_id]] * (B - len(reqs))
        tokens, lengths = self.tok.pad_batch(seqs, L)
        slot_arr = np.full(B, self.max_slots, dtype=np.int32)
        slot_arr[: len(reqs)] = slots
        logits, rows = self._prefill(self.params, jnp.asarray(tokens),
                                     jnp.asarray(lengths), self.max_len)
        self.n_prefill_calls += 1
        self.cache = self._insert_many(self.cache, rows, jnp.asarray(slot_arr))
        first = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        now = time.time()
        for req, slot, f in zip(reqs, slots, first):
            self.slot_req[slot] = req
            req.started_at = now
            req.finished_at = None      # clear stale timing on re-admission
            req.done = False
            req.out_tokens.append(int(f))
            if int(f) == self.eos_id:
                self._retire(slot)

    def _admit_free(self, queue: list[Request]):
        """Fill every free slot from the queue (FCFS, slot-index order); an
        EOS-at-prefill retirement frees its slot for the next round."""
        while queue:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free:
                return
            n = min(len(free), len(queue))
            self._admit_batch([queue.pop(0) for _ in range(n)], free[:n])

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
            req.finished_at = time.time()
        self.slot_req[slot] = None

    def _active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _slot_state(self):
        """Host view of the device decode state, rebuilt from the requests
        each fused call — the host bookkeeping stays authoritative."""
        last = np.zeros(self.max_slots, dtype=np.int32)
        act = np.zeros(self.max_slots, dtype=bool)
        n_out = np.zeros(self.max_slots, dtype=np.int32)
        limit = np.ones(self.max_slots, dtype=np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            last[i] = req.out_tokens[-1]
            act[i] = True
            n_out[i] = len(req.out_tokens)
            limit[i] = min(req.max_new, self.max_len - 1 - len(req.tokens))
        return last, act, n_out, limit

    # ------------------------------------------------------------------
    def serve(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        """Run all requests to completion with continuous batching.

        Fused driver: one batched admission per tick, then one
        ``_decode_k`` dispatch generates up to ``decode_block`` tokens for
        every active slot before the host looks at the results again.
        """
        queue = list(requests)
        while queue or self._active_slots():
            self._admit_free(queue)
            active = self._active_slots()
            if not active:
                continue
            last, act, n_out, limit = self._slot_state()
            live = max(len(self.slot_req[i].tokens) + len(self.slot_req[i].out_tokens)
                       for i in active)
            horizon = min(self.max_len, self._bucket_len(live + self.decode_block))
            self.cache, act_f, toks, valid = self._decode_k(
                horizon, self.params, self.cache, jnp.asarray(last),
                jnp.asarray(act), jnp.asarray(n_out), jnp.asarray(limit))
            self.n_decode_calls += 1
            self.n_decode_steps += self.decode_block
            toks = np.asarray(toks)
            valid = np.asarray(valid)
            act_f = np.asarray(act_f)
            for i in active:
                req = self.slot_req[i]
                req.out_tokens.extend(int(t) for t in toks[valid[:, i], i])
                if not act_f[i]:
                    self._retire(i)
        return requests

    def serve_stepwise(self, requests: list[Request]) -> list[Request]:
        """Pre-fusion reference driver: per-request prefill admission and one
        host round-trip (dispatch + argmax sync) per generated token.  Kept
        for the fused-path parity tests and as the baseline leg of
        ``benchmarks/engine_decode.py``; outputs are bit-identical to
        :meth:`serve` under greedy sampling."""
        queue = list(requests)
        while queue or self._active_slots():
            for slot in range(self.max_slots):
                if self.slot_req[slot] is None and queue:
                    self._admit_batch([queue.pop(0)], [slot])
            active = self._active_slots()
            if not active:
                continue
            # lockstep decode across all slots (inactive slots decode garbage
            # into their own slot state; they are reset at admission)
            last = np.full((self.max_slots, 1), self.pad_id, dtype=np.int32)
            for i in active:
                last[i, 0] = self.slot_req[i].out_tokens[-1]
            logits, self.cache = self._decode(self.params, jnp.asarray(last), self.cache)
            self.n_decode_calls += 1
            self.n_decode_steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for i in active:
                req = self.slot_req[i]
                req.out_tokens.append(int(nxt[i]))
                total_len = len(req.tokens) + len(req.out_tokens)
                if (int(nxt[i]) == self.eos_id or len(req.out_tokens) >= req.max_new
                        or total_len >= self.max_len - 1):
                    self._retire(i)
        return requests

    # convenience --------------------------------------------------------
    def generate_text(self, prompts: list[str], max_new: int = 32) -> list[str]:
        reqs = [Request(rid=i, tokens=self.tok.encode(p), max_new=max_new)
                for i, p in enumerate(prompts)]
        self.serve(reqs)
        outs = []
        for r in reqs:
            ids = r.out_tokens
            if self.eos_id in ids:
                ids = ids[: ids.index(self.eos_id)]
            outs.append(self.tok.decode(ids))
        return outs
