"""Serving engine: slot-based continuous batching over prefill/decode steps.

One engine serves one model.  The KV cache is a fixed (max_slots, ...) pytree;
requests are admitted into free slots (their prefilled cache rows are
scattered into the slots), all active slots decode in lockstep, and finished
requests retire immediately so new ones can be admitted mid-stream — the vLLM
iteration-level scheduling idea, realized with jit-static shapes.

The generation path is **fused on-device**: one jitted ``jax.lax.scan``
(:attr:`ServingEngine._decode_k`) generates ``decode_block`` tokens per host
dispatch with on-device greedy sampling and per-slot active/EOS/max_new
masking, returning only a ``(K, max_slots)`` token block plus validity masks
to the host — the host syncs once per K tokens instead of once per token.
The KV cache is **donated** through the decode and insert jits
(``donate_argnums``), so decode updates the cache buffers in place instead of
copying the full ``(max_slots, max_len, ...)`` pytree every step.  Decode
attention reads only a power-of-two **horizon** slice of the cache covering
the longest live sequence plus the K-token block (the seq axis is bucketed
like prompt lengths, so jit variants stay bounded): on CPU the decode step is
memory-bound on the K/V read, and short streams in a long-``max_len`` engine
stop paying for buffer they have not filled.  Admission is **batched**: every
request admitted in one serving tick is padded to a shared length bucket,
prefilled in a single call, and scatter-inserted into its slot by one fused
``_insert_many``.

The pre-fusion driver survives as :meth:`ServingEngine.serve_stepwise` (one
host round-trip per token, per-request prefill) — the parity reference for
``tests/test_engine.py`` and the baseline leg of
``benchmarks/engine_decode.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models.transformer import Model
from repro.serving.generation import GenerationConfig
from repro.serving.kvpool import PagedCacheManager


def sample_tokens(logits, keys, temp, top_k, top_p):
    """Temperature/top-k/top-p sampling, one token per row.

    ``logits``: (B, V) float32; ``keys``: (B, 2) uint32 per-row PRNG keys
    (already folded with the stream position); ``temp``/``top_k``/``top_p``:
    (B,) per-row knobs.  Rows with ``temp <= 0`` take the greedy argmax —
    bit-identical to the plain argmax path, so mixed greedy/sampled batches
    are safe.  The filtering order is standard: temperature-scale, sort
    descending, intersect the top-k rank mask with the nucleus mask (the
    rank-0 token is always kept), then sample categorically over the
    survivors.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(lg, key, t, k, p):
        scaled = lg.astype(jnp.float32) / jnp.maximum(t, 1e-6)
        order = jnp.argsort(-scaled)                    # descending, stable
        sl = scaled[order]
        ranks = jnp.arange(sl.shape[-1], dtype=jnp.int32)
        keep = jnp.where(k > 0, ranks < k, True)
        probs = jax.nn.softmax(sl)
        keep &= (jnp.cumsum(probs) - probs) < p         # mass *before* token
        idx = jax.random.categorical(key, jnp.where(keep, sl, -jnp.inf))
        return order[idx].astype(jnp.int32)

    sampled = jax.vmap(one)(logits, keys, temp, top_k, top_p)
    return jnp.where(temp > 0.0, sampled, greedy)


def _fold_keys(keys, n):
    """Per-row ``fold_in``: key i is folded with stream position ``n[i]`` —
    the determinism pivot (see ``GenerationConfig``): position, never the
    dispatch step, so outputs are invariant to K/slot/replica placement."""
    return jax.vmap(jax.random.fold_in)(keys, n)


@dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new: int = 32
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    enqueued_at: float = field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    on_tokens: Optional[Callable[[list[int], bool], None]] = None
    # ^ streaming hook: called once per host dispatch that appended to
    #   out_tokens — the prefill's first token at admission, then each fused
    #   decode block (so the cadence is exactly ``decode_block`` tokens).
    #   Args: the freshly appended token ids and whether the request is done.
    #   Called from the serving thread; sinks must not block.
    gen: Optional[GenerationConfig] = None
    # ^ unified generation knobs; None (the deprecation shim) synthesizes a
    #   greedy config from the legacy ``max_new`` field — bit-identical to
    #   the pre-GenerationConfig engine.


class ServingEngine:
    """Continuous-batching engine for a single model on the local device(s).

    ``decode_block`` is K, the number of tokens generated per host dispatch
    by the fused scan; K=1 degenerates to one sync per token (still fused
    sampling/masking on device).  Greedy outputs are bit-identical for every
    K (parity-tested) — K only trades host round-trips against up to K−1
    wasted lockstep steps on the final block of a stream.

    ``paged=True`` swaps the contiguous ``(max_slots, max_len, ...)`` KV
    pytree for a paged layout: per-layer block *pools* of ``page_size``-token
    pages plus host-side per-slot block tables (:class:`PagedCacheManager`).
    Batched admission prefills the shared batch-prompt prefix once and maps
    every sibling slot's table onto the same physical pages (refcounted);
    a slot gets a private copy only when decode first appends into a shared
    page (copy-on-write, performed as one fused page-copy before the decode
    dispatch).  Greedy outputs are bit-identical to the contiguous path:
    causal attention makes the shared prefix K/V independent of what follows
    it, and reads beyond a slot's length are masked to exact zeros in both
    layouts.  ``share_prefix=False`` keeps paging but gives every slot
    private pages (the CoW machinery then never fires).
    """

    def __init__(self, model: Model, params, *, max_slots: int = 8, max_len: int = 1024,
                 decode_block: int = 8, paged: bool = False, page_size: int = 16,
                 share_prefix: bool = True,
                 eos_id: int = ByteTokenizer.eos, pad_id: int = ByteTokenizer.pad):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_block = max(1, int(decode_block))
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.share_prefix = bool(share_prefix)
        self.eos_id = eos_id
        self.pad_id = pad_id
        if self.paged:
            self.kv = PagedCacheManager(max_slots, max_len, self.page_size)
            self.cache = model.init_paged_cache(self.kv.alloc.n_pages,
                                                self.page_size, max_slots)
        else:
            self.kv = None
            self.cache = model.init_cache(max_slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.tok = ByteTokenizer()          # engine-owned: one instance, all paths
        # telemetry: host dispatches vs device steps (benchmarks/engine_decode.py)
        self.n_decode_calls = 0             # host→device decode dispatches
        self.n_decode_steps = 0             # device decode steps they executed
        self.n_prefill_calls = 0            # admission prefill dispatches

        @jax.jit
        def _decode(params, tokens, cache):
            # the pre-fusion reference step: deliberately NOT donated — one
            # full-cache copy per token is part of what serve_stepwise
            # baselines (benchmarks/engine_decode.py measures against it)
            return model.decode_step(params, tokens, cache)

        self._decode = _decode

        @partial(jax.jit, static_argnums=(3,))
        def _prefill(params, tokens, lengths, max_len):
            return model.prefill(params, tokens, max_len, lengths=lengths)

        self._prefill = _prefill

        @partial(jax.jit, donate_argnums=(0,))
        def _insert_many(cache, rows, slots):
            # scatter B freshly prefilled cache rows into their slots in one
            # fused update; a slot index of max_slots marks a padding row of
            # the admission bucket and mode="drop" discards it
            def ins_axis(axis):
                def ins(dst, src):
                    src = src.astype(dst.dtype)
                    if axis == 0:
                        return dst.at[slots].set(src, mode="drop")
                    return dst.at[:, slots].set(src, mode="drop")
                return ins
            out = {}
            for key, sub in cache.items():
                # "blocks" leaves are layer-stacked: batch dim is axis 1
                axis = 1 if key == "blocks" else 0
                out[key] = jax.tree.map(ins_axis(axis), sub, rows[key])
            return out

        self._insert_many = _insert_many

        def _seq_axis(leaf) -> Optional[int]:
            # K/V cache leaves are (..., seq, kv_heads, head_dim) with
            # seq == max_len for global attention (window/ring caches and
            # recurrent states are smaller and never match) — the only
            # leaves the decode horizon may shrink
            if leaf.ndim >= 3 and leaf.shape[-3] == self.max_len:
                return leaf.ndim - 3
            return None

        @partial(jax.jit, static_argnums=(0,), static_argnames=("sample",),
                 donate_argnums=(2,))
        def _decode_k(horizon, params, cache, last_tok, active, n_out, limit,
                      keys=None, temp=None, top_k=None, top_p=None, *,
                      sample=False):
            """K decode steps fused in one dispatch.

            Device state per slot: ``last_tok`` (next input token), ``active``
            (still generating), ``n_out`` (tokens emitted so far, prefill
            first token included), ``limit`` (min(max_new, max_len−1−prompt)).
            Returns the updated cache (donated — in-place), the final active
            mask, the (K, max_slots) greedy token block and a validity mask
            (``valid[k, i]`` ⇔ slot i was active entering step k, i.e. token
            ``toks[k, i]`` belongs to its stream).  Inactive slots decode
            garbage into their own cache rows, exactly like the stepwise
            driver — admission overwrites the whole row.

            ``horizon`` (static) bounds the K/V positions attention can see:
            the scan runs on a ``[:horizon]`` slice of the seq axis and the
            slice is written back into the donated full buffer afterwards.
            The host guarantees horizon ≥ the largest live sequence length
            + K, so the restriction is exact (greedy outputs are parity-
            tested against the full-horizon stepwise path); a retired slot's
            garbage stream may run past the horizon, where its writes drop
            out of bounds — admission rebuilds the row from prefill anyway.

            ``sample`` (static) switches the on-device token choice from
            greedy argmax to :func:`sample_tokens`; ``keys`` (max_slots, 2)
            are per-slot PRNG base keys folded with the position counter
            ``n`` carried through the scan — token t of a stream is a pure
            function of (seed, t).  With ``sample=False`` the traced graph
            is exactly the greedy one (the sampling args are never touched),
            so all-greedy serving stays bit-identical to the pre-sampling
            engine.
            """
            def shrink(leaf):
                ax = _seq_axis(leaf)
                if ax is None or horizon >= self.max_len:
                    return leaf
                return jax.lax.slice_in_dim(leaf, 0, horizon, axis=ax)

            def merge(full, small):
                if full.shape == small.shape:
                    return small
                return jax.lax.dynamic_update_slice_in_dim(
                    full, small, 0, axis=full.ndim - 3)

            small = jax.tree.map(shrink, cache)

            def step(carry, _):
                sc, last, act, n = carry
                logits, sc = model.decode_step(params, last[:, None], sc)
                if sample:
                    nxt = sample_tokens(logits[:, 0], _fold_keys(keys, n),
                                        temp, top_k, top_p)
                else:
                    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                n = n + act.astype(jnp.int32)
                done = act & ((nxt == self.eos_id) | (n >= limit))
                last = jnp.where(act, nxt, last)
                return (sc, last, act & ~done, n), (nxt, act)

            (small, _last, act, _n), (toks, valid) = jax.lax.scan(
                step, (small, last_tok, active, n_out), None,
                length=self.decode_block)
            cache = jax.tree.map(merge, cache, small)
            return cache, act, toks, valid

        self._decode_k = _decode_k

        @partial(jax.jit, donate_argnums=(0,))
        def _insert_pages(cache, rows, slots, dst_pages):
            """Scatter freshly prefilled rows into the page pools.

            ``rows`` is a prefill cache whose K/V leaves are ``(B, Lp, ...)``
            with ``Lp`` a page multiple; each row splits into ``Lp/page_size``
            logical pages and lands at the physical page ``dst_pages[b, j]``
            (sentinel ≥ n_pages drops the write — padding rows of the
            admission bucket, and shared prefix pages the owner row already
            wrote).  ``len`` leaves scatter per slot exactly as in the
            contiguous ``_insert_many``.
            """
            ps = self.page_size
            flat = dst_pages.reshape(-1)

            def ins_axis(axis):
                def ins(dst, src):
                    src = src.astype(dst.dtype)
                    if dst.ndim - axis >= 4:        # K/V pool leaf
                        lead = src.shape[:axis]
                        b, lp = src.shape[axis], src.shape[axis + 1]
                        src_r = src.reshape(*lead, b * (lp // ps), ps,
                                            *src.shape[axis + 2:])
                        if axis == 0:
                            return dst.at[flat].set(src_r, mode="drop")
                        return dst.at[:, flat].set(src_r, mode="drop")
                    if axis == 0:                   # per-slot length leaf
                        return dst.at[slots].set(src, mode="drop")
                    return dst.at[:, slots].set(src, mode="drop")
                return ins

            out = {}
            for key, sub in cache.items():
                axis = 1 if key == "blocks" else 0
                out[key] = jax.tree.map(ins_axis(axis), sub, rows[key])
            return out

        self._insert_pages = _insert_pages

        @partial(jax.jit, donate_argnums=(0,))
        def _fork_pages(cache, src_pages, dst_pages):
            """Copy-on-write device copy: physical page ``src[i]`` → ``dst[i]``
            in every layer's pools.  Sentinel entries (the fork list is padded
            to a size bucket) clip their read to the last real page and drop
            their write."""
            def cp_axis(axis):
                def cp(leaf):
                    if leaf.ndim - axis < 4:
                        return leaf                 # length leaf: no pages
                    safe = jnp.minimum(src_pages, leaf.shape[axis] - 1)
                    if axis == 0:
                        return leaf.at[dst_pages].set(leaf[safe], mode="drop")
                    return leaf.at[:, dst_pages].set(leaf[:, safe], mode="drop")
                return cp

            return {key: jax.tree.map(cp_axis(1 if key == "blocks" else 0), sub)
                    for key, sub in cache.items()}

        self._fork_pages = _fork_pages

        @partial(jax.jit, static_argnames=("sample",), donate_argnums=(1,))
        def _decode_k_paged(params, cache, table, last_tok, active, n_out,
                            limit, keys=None, temp=None, top_k=None,
                            top_p=None, *, sample=False):
            """Paged twin of ``_decode_k``: same fused K-step scan, same
            donated in-place cache, but attention walks ``table`` (already
            sliced host-side to the bucketed horizon's column count, which
            bounds both per-step attention cost and jit variants — the paged
            analogue of the contiguous horizon slice).  No seq-axis shrink:
            the pool is shared, the table IS the horizon.  Sampling args as
            in ``_decode_k``.
            """
            def step(carry, _):
                sc, last, act, n = carry
                logits, sc = model.decode_step(params, last[:, None], sc,
                                               table=table)
                if sample:
                    nxt = sample_tokens(logits[:, 0], _fold_keys(keys, n),
                                        temp, top_k, top_p)
                else:
                    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                n = n + act.astype(jnp.int32)
                done = act & ((nxt == self.eos_id) | (n >= limit))
                last = jnp.where(act, nxt, last)
                return (sc, last, act & ~done, n), (nxt, act)

            (cache, _last, act, _n), (toks, valid) = jax.lax.scan(
                step, (cache, last_tok, active, n_out), None,
                length=self.decode_block)
            return cache, act, toks, valid

        self._decode_k_paged = _decode_k_paged

        @jax.jit
        def _pick_tokens(logits, keys, n, temp, top_k, top_p):
            # one sampled token per row at stream position ``n`` — the
            # admission first-token and stepwise-driver analogue of the
            # in-scan sampling (identical fold-in, so fused/stepwise agree)
            return sample_tokens(logits, _fold_keys(keys, n), temp, top_k,
                                 top_p)

        self._pick_tokens = _pick_tokens

        @partial(jax.jit, donate_argnums=(0,))
        def _set_lens(cache, lens):
            # speculative-decode rollback: reset every per-slot KV length
            # leaf ((..., max_slots) int32) to ``lens`` — pages past the new
            # length are dropped host-side by the block-table truncation, so
            # no KV bytes move
            def fix(leaf):
                if (leaf.dtype == jnp.int32 and leaf.ndim >= 1
                        and leaf.shape[-1] == self.max_slots):
                    return jnp.broadcast_to(lens.astype(jnp.int32), leaf.shape)
                return leaf
            return jax.tree.map(fix, cache)

        self._set_lens = _set_lens

    # ------------------------------------------------------------------
    def _bucket_len(self, n: int) -> int:
        """Pad prompt lengths to power-of-two buckets to bound jit variants."""
        b = 16
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _bucket_count(self, n: int) -> int:
        """Pad admission batch sizes to power-of-two buckets (≤ max_slots)."""
        b = 1
        while b < n:
            b *= 2
        return min(b, self.max_slots)

    def _sibling_share_pages(self, owner: list[int], sib: list[int]) -> int:
        """How many of the owner's prompt pages a sibling admitted in the
        same batch may point at.  Page j is shareable iff the two prompts
        agree on every position of it the SIBLING will ever read unmasked —
        i.e. the common token prefix reaches ``min(len(sib), page_end)``.
        So a sibling that is a prefix of the owner (identical prompts
        included) shares even the final partial page (positions past its
        length are masked, and its first decode append CoW-forks the page);
        past a genuine divergence the floor applies."""
        common = 0
        for a, b in zip(owner, sib):
            if a != b:
                break
            common += 1
        if common == len(sib):
            return -(-common // self.page_size)
        return common // self.page_size

    def _admit_batch(self, reqs: list[Request], slots: list[int]):
        """Admit ``reqs`` into ``slots`` with ONE prefill + ONE insert: all
        prompts pad to a shared length bucket, the batch count pads to a
        power-of-two bucket (padding rows scatter out of bounds and drop).

        Paged mode pads prompts to a page multiple and builds the sharing
        plan first: the owner (first request) allocates and writes all its
        prompt pages; every sibling points its table at the owner's common-
        prefix pages (refcount bump, write dropped via sentinel) and
        allocates only the pages past the shared prefix.  The shared prefix
        K/V is therefore prefilled B times but *stored* once — causal
        attention makes each row's prefix K/V bit-identical, so which row's
        bytes land is immaterial.
        """
        B = self._bucket_count(len(reqs))
        L = self._bucket_len(max(len(r.tokens) for r in reqs))
        if self.paged:
            ps = self.page_size
            Lp = -(-L // ps) * ps           # page-multiple prompt buffer
            seqs = [r.tokens for r in reqs] + [[self.pad_id]] * (B - len(reqs))
            tokens, lengths = self.tok.pad_batch(seqs, Lp)
            dst = np.full((B, Lp // ps), self.kv.alloc.n_pages, np.int32)
            owner_pages: list[int] = []
            for idx, (req, slot) in enumerate(zip(reqs, slots)):
                n_need = -(-len(req.tokens) // ps)
                if idx == 0:
                    pages = self.kv.alloc.alloc_n(n_need)
                    owner_pages = pages
                    dst[idx, :n_need] = pages
                else:
                    n_sh = 0
                    if self.share_prefix:
                        n_sh = min(self._sibling_share_pages(reqs[0].tokens,
                                                             req.tokens),
                                   n_need, len(owner_pages))
                    pages = [self.kv.alloc.share(p) for p in owner_pages[:n_sh]]
                    priv = self.kv.alloc.alloc_n(n_need - n_sh)
                    dst[idx, n_sh:n_need] = priv
                    pages = pages + priv
                self.kv.map_slot(slot, pages)
            logits, rows = self._prefill(self.params, jnp.asarray(tokens),
                                         jnp.asarray(lengths), Lp)
            self.n_prefill_calls += 1
            slot_arr = np.full(B, self.max_slots, dtype=np.int32)
            slot_arr[: len(reqs)] = slots
            self.cache = self._insert_pages(self.cache, rows,
                                            jnp.asarray(slot_arr),
                                            jnp.asarray(dst))
        else:
            seqs = [r.tokens for r in reqs] + [[self.pad_id]] * (B - len(reqs))
            tokens, lengths = self.tok.pad_batch(seqs, L)
            slot_arr = np.full(B, self.max_slots, dtype=np.int32)
            slot_arr[: len(reqs)] = slots
            logits, rows = self._prefill(self.params, jnp.asarray(tokens),
                                         jnp.asarray(lengths), self.max_len)
            self.n_prefill_calls += 1
            self.cache = self._insert_many(self.cache, rows, jnp.asarray(slot_arr))
        gens = [self._gen_of(r) for r in reqs]
        if any(not g.greedy for g in gens):
            # sampled first token: stream position 0, same fold-in as every
            # later position — padding rows of the bucket stay greedy
            rows_n = int(logits.shape[0])
            keys = np.zeros((rows_n, 2), dtype=np.uint32)
            temp = np.zeros(rows_n, dtype=np.float32)
            top_k = np.zeros(rows_n, dtype=np.int32)
            top_p = np.ones(rows_n, dtype=np.float32)
            for j, (req, g) in enumerate(zip(reqs, gens)):
                if g.greedy:
                    continue
                keys[j] = self._base_key(req)
                temp[j] = g.temperature
                top_k[j] = g.top_k
                top_p[j] = g.top_p
            first = np.asarray(self._pick_tokens(
                logits[:, 0], jnp.asarray(keys),
                jnp.zeros(rows_n, jnp.int32), jnp.asarray(temp),
                jnp.asarray(top_k), jnp.asarray(top_p)))
        else:
            first = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        now = time.time()
        for req, slot, f in zip(reqs, slots, first):
            self.slot_req[slot] = req
            req.started_at = now
            req.finished_at = None      # clear stale timing on re-admission
            req.done = False
            req.out_tokens.append(int(f))
            if int(f) == self.eos_id:
                self._retire(slot)
            if req.on_tokens is not None:
                req.on_tokens([int(f)], req.done)

    def _admit_free(self, queue: list[Request]):
        """Fill every free slot from the queue (FCFS, slot-index order); an
        EOS-at-prefill retirement frees its slot for the next round."""
        while queue:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free:
                return
            n = min(len(free), len(queue))
            self._admit_batch([queue.pop(0) for _ in range(n)], free[:n])

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        if req is not None:
            req.done = True
            req.finished_at = time.time()
        self.slot_req[slot] = None
        if self.paged:
            # drop the slot's table references; only pages no sibling still
            # shares actually return to the free list
            self.kv.release_slot(slot)

    def _active_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    @staticmethod
    def _gen_of(req: Request) -> GenerationConfig:
        """Effective generation config: the request's, or (deprecation shim)
        a greedy one synthesized from the legacy ``max_new`` field."""
        if req.gen is not None:
            return req.gen
        return GenerationConfig(max_new=req.max_new)

    @staticmethod
    def _base_key(req: Request) -> np.ndarray:
        """Per-request PRNG base key (cached on the request — admission to
        retirement, every driver folds the same base with the position)."""
        key = getattr(req, "_prng_base", None)
        if key is None:
            seed = ServingEngine._gen_of(req).seed
            key = np.asarray(jax.random.PRNGKey(seed), dtype=np.uint32)
            req._prng_base = key
        return key

    def _slot_state(self):
        """Host view of the device decode state, rebuilt from the requests
        each fused call — the host bookkeeping stays authoritative."""
        last = np.zeros(self.max_slots, dtype=np.int32)
        act = np.zeros(self.max_slots, dtype=bool)
        n_out = np.zeros(self.max_slots, dtype=np.int32)
        limit = np.ones(self.max_slots, dtype=np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            last[i] = req.out_tokens[-1]
            act[i] = True
            n_out[i] = len(req.out_tokens)
            limit[i] = min(self._gen_of(req).max_new,
                           self.max_len - 1 - len(req.tokens))
        return last, act, n_out, limit

    def _sampling_state(self):
        """Per-slot sampling arrays for the fused/stepwise dispatch; rows of
        greedy requests stay at (temp=0, key=0) and take the argmax branch
        inside :func:`sample_tokens`.  ``sample`` is False iff every live
        request is greedy — the dispatch then omits the sampling args
        entirely and runs the exact pre-sampling graph."""
        keys = np.zeros((self.max_slots, 2), dtype=np.uint32)
        temp = np.zeros(self.max_slots, dtype=np.float32)
        top_k = np.zeros(self.max_slots, dtype=np.int32)
        top_p = np.ones(self.max_slots, dtype=np.float32)
        sample = False
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            g = self._gen_of(req)
            if g.greedy:
                continue
            sample = True
            keys[i] = self._base_key(req)
            temp[i] = g.temperature
            top_k[i] = g.top_k
            top_p[i] = g.top_p
        return sample, keys, temp, top_k, top_p

    def _prepare_paged(self, active: list[int], horizon: int,
                       offset: int = 0):
        """Page maintenance before one paged decode dispatch: grow every
        active slot's table to cover its next ``decode_block`` writes, CoW-
        fork any still-shared page in that write range (one fused device
        copy for the whole tick), and upload the table sliced to the
        horizon's column count — the slice is what bounds per-step attention
        cost, playing the role of the contiguous path's seq-axis shrink.

        ``offset`` shifts the first write position relative to the default
        ``prompt + emitted``: the speculative engine passes −1 because its
        dispatches re-feed the last emitted token (whose KV was rolled back
        or never written), so the write range starts one position earlier."""
        ps = self.page_size
        cap = self.kv.pages_per_slot * ps
        src: list[int] = []
        dst: list[int] = []
        for i in active:
            req = self.slot_req[i]
            ln = len(req.tokens) + len(req.out_tokens) + offset
            end = min(ln + self.decode_block, cap)
            self.kv.extend_slot(i, -(-end // ps))
            s, d = self.kv.fork_for_write(i, ln, end)
            src += s
            dst += d
        if src:
            # pad the fork list to a power-of-two bucket (bounds jit
            # variants); sentinel pads clip their read and drop their write
            nb = 1
            while nb < len(src):
                nb *= 2
            sentinel = self.kv.alloc.n_pages
            sa = np.full(nb, sentinel, np.int32)
            da = np.full(nb, sentinel, np.int32)
            sa[: len(src)] = src
            da[: len(dst)] = dst
            self.cache = self._fork_pages(self.cache, jnp.asarray(sa),
                                          jnp.asarray(da))
        n_cols = min(self.kv.pages_per_slot, -(-horizon // ps))
        return jnp.asarray(self.kv.table[:, :n_cols])

    def kv_occupancy(self) -> dict:
        """KV memory telemetry for the serving plane (WindowReport / bench).

        Contiguous engines report the committed buffer size (every slot owns
        a full ``max_len`` row whether it uses it or not); paged engines
        report live and peak *mapped* bytes — distinct physical pages times
        per-page bytes summed across layers — which is what prefix sharing
        and page-granular growth actually save.
        """
        kv_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache)
                       if leaf.ndim >= 3)
        if not self.paged:
            return {"paged": False, "kv_bytes": kv_bytes,
                    "peak_kv_bytes": kv_bytes}
        occ = self.kv.occupancy()
        page_bytes = kv_bytes // max(self.kv.alloc.n_pages, 1)
        occ.update(paged=True, page_bytes=page_bytes,
                   kv_bytes=occ["pages_used"] * page_bytes,
                   peak_kv_bytes=occ["peak_pages"] * page_bytes)
        return occ

    # ------------------------------------------------------------------
    def serve(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        """Run all requests to completion with continuous batching.

        Fused driver: one batched admission per tick, then one
        ``_decode_k`` dispatch generates up to ``decode_block`` tokens for
        every active slot before the host looks at the results again.
        """
        queue = list(requests)
        while queue or self._active_slots():
            self._admit_free(queue)
            active = self._active_slots()
            if not active:
                continue
            last, act, n_out, limit = self._slot_state()
            sample, keys, temp, top_k, top_p = self._sampling_state()
            kw = {}
            if sample:
                kw = dict(keys=jnp.asarray(keys), temp=jnp.asarray(temp),
                          top_k=jnp.asarray(top_k), top_p=jnp.asarray(top_p),
                          sample=True)
            live = max(len(self.slot_req[i].tokens) + len(self.slot_req[i].out_tokens)
                       for i in active)
            horizon = min(self.max_len, self._bucket_len(live + self.decode_block))
            if self.paged:
                table = self._prepare_paged(active, horizon)
                self.cache, act_f, toks, valid = self._decode_k_paged(
                    self.params, self.cache, table, jnp.asarray(last),
                    jnp.asarray(act), jnp.asarray(n_out), jnp.asarray(limit),
                    **kw)
            else:
                self.cache, act_f, toks, valid = self._decode_k(
                    horizon, self.params, self.cache, jnp.asarray(last),
                    jnp.asarray(act), jnp.asarray(n_out), jnp.asarray(limit),
                    **kw)
            self.n_decode_calls += 1
            self.n_decode_steps += self.decode_block
            toks = np.asarray(toks)
            valid = np.asarray(valid)
            act_f = np.asarray(act_f)
            for i in active:
                req = self.slot_req[i]
                block = [int(t) for t in toks[valid[:, i], i]]
                req.out_tokens.extend(block)
                if not act_f[i]:
                    self._retire(i)
                if req.on_tokens is not None:
                    req.on_tokens(block, req.done)
        return requests

    def serve_stepwise(self, requests: list[Request]) -> list[Request]:
        """Pre-fusion reference driver: per-request prefill admission and one
        host round-trip (dispatch + argmax sync) per generated token.  Kept
        for the fused-path parity tests and as the baseline leg of
        ``benchmarks/engine_decode.py``; outputs are bit-identical to
        :meth:`serve` under greedy sampling.  Contiguous-layout only — it is
        the *reference*, and paging it would leave no fixed point to test
        against."""
        if self.paged:
            raise RuntimeError("serve_stepwise is the contiguous parity "
                               "reference; use serve() on a paged engine")
        queue = list(requests)
        while queue or self._active_slots():
            for slot in range(self.max_slots):
                if self.slot_req[slot] is None and queue:
                    self._admit_batch([queue.pop(0)], [slot])
            active = self._active_slots()
            if not active:
                continue
            # lockstep decode across all slots (inactive slots decode garbage
            # into their own slot state; they are reset at admission)
            last = np.full((self.max_slots, 1), self.pad_id, dtype=np.int32)
            for i in active:
                last[i, 0] = self.slot_req[i].out_tokens[-1]
            logits, self.cache = self._decode(self.params, jnp.asarray(last), self.cache)
            self.n_decode_calls += 1
            self.n_decode_steps += 1
            sample, keys, temp, top_k, top_p = self._sampling_state()
            if sample:
                n_arr = np.zeros(self.max_slots, dtype=np.int32)
                for i in active:
                    n_arr[i] = len(self.slot_req[i].out_tokens)
                nxt = np.asarray(self._pick_tokens(
                    logits[:, 0], jnp.asarray(keys), jnp.asarray(n_arr),
                    jnp.asarray(temp), jnp.asarray(top_k),
                    jnp.asarray(top_p)))
            else:
                nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for i in active:
                req = self.slot_req[i]
                req.out_tokens.append(int(nxt[i]))
                total_len = len(req.tokens) + len(req.out_tokens)
                if (int(nxt[i]) == self.eos_id
                        or len(req.out_tokens) >= self._gen_of(req).max_new
                        or total_len >= self.max_len - 1):
                    self._retire(i)
                if req.on_tokens is not None:
                    req.on_tokens([int(nxt[i])], req.done)
        return requests

    # convenience --------------------------------------------------------
    def generate_text(self, prompts: list[str], max_new: int = 32,
                      gen: Optional[GenerationConfig] = None) -> list[str]:
        """``gen`` supersedes the legacy ``max_new`` kwarg when given (the
        deprecation shim keeps ``max_new=`` callers bit-identical)."""
        if gen is not None:
            max_new = gen.max_new
        reqs = [Request(rid=i, tokens=self.tok.encode(p), max_new=max_new,
                        gen=gen)
                for i, p in enumerate(prompts)]
        self.serve(reqs)
        outs = []
        for r in reqs:
            ids = r.out_tokens
            if self.eos_id in ids:
                ids = ids[: ids.index(self.eos_id)]
            outs.append(self.tok.decode(ids))
        return outs
