"""Prometheus-style metrics for the serving plane, stdlib-only.

:class:`MetricsRegistry` holds counter/gauge/histogram families with label
support and renders the Prometheus text exposition format (version 0.0.4 —
``# HELP``/``# TYPE`` headers, ``name{label="value"} number`` samples,
cumulative ``_bucket{le=...}`` histograms).  No client library: the format is
a dozen lines of string assembly, and ``requirements-ci.txt`` stays lean.

:func:`bind_server_metrics` wires a registry to a running
:class:`repro.serving.online.OnlineRobatchServer` through its ``on_window`` /
``on_complete`` hooks, translating the serving plane's existing signals —
window accounting, per-member capacity pressure, breaker transitions, replica
counts and pending async builds, paged-KV occupancy, budget spend — into
scrapeable families.  The HTTP front-end (:mod:`repro.http.server`) adds its
own request/latency families on top and serves ``registry.render()`` at
``GET /metrics``.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "bind_server_metrics", "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0)


def _escape(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class _Family:
    """One metric family: a name, a help line, and children keyed by label
    values.  A family with no ``labelnames`` has exactly one anonymous child
    and the family itself proxies its methods."""

    kind = "untyped"

    def __init__(self, name: str, help_text: str,
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help_text
        self.labelnames = tuple(labelnames)
        self._children: dict = {}
        self._lock = threading.Lock()

    def labels(self, **labels):
        assert set(labels) == set(self.labelnames), \
            f"{self.name}: expected labels {self.labelnames}, got {tuple(labels)}"
        key = tuple(str(labels[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._child()
            return child

    def _default(self):
        return self.labels() if not self.labelnames else None

    def _label_str(self, key: tuple) -> str:
        if not key:
            return ""
        pairs = ",".join(f'{n}="{_escape(v)}"'
                          for n, v in zip(self.labelnames, key))
        return "{" + pairs + "}"

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        with self._lock:
            children = sorted(self._children.items())
        for key, child in children:
            lines.extend(child.render_samples(self.name, self._label_str(key)))
        return lines


class _CounterChild:
    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        assert amount >= 0, "counters only go up"
        self.value += amount

    def render_samples(self, name: str, labels: str) -> list[str]:
        return [f"{name}{labels} {_fmt(self.value)}"]


class Counter(_Family):
    kind = "counter"
    _child = _CounterChild

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)


class _GaugeChild:
    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def render_samples(self, name: str, labels: str) -> list[str]:
        return [f"{name}{labels} {_fmt(self.value)}"]


class Gauge(_Family):
    kind = "gauge"
    _child = _GaugeChild

    def set(self, value: float) -> None:
        self._default().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)


class _HistogramChild:
    def __init__(self, buckets: Sequence[float]):
        self.buckets = buckets
        self.counts = [0] * len(buckets)
        self.total = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.total += 1
        i = bisect_left(self.buckets, v)
        if i < len(self.counts):
            self.counts[i] += 1

    def render_samples(self, name: str, labels: str) -> list[str]:
        # cumulative le-buckets, as Prometheus requires
        base = labels[1:-1] if labels else ""
        lines, cum = [], 0
        for le, c in zip(self.buckets, self.counts):
            cum += c
            sep = "," if base else ""
            lines.append(f'{name}_bucket{{{base}{sep}le="{_fmt(le)}"}} {cum}')
        sep = "," if base else ""
        lines.append(f'{name}_bucket{{{base}{sep}le="+Inf"}} {self.total}')
        lines.append(f"{name}_sum{labels} {_fmt(self.sum)}")
        lines.append(f"{name}_count{labels} {self.total}")
        return lines


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name: str, help_text: str,
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help_text, labelnames)
        self.buckets = tuple(sorted(buckets))

    def _child(self):
        return _HistogramChild(self.buckets)

    def observe(self, value: float) -> None:
        self._default().observe(value)


class MetricsRegistry:
    """Named metric families, rendered together at ``GET /metrics``."""

    def __init__(self):
        self._families: "dict[str, _Family]" = {}
        self._lock = threading.Lock()

    def _register(self, cls, name, help_text, labelnames, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = cls(name, help_text,
                                                 labelnames, **kw)
            assert isinstance(fam, cls) and fam.labelnames == tuple(labelnames), \
                f"metric {name} re-registered with a different signature"
            return fam

    def counter(self, name: str, help_text: str,
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help_text, labelnames)

    def gauge(self, name: str, help_text: str,
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help_text, labelnames)

    def histogram(self, name: str, help_text: str,
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help_text, labelnames,
                              buckets=buckets)

    def render(self) -> str:
        with self._lock:
            families = sorted(self._families.items())
        lines: list[str] = []
        for _, fam in families:
            lines.extend(fam.render())
        return "\n".join(lines) + "\n"


def bind_server_metrics(registry: MetricsRegistry, server,
                        prefix: str = "robatch") -> MetricsRegistry:
    """Populate ``registry`` from a server's existing signals via its
    ``on_window``/``on_complete`` hooks.  Idempotent families (re-binding a
    second server to the same registry reuses them); chains with any hooks
    already installed."""
    names = [m.name for m in server.pool]

    completions = registry.counter(
        f"{prefix}_requests_total", "completed requests by outcome",
        ("outcome",))
    latency = registry.histogram(
        f"{prefix}_request_latency_seconds",
        "request latency (arrival to completion, serving timeline)")
    utility = registry.counter(
        f"{prefix}_utility_sum", "summed judged utility of served requests")
    cost = registry.counter(
        f"{prefix}_cost_dollars_total", "realized billed cost by member",
        ("member",))
    windows = registry.counter(f"{prefix}_windows_total",
                               "scheduling rounds run")
    pending = registry.gauge(f"{prefix}_pending_requests",
                             "queue depth entering the last round")
    late = registry.gauge(f"{prefix}_window_late_seconds",
                          "realtime lateness of the last round")
    spent_g = registry.gauge(f"{prefix}_budget_spent_dollars",
                             "total realized budget spend")
    window_ctr = registry.counter(
        f"{prefix}_window_events_total",
        "per-round accounting events (admitted/deferred/shed/...)", ("event",))
    held = registry.counter(
        f"{prefix}_capacity_held_total",
        "queries held out by a member's replica caps", ("member",))
    packed = registry.counter(
        f"{prefix}_capacity_packed_total",
        "queries re-packed into wider batches by a member's caps", ("member",))
    pressure = registry.gauge(
        f"{prefix}_member_pressure",
        "cumulative capacity pressure (held+packed queries) per member",
        ("member",))
    breaker_state = registry.gauge(
        f"{prefix}_breaker_state",
        "circuit breaker state per member (0=closed 1=half-open 2=open)",
        ("member",))
    breaker_trips = registry.counter(
        f"{prefix}_breaker_trips_total", "breaker close->open transitions",
        ("member",))
    replicas = registry.gauge(f"{prefix}_member_replicas",
                              "active replicas per member", ("member",))
    scale_events = registry.counter(
        f"{prefix}_scale_events_total",
        "autoscale actions fired, by member and direction", ("member", "direction"))
    pending_builds = registry.gauge(
        f"{prefix}_member_pending_builds",
        "async replica builds launched but not yet attached", ("member",))
    kv_pages = registry.gauge(
        f"{prefix}_kv_pages", "paged-KV occupancy per member",
        ("member", "kind"))
    cache_entries = registry.gauge(f"{prefix}_cache_entries",
                                   "response cache live entries")
    cache_hits = registry.gauge(f"{prefix}_cache_hits_total",
                                "response cache hits")
    sem_events = registry.counter(
        f"{prefix}_semcache_events_total",
        "semantic cache events (hit/miss/insert/evict/expire)", ("event",))
    sem_entries = registry.gauge(f"{prefix}_semcache_entries",
                                 "semantic cache live entries")
    sem_bytes = registry.gauge(f"{prefix}_semcache_bytes",
                               "semantic cache stored answer bytes")
    sem_loss = registry.gauge(
        f"{prefix}_semcache_utility_loss_sum",
        "summed calibrated utility-loss estimate u·ε(sim) over hits")
    sem_sim = registry.histogram(
        f"{prefix}_semcache_hit_similarity",
        "cosine similarity of semantic cache hits",
        buckets=(0.80, 0.84, 0.88, 0.90, 0.92, 0.94, 0.96, 0.98, 0.99, 1.0))

    from repro.serving.fault import CircuitState
    state_code = {CircuitState.CLOSED: 0, CircuitState.HALF_OPEN: 1,
                  CircuitState.OPEN: 2}
    # pressure gauges surface even before any pressure accrues — a scrape
    # right after boot must already carry one sample per member
    own_pressure = {k: 0 for k in range(len(names))}
    for name in names:
        pressure.labels(member=name).set(0)
        breaker_trips.labels(member=name)     # zero-valued child
    trips_seen = [br.n_trips for br in server.breakers]

    # semantic-cache counters are cumulative on the cache object — scrape
    # deltas per window so a re-bound registry never double-counts
    sem_seen = {"hit": 0, "miss": 0, "insert": 0, "evict": 0, "expire": 0}

    def on_complete(req) -> None:
        if req.dropped:
            completions.labels(outcome="dropped").inc()
        else:
            outcome = ("sem_hit" if req.sem_hit
                       else "cache_hit" if req.cache_hit else "served")
            completions.labels(outcome=outcome).inc()
            latency.observe(max(0.0, req.latency))
            utility.inc(float(req.utility or 0.0))
            if req.sem_hit:
                sem_sim.observe(req.sem_sim)
        if req.model is not None and req.cost:
            cost.labels(member=names[req.model]).inc(req.cost)

    def on_window(rep) -> None:
        windows.inc()
        pending.set(rep.n_pending)
        late.set(rep.late_s)
        spent_g.set(server.bucket.total_spent)
        cache_entries.set(len(server.cache))
        cache_hits.set(server.cache.hits)
        sc = getattr(server, "semcache", None)
        if sc is not None:
            sem_entries.set(len(sc))
            sem_bytes.set(sc.total_bytes)
            sem_loss.set(sc.utility_loss)
            for event, total in (("hit", sc.hits), ("miss", sc.misses),
                                 ("insert", sc.insertions),
                                 ("evict", sc.evictions),
                                 ("expire", sc.expirations)):
                if total > sem_seen[event]:
                    sem_events.labels(event=event).inc(total - sem_seen[event])
                    sem_seen[event] = total
        for event, n in (("admitted", rep.n_admitted),
                         ("deferred", rep.n_deferred),
                         ("shed", rep.n_shed), ("failed", rep.n_failed),
                         ("coalesced", rep.n_coalesced),
                         ("groups", rep.n_groups)):
            if n:
                window_ctr.labels(event=event).inc(n)
        for k, n in rep.held_by_member:
            held.labels(member=names[k]).inc(n)
            own_pressure[k] += n
        for k, n in rep.packed_by_member:
            packed.labels(member=names[k]).inc(n)
            own_pressure[k] += n
        # satellite: Autoscaler.pressure_by_member as per-member gauges —
        # the autoscaler's own accumulation when one is attached, the same
        # held+packed sum accumulated here when the pool is fixed
        by_member = (server.autoscaler.pressure_by_member
                     if server.autoscaler is not None else own_pressure)
        for k, n in by_member.items():
            pressure.labels(member=names[k]).set(n)
        for k, (br, name) in enumerate(zip(server.breakers, names)):
            breaker_state.labels(member=name).set(state_code[br.state])
            if br.n_trips > trips_seen[k]:
                breaker_trips.labels(member=name).inc(br.n_trips - trips_seen[k])
                trips_seen[k] = br.n_trips
        for member, from_n, to_n in getattr(rep, "scale_events", ()):
            direction = "up" if to_n > from_n else "down"
            scale_events.labels(member=member, direction=direction).inc()
        for k, n in enumerate(rep.replica_counts):
            replicas.labels(member=names[k]).set(n)
        for name, m in zip(names, server.pool):
            nb = getattr(m, "n_pending_builds", None)
            if nb is not None:
                pending_builds.labels(member=name).set(int(nb))
        for k, used, shared, forks in rep.kv_pages:
            kv_pages.labels(member=names[k], kind="used").set(used)
            kv_pages.labels(member=names[k], kind="shared").set(shared)
            kv_pages.labels(member=names[k], kind="cow_forks").set(forks)

    def chain(old, new):
        if old is None:
            return new

        def both(arg):
            old(arg)
            new(arg)
        return both

    server.on_complete = chain(server.on_complete, on_complete)
    server.on_window = chain(server.on_window, on_window)
    return registry


def make_registry(server=None, prefix: str = "robatch",
                  registry: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Convenience: a fresh registry, optionally pre-bound to a server."""
    registry = registry if registry is not None else MetricsRegistry()
    if server is not None:
        bind_server_metrics(registry, server, prefix=prefix)
    return registry
