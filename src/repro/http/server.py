"""OpenAI-compatible HTTP front-end over the online serving plane.

Stdlib-only by design (``http.server.ThreadingHTTPServer`` — no FastAPI or
uvicorn, ``requirements-ci.txt`` stays lean).  One :class:`HttpFrontend`
fronts one :class:`repro.serving.online.OnlineRobatchServer`:

* a **serving-loop thread** runs :meth:`~repro.serving.online.
  OnlineRobatchServer.run_bridge` — one scheduling round per wall-clock
  window, requests arriving concurrently from handler threads;
* **handler threads** (one per connection) translate the wire protocol:
  ``POST /v1/chat/completions`` submits through the live ingress bridge
  (``submit_request``) and either blocks on the request's ``done_event``
  (non-streamed) or relays its :class:`~repro.serving.online.StreamSink`
  events as SSE ``chat.completion.chunk`` frames (streamed — deltas arrive at
  the engine's ``decode_block`` cadence via the batch-prompt demultiplexer);
* ``GET /v1/models`` lists pool members with per-token prices,
  ``GET /healthz`` reports breaker state and replica availability, and
  ``GET /metrics`` renders the bound :class:`repro.http.metrics.
  MetricsRegistry` in Prometheus text exposition format.

Streamed responses are sent with ``Connection: close`` framing (the client
reads until EOF), which every SSE consumer — curl, the OpenAI SDKs, browsers
— handles; non-streamed responses carry a normal ``Content-Length``.
"""
from __future__ import annotations

import json
import queue
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.http.metrics import MetricsRegistry, bind_server_metrics
from repro.http.protocol import (SSE_DONE, ApiError, chunk_frame,
                                 completion_response, finish_frame,
                                 models_response, parse_chat_body,
                                 resolve_query_idx, role_frame, sse_event)
from repro.serving.fault import CircuitState

__all__ = ["HttpFrontend"]


def _pool_text_index(pool) -> dict:
    """Exact query-text -> workload index map from any TextTask the pool's
    members (or their replicas) carry; simulated pools yield an empty map."""
    for member in pool:
        task = getattr(member, "task", None)
        if task is None:
            task = getattr(getattr(member, "replicas", [None])[0], "task", None)
        if task is not None and getattr(task, "queries", None) is not None:
            return {str(q): i for i, q in enumerate(task.queries)}
    return {}


class _HttpServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    frontend: "HttpFrontend"


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server: _HttpServer

    # ------------------------------------------------------------- plumbing
    def log_message(self, fmt, *args):   # noqa: A002 — stdlib signature
        if self.server.frontend.verbose:
            super().log_message(fmt, *args)

    def _observe(self, path: str, code: int) -> None:
        fe = self.server.frontend
        fe.n_http_requests += 1
        if fe._http_requests is not None:
            fe._http_requests.labels(path=path, code=str(code)).inc()

    def _send_json(self, code: int, payload: dict, path: str) -> None:
        body = json.dumps(payload, indent=1).encode() + b"\n"
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        self._observe(path, code)

    def _send_text(self, code: int, text: str, path: str,
                   content_type: str) -> None:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        self._observe(path, code)

    # ------------------------------------------------------------ GET routes
    def do_GET(self):   # noqa: N802 — stdlib handler name
        fe = self.server.frontend
        path = self.path.split("?", 1)[0]
        try:
            if path == "/v1/models":
                self._send_json(200, models_response(fe.server.pool), path)
            elif path == "/healthz":
                self._send_json(200, fe.health(), path)
            elif path == "/metrics":
                self._send_text(200, fe.metrics.render(), path,
                                "text/plain; version=0.0.4; charset=utf-8")
            else:
                self._send_json(404, ApiError(404, f"no route {path}").body(),
                                path)
        except (BrokenPipeError, ConnectionResetError):
            pass

    # ----------------------------------------------------------- POST routes
    def do_POST(self):  # noqa: N802 — stdlib handler name
        fe = self.server.frontend
        path = self.path.split("?", 1)[0]
        if path != "/v1/chat/completions":
            self._send_json(404, ApiError(404, f"no route {path}").body(), path)
            return
        t0 = time.perf_counter()
        try:
            length = int(self.headers.get("Content-Length") or 0)
            parsed = parse_chat_body(self.rfile.read(length))
            q = resolve_query_idx(parsed, fe.universe, fe.text_index)
            if parsed["stream"]:
                self._stream_completion(q, path, t0, parsed["gen"])
            else:
                self._unary_completion(q, path, t0, parsed["gen"])
        except ApiError as e:
            self._send_json(e.status, e.body(), path)
        except (BrokenPipeError, ConnectionResetError):
            pass
        except Exception as e:   # noqa: BLE001 — wire boundary
            self._send_json(500, ApiError(500, f"internal error: {e}",
                                          "server_error").body(), path)

    def _model_name(self, req) -> Optional[str]:
        fe = self.server.frontend
        return fe.server.pool[req.model].name if req.model is not None else None

    def _unary_completion(self, q: int, path: str, t0: float,
                          gen=None) -> None:
        fe = self.server.frontend
        req = fe.server.submit_request(q, stream=False, gen=gen)
        if not req.done_event.wait(fe.request_timeout_s):
            raise ApiError(504, "request timed out in the serving queue",
                           "timeout_error")
        if req.dropped:
            raise ApiError(429, "request shed (budget or reroute limit)",
                           "rate_limit_error")
        body = completion_response(req, self._model_name(req), fe.server.wl)
        self._send_json(200, body, path)
        if fe._http_latency is not None:
            fe._http_latency.labels(mode="unary").observe(time.perf_counter() - t0)

    def _stream_completion(self, q: int, path: str, t0: float,
                           gen=None) -> None:
        fe = self.server.frontend
        req = fe.server.submit_request(q, stream=True, gen=gen)
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True
        self._observe(path, 200)
        first_chunk_at: Optional[float] = None
        try:
            self.wfile.write(role_frame(req))
            self.wfile.flush()
            deadline = time.perf_counter() + fe.request_timeout_s
            while True:
                timeout = max(0.0, deadline - time.perf_counter())
                try:
                    kind, payload = req.stream.q.get(timeout=timeout)
                except queue.Empty:
                    self.wfile.write(sse_event(
                        ApiError(504, "stream timed out", "timeout_error").body()))
                    break
                if kind == "delta":
                    if first_chunk_at is None:
                        first_chunk_at = time.perf_counter()
                    self.wfile.write(chunk_frame(req, payload))
                    self.wfile.flush()
                elif kind == "error":
                    self.wfile.write(sse_event(
                        ApiError(429, payload, "rate_limit_error").body()))
                else:       # ("done", None): the seal — emit the final frame
                    self.wfile.write(finish_frame(req, self._model_name(req),
                                                  fe.server.wl))
                    break
            self.wfile.write(SSE_DONE)
            self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            return          # client went away mid-stream; serving completes anyway
        if fe._http_latency is not None:
            now = time.perf_counter()
            fe._http_latency.labels(mode="stream").observe(now - t0)
            if first_chunk_at is not None and fe._http_ttfc is not None:
                fe._http_ttfc.observe(first_chunk_at - t0)


class HttpFrontend:
    """Threaded HTTP facade over one online server; see the module docstring.

    ``port=0`` binds an ephemeral port — read the actual one from
    :attr:`port` after :meth:`start` (the CLI prints it).  ``universe``
    defaults to the workload's test split: the index space chat requests
    resolve into.
    """

    def __init__(self, server, *, host: str = "127.0.0.1", port: int = 0,
                 metrics: Optional[MetricsRegistry] = None, universe=None,
                 request_timeout_s: float = 120.0, verbose: bool = False):
        self.server = server
        self.host = host
        self.universe = (server.wl.subset_indices("test")
                         if universe is None else universe)
        self.text_index = _pool_text_index(server.pool)
        self.request_timeout_s = float(request_timeout_s)
        self.verbose = verbose
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        bind_server_metrics(self.metrics, server)
        self._http_requests = self.metrics.counter(
            "robatch_http_requests_total",
            "HTTP requests by path and status code", ("path", "code"))
        self._http_latency = self.metrics.histogram(
            "robatch_http_request_seconds",
            "wall time per HTTP completion request", ("mode",))
        self._http_ttfc = self.metrics.histogram(
            "robatch_http_time_to_first_chunk_seconds",
            "wall time from request to first streamed content chunk")
        self.n_http_requests = 0
        self._httpd = _HttpServer((host, port), _Handler)
        self._httpd.frontend = self
        self._stop = threading.Event()
        self._loop: Optional[threading.Thread] = None
        self._serve: Optional[threading.Thread] = None
        self.threads_leaked: list[str] = []   # set by stop(); [] == clean exit

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def health(self) -> dict:
        srv = self.server
        members = []
        degraded = False
        for m, br in zip(srv.pool, srv.breakers):
            n_rep = int(getattr(m, "n_replicas", 1))
            avail_fn = getattr(m, "n_available", None)
            avail = int(avail_fn()) if avail_fn is not None else n_rep
            state = br.state.name.lower()
            if br.state != CircuitState.CLOSED or avail < n_rep:
                degraded = True
            members.append({"name": m.name, "breaker": state,
                            "replicas": n_rep, "available": avail,
                            "pending_builds": int(getattr(m, "n_pending_builds", 0))})
        return {"status": "degraded" if degraded else "ok",
                "pending": len(srv.pending), "windows": len(srv.windows),
                "completed": len(srv.completed),
                "last_window": srv.windows[-1].summary() if srv.windows else None,
                "members": members}

    # --------------------------------------------------------------- lifecycle
    def start(self) -> "HttpFrontend":
        assert self._loop is None, "frontend already started"
        self._loop = threading.Thread(target=self.server.run_bridge,
                                      args=(self._stop,), daemon=True,
                                      name="robatch-serving-loop")
        self._serve = threading.Thread(target=self._httpd.serve_forever,
                                       daemon=True, name="robatch-http")
        self._loop.start()
        self._serve.start()
        return self

    def stop(self, timeout_s: float = 10.0) -> None:
        """Graceful shutdown: stop accepting connections, wake the serving
        loop (which drains pending requests so no waiter hangs), join both
        threads.  A thread that outlives its join lands in
        :attr:`threads_leaked` (and a stderr warning) instead of being
        silently abandoned — the launcher's shutdown marker reports it."""
        self._httpd.shutdown()
        self._httpd.server_close()
        self._stop.set()
        if self._serve is not None:
            self._serve.join(timeout=timeout_s)
        if self._loop is not None:
            self._loop.join(timeout=timeout_s)
        self.threads_leaked = [t.name for t in (self._serve, self._loop)
                               if t is not None and t.is_alive()]
        if self.threads_leaked:
            print(f"HttpFrontend.stop: WARNING threads still alive "
                  f"{timeout_s}s after shutdown: {self.threads_leaked}",
                  file=sys.stderr)
        self.server.close()

    def __enter__(self) -> "HttpFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
