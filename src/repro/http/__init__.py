"""HTTP front-end for the RoBatch serving plane: an OpenAI-compatible wire
surface (``/v1/chat/completions`` with SSE streaming, ``/v1/models``), health
(``/healthz``) and Prometheus metrics (``/metrics``) — stdlib-only.

Entry points::

    from repro.http import HttpFrontend, MetricsRegistry

    fe = HttpFrontend(online_server, port=0).start()   # or Gateway.serve_http
    ...
    fe.stop()

or from the CLI: ``python -m repro.launch.serve http --port 8080``.
"""
from repro.http.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                                bind_server_metrics)
from repro.http.server import HttpFrontend

__all__ = ["HttpFrontend", "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "bind_server_metrics"]
