"""OpenAI chat-completions wire schema and SSE framing, stdlib-only.

The front-end speaks the `/v1/chat/completions` request/response shape any
OpenAI SDK emits; routing metadata the schema has no slot for (which pool
member served, the judged utility, the billed cost share, cache/batch state)
rides in a ``robatch`` extension object on every response.

Query resolution maps a chat message onto the workload the gateway was fitted
on.  The serving plane routes by *workload index* (the router embedding, cost
columns and calibrations are all indexed), so free-text ingress must land on
an index; the ladder, first match wins:

1. an explicit integer ``query_idx`` field in the request body,
2. exact text match against the pool's :class:`repro.serving.pool.TextTask`
   queries (real engine pools),
3. ``#N`` / ``qN`` in the message content — an explicit index reference,
4. a stable content hash onto the serving universe — arbitrary curl text
   exercises the full plane deterministically.

SSE framing follows the OpenAI streaming contract: ``data: {chunk}\\n\\n``
frames with ``object: chat.completion.chunk``, a first frame carrying the
assistant role, one frame per content delta, a terminal frame with
``finish_reason``, then the literal ``data: [DONE]`` sentinel.
"""
from __future__ import annotations

import json
import re
import zlib
from typing import Optional

__all__ = ["ApiError", "parse_chat_body", "resolve_query_idx",
           "completion_response", "chunk_frame", "role_frame", "finish_frame",
           "sse_event", "SSE_DONE", "models_response", "usage_for"]

SSE_DONE = b"data: [DONE]\n\n"

_IDX_RE = re.compile(r"^\s*(?:#|q)?(\d+)\s*$", re.IGNORECASE)


class ApiError(Exception):
    """Maps to an OpenAI-style error envelope with an HTTP status."""

    def __init__(self, status: int, message: str, err_type: str = "invalid_request_error"):
        super().__init__(message)
        self.status = status
        self.err_type = err_type

    def body(self) -> dict:
        return {"error": {"message": str(self), "type": self.err_type,
                          "code": self.status}}


def parse_chat_body(raw: bytes) -> dict:
    """Decode and structurally validate a chat-completions request body;
    returns ``{"content", "stream", "model", "query_idx"}``."""
    try:
        body = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ApiError(400, f"request body is not valid JSON: {e}")
    if not isinstance(body, dict):
        raise ApiError(400, "request body must be a JSON object")
    messages = body.get("messages")
    if not isinstance(messages, list) or not messages:
        raise ApiError(400, "'messages' must be a non-empty array")
    content: Optional[str] = None
    for msg in reversed(messages):
        if isinstance(msg, dict) and msg.get("role") == "user":
            content = msg.get("content")
            break
    if not isinstance(content, str) or not content:
        raise ApiError(400, "need at least one user message with string content")
    query_idx = body.get("query_idx")
    if query_idx is not None and not isinstance(query_idx, int):
        raise ApiError(400, "'query_idx' must be an integer when present")
    return {"content": content, "stream": bool(body.get("stream", False)),
            "model": body.get("model"), "query_idx": query_idx}


def resolve_query_idx(parsed: dict, universe, text_index: dict) -> int:
    """The resolution ladder above; ``universe`` is the serving index array,
    ``text_index`` maps exact TextTask query strings to workload indices."""
    n = len(universe)
    if n == 0:
        raise ApiError(503, "server has no serving universe", "server_error")
    if parsed["query_idx"] is not None:
        q = parsed["query_idx"]
        if not 0 <= q < n:
            raise ApiError(400, f"query_idx {q} outside the serving universe "
                                f"[0, {n})")
        return int(universe[q])
    content = parsed["content"]
    hit = text_index.get(content)
    if hit is None:
        hit = text_index.get(content.strip())
    if hit is not None:
        return int(hit)
    m = _IDX_RE.match(content)
    if m and int(m.group(1)) < n:
        return int(universe[int(m.group(1))])
    return int(universe[zlib.crc32(content.strip().encode("utf-8")) % n])


def usage_for(wl, query_idx: int) -> dict:
    """Token accounting from the workload's calibrated counts (the serving
    plane bills batch-amortized tokens; this is the per-query view)."""
    prompt = int(wl.sys_tokens + wl.in_tokens[query_idx])
    completion = int(wl.out_tokens[query_idx])
    return {"prompt_tokens": prompt, "completion_tokens": completion,
            "total_tokens": prompt + completion}


def _robatch_ext(req, model_name: Optional[str]) -> dict:
    return {"query_idx": req.query_idx, "model_idx": req.model,
            "model": model_name, "batch": req.batch,
            "utility": req.utility, "cost": req.cost,
            "cache_hit": req.cache_hit, "n_reroutes": req.n_reroutes,
            "latency_s": round(req.latency, 6)}


def completion_response(req, model_name: Optional[str], wl,
                        created: int = 0) -> dict:
    """Non-streamed ``chat.completion`` body for a completed OnlineRequest.

    ``id`` is deterministic in the request id and ``created`` defaults to 0:
    responses are bit-comparable across serving paths and runs (the parity
    guarantee the tests pin); a wall timestamp would be the only nondeterminism.
    """
    return {
        "id": f"chatcmpl-{req.rid}",
        "object": "chat.completion",
        "created": created,
        "model": model_name or "robatch",
        "choices": [{
            "index": 0,
            "message": {"role": "assistant", "content": req.content or ""},
            "finish_reason": "stop",
        }],
        "usage": usage_for(wl, req.query_idx),
        "robatch": _robatch_ext(req, model_name),
    }


def sse_event(payload: dict) -> bytes:
    return b"data: " + json.dumps(payload, separators=(",", ":")).encode() + b"\n\n"


def _chunk(req, model_name: Optional[str], delta: dict,
           finish_reason: Optional[str], created: int = 0) -> dict:
    return {
        "id": f"chatcmpl-{req.rid}",
        "object": "chat.completion.chunk",
        "created": created,
        "model": model_name or "robatch",
        "choices": [{"index": 0, "delta": delta,
                     "finish_reason": finish_reason}],
    }


def role_frame(req, model_name: Optional[str] = None) -> bytes:
    return sse_event(_chunk(req, model_name, {"role": "assistant"}, None))


def chunk_frame(req, delta_text: str, model_name: Optional[str] = None) -> bytes:
    return sse_event(_chunk(req, model_name, {"content": delta_text}, None))


def finish_frame(req, model_name: Optional[str], wl) -> bytes:
    body = _chunk(req, model_name, {}, "stop")
    body["usage"] = usage_for(wl, req.query_idx)
    body["robatch"] = _robatch_ext(req, model_name)
    return sse_event(body)


def models_response(pool) -> dict:
    """``GET /v1/models``: pool members with their per-token prices."""
    return {"object": "list", "data": [{
        "id": m.name, "object": "model", "owned_by": "robatch",
        "context_len": int(m.context_len),
        "pricing": {"input_per_1m_tokens": float(m.c_in),
                    "output_per_1m_tokens": float(m.c_out)},
        "replicas": int(getattr(m, "n_replicas", 1)),
    } for m in pool]}
