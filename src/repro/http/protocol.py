"""OpenAI chat-completions wire schema and SSE framing, stdlib-only.

The front-end speaks the `/v1/chat/completions` request/response shape any
OpenAI SDK emits; routing metadata the schema has no slot for (which pool
member served, the judged utility, the billed cost share, cache/batch state)
rides in a ``robatch`` extension object on every response.

Query resolution maps a chat message onto the workload the gateway was fitted
on.  The serving plane routes by *workload index* (the router embedding, cost
columns and calibrations are all indexed), so free-text ingress must land on
an index; the ladder, first match wins:

1. an explicit integer ``query_idx`` field in the request body,
2. exact text match against the pool's :class:`repro.serving.pool.TextTask`
   queries (real engine pools),
3. ``#N`` / ``qN`` in the message content — an explicit index reference,
4. a stable content hash onto the serving universe — arbitrary curl text
   exercises the full plane deterministically.

SSE framing follows the OpenAI streaming contract: ``data: {chunk}\\n\\n``
frames with ``object: chat.completion.chunk``, a first frame carrying the
assistant role, one frame per content delta, a terminal frame with
``finish_reason``, then the literal ``data: [DONE]`` sentinel.
"""
from __future__ import annotations

import json
import re
import zlib
from typing import Optional

__all__ = ["ApiError", "parse_chat_body", "resolve_query_idx",
           "completion_response", "chunk_frame", "role_frame", "finish_frame",
           "sse_event", "SSE_DONE", "models_response", "usage_for"]

SSE_DONE = b"data: [DONE]\n\n"

_IDX_RE = re.compile(r"^\s*(?:#|q)?(\d+)\s*$", re.IGNORECASE)


class ApiError(Exception):
    """Maps to an OpenAI-style error envelope with an HTTP status."""

    def __init__(self, status: int, message: str, err_type: str = "invalid_request_error"):
        super().__init__(message)
        self.status = status
        self.err_type = err_type

    def body(self) -> dict:
        return {"error": {"message": str(self), "type": self.err_type,
                          "code": self.status}}


# OpenAI chat-completions fields this plane cannot honor: the batch-prompt
# engine produces exactly one choice per query and exposes no token-level
# logprobs, and penalty/bias knobs have no analogue in the fused sampler.
# Sending one is a structured 400, not a silent ignore (docs/architecture.md
# documents the supported subset).
_UNSUPPORTED_FIELDS = ("logprobs", "top_logprobs", "logit_bias", "tools",
                      "tool_choice", "functions", "function_call", "stop",
                      "presence_penalty", "frequency_penalty")


def _number(body: dict, key: str, lo: float, hi: float, default):
    v = body.get(key)
    if v is None:
        return default
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise ApiError(400, f"'{key}' must be a number")
    if not lo <= float(v) <= hi:
        raise ApiError(400, f"'{key}' must be in [{lo}, {hi}], got {v}")
    return float(v)


def parse_chat_body(raw: bytes) -> dict:
    """Decode and structurally validate a chat-completions request body;
    returns ``{"content", "stream", "model", "query_idx", "gen"}`` where
    ``gen`` is a :class:`repro.serving.generation.GenerationConfig` when the
    request carries any sampling field (``temperature``/``top_p``/``seed``/
    ``max_tokens``) and ``None`` otherwise (server-default generation).
    Unsupported OpenAI fields are rejected with a structured 400."""
    try:
        body = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ApiError(400, f"request body is not valid JSON: {e}")
    if not isinstance(body, dict):
        raise ApiError(400, "request body must be a JSON object")
    for key in _UNSUPPORTED_FIELDS:
        if body.get(key) is not None:
            raise ApiError(400, f"'{key}' is not supported by this server; "
                                "see docs/architecture.md for the supported "
                                "request subset", "unsupported_field_error")
    n = body.get("n")
    if n is not None and n != 1:
        raise ApiError(400, "'n' must be 1: the batch-prompt plane returns "
                            "exactly one choice per query",
                       "unsupported_field_error")
    messages = body.get("messages")
    if not isinstance(messages, list) or not messages:
        raise ApiError(400, "'messages' must be a non-empty array")
    content: Optional[str] = None
    for msg in reversed(messages):
        if isinstance(msg, dict) and msg.get("role") == "user":
            content = msg.get("content")
            break
    if not isinstance(content, str) or not content:
        raise ApiError(400, "need at least one user message with string content")
    query_idx = body.get("query_idx")
    if query_idx is not None and not isinstance(query_idx, int):
        raise ApiError(400, "'query_idx' must be an integer when present")
    temperature = _number(body, "temperature", 0.0, 2.0, None)
    top_p = _number(body, "top_p", 0.0, 1.0, None)
    if top_p == 0.0:
        raise ApiError(400, "'top_p' must be > 0")
    seed = body.get("seed")
    if seed is not None and (isinstance(seed, bool) or not isinstance(seed, int)):
        raise ApiError(400, "'seed' must be an integer when present")
    max_tokens = body.get("max_tokens", body.get("max_completion_tokens"))
    if max_tokens is not None and (isinstance(max_tokens, bool)
                                   or not isinstance(max_tokens, int)
                                   or max_tokens < 1):
        raise ApiError(400, "'max_tokens' must be a positive integer")
    gen = None
    if any(v is not None for v in (temperature, top_p, seed, max_tokens)):
        from repro.serving.generation import GenerationConfig

        gen = GenerationConfig(
            max_new=max_tokens if max_tokens is not None else 32,
            temperature=temperature if temperature is not None else 0.0,
            top_p=top_p if top_p is not None else 1.0,
            seed=seed if seed is not None else 0)
    return {"content": content, "stream": bool(body.get("stream", False)),
            "model": body.get("model"), "query_idx": query_idx, "gen": gen}


def resolve_query_idx(parsed: dict, universe, text_index: dict) -> int:
    """The resolution ladder above; ``universe`` is the serving index array,
    ``text_index`` maps exact TextTask query strings to workload indices."""
    n = len(universe)
    if n == 0:
        raise ApiError(503, "server has no serving universe", "server_error")
    if parsed["query_idx"] is not None:
        q = parsed["query_idx"]
        if not 0 <= q < n:
            raise ApiError(400, f"query_idx {q} outside the serving universe "
                                f"[0, {n})")
        return int(universe[q])
    content = parsed["content"]
    hit = text_index.get(content)
    if hit is None:
        hit = text_index.get(content.strip())
    if hit is not None:
        return int(hit)
    m = _IDX_RE.match(content)
    if m and int(m.group(1)) < n:
        return int(universe[int(m.group(1))])
    return int(universe[zlib.crc32(content.strip().encode("utf-8")) % n])


def usage_for(wl, query_idx: int) -> dict:
    """Token accounting from the workload's calibrated counts (the serving
    plane bills batch-amortized tokens; this is the per-query view)."""
    prompt = int(wl.sys_tokens + wl.in_tokens[query_idx])
    completion = int(wl.out_tokens[query_idx])
    return {"prompt_tokens": prompt, "completion_tokens": completion,
            "total_tokens": prompt + completion}


def _robatch_ext(req, model_name: Optional[str]) -> dict:
    return {"query_idx": req.query_idx, "model_idx": req.model,
            "model": model_name, "batch": req.batch,
            "utility": req.utility, "cost": req.cost,
            "cache_hit": req.cache_hit, "n_reroutes": req.n_reroutes,
            "latency_s": round(req.latency, 6)}


def completion_response(req, model_name: Optional[str], wl,
                        created: int = 0) -> dict:
    """Non-streamed ``chat.completion`` body for a completed OnlineRequest.

    ``id`` is deterministic in the request id and ``created`` defaults to 0:
    responses are bit-comparable across serving paths and runs (the parity
    guarantee the tests pin); a wall timestamp would be the only nondeterminism.
    """
    return {
        "id": f"chatcmpl-{req.rid}",
        "object": "chat.completion",
        "created": created,
        "model": model_name or "robatch",
        "choices": [{
            "index": 0,
            "message": {"role": "assistant", "content": req.content or ""},
            "finish_reason": "stop",
        }],
        "usage": usage_for(wl, req.query_idx),
        "robatch": _robatch_ext(req, model_name),
    }


def sse_event(payload: dict) -> bytes:
    return b"data: " + json.dumps(payload, separators=(",", ":")).encode() + b"\n\n"


def _chunk(req, model_name: Optional[str], delta: dict,
           finish_reason: Optional[str], created: int = 0) -> dict:
    return {
        "id": f"chatcmpl-{req.rid}",
        "object": "chat.completion.chunk",
        "created": created,
        "model": model_name or "robatch",
        "choices": [{"index": 0, "delta": delta,
                     "finish_reason": finish_reason}],
    }


def role_frame(req, model_name: Optional[str] = None) -> bytes:
    return sse_event(_chunk(req, model_name, {"role": "assistant"}, None))


def chunk_frame(req, delta_text: str, model_name: Optional[str] = None) -> bytes:
    return sse_event(_chunk(req, model_name, {"content": delta_text}, None))


def finish_frame(req, model_name: Optional[str], wl) -> bytes:
    body = _chunk(req, model_name, {}, "stop")
    body["usage"] = usage_for(wl, req.query_idx)
    body["robatch"] = _robatch_ext(req, model_name)
    return sse_event(body)


def models_response(pool) -> dict:
    """``GET /v1/models``: pool members with their per-token prices."""
    return {"object": "list", "data": [{
        "id": m.name, "object": "model", "owned_by": "robatch",
        "context_len": int(m.context_len),
        "pricing": {"input_per_1m_tokens": float(m.c_in),
                    "output_per_1m_tokens": float(m.c_out)},
        "replicas": int(getattr(m, "n_replicas", 1)),
    } for m in pool]}
