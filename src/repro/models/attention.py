"""Attention block: GQA, RoPE/M-RoPE, QK-norm, sliding window, cross-attention,
full-sequence (train/prefill) and cached single-token decode paths.

KV caches:
  * global attention — full-length buffer (B, S_max, Hk, hd) + per-sequence
    lengths; with ``kv_seq_shard`` the sequence dim is sharded over the model
    axis and the decode softmax becomes a flash-decode partial reduction.
  * sliding-window attention — ring buffer (B, window, Hk, hd): keys are
    RoPE-rotated at write time, so ring order does not matter.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import Builder, apply_dense, apply_rope, init_dense


def init_attention(b: Builder, cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    H, Hk = cfg.n_heads, cfg.n_kv_heads
    p = {
        "q": init_dense(b, d, H * hd, ("embed", "heads"), bias=cfg.qkv_bias and not cross,
                        bias_axes=("heads",)),
        "k": init_dense(b, d, Hk * hd, ("embed", "kv_heads"), bias=cfg.qkv_bias and not cross,
                        bias_axes=("kv_heads",)),
        "v": init_dense(b, d, Hk * hd, ("embed", "kv_heads"), bias=cfg.qkv_bias and not cross,
                        bias_axes=("kv_heads",)),
        "o": init_dense(b, H * hd, d, ("heads", "embed")),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = b.param((hd,), ("head_dim",), init="ones")
        p["k_norm"] = b.param((hd,), ("head_dim",), init="ones")
    return p


def _heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _qk_normalize(p, q, k, eps):
    def rms(x, scale):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)
    if "q_norm" in p:
        q = rms(q, p["q_norm"].astype(jnp.float32))
        k = rms(k, p["k_norm"].astype(jnp.float32))
    return q, k


def attention_full(p, cfg: ModelConfig, x, positions, *, causal: bool = True,
                   window: Optional[int] = None, kv_source=None, flags=None):
    """Full-sequence attention.  x: (B, S, d); positions: (B, S) or (B, 3, S).

    ``kv_source``: encoder output for cross-attention (no RoPE, not causal).
    Returns (out, (k, v)) — the projected K/V so prefill can fill caches.
    """
    B, S, d = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cross = kv_source is not None
    src = kv_source if cross else x
    fl = flags or {}
    constrain0 = fl.get("constrain")
    q = _heads(apply_dense(p["q"], x), H, hd)
    k = _heads(apply_dense(p["k"], src), Hk, hd)
    v = _heads(apply_dense(p["v"], src), Hk, hd)
    if constrain0 is not None:
        # pin PRODUCTION layout (batch-sharded, seq-replicated) right at the
        # projections — otherwise the seq-sharded cache out-sharding
        # back-propagates into the matmuls and GSPMD gathers per layer
        k = constrain0(k, ("batch", None, "kv_heads", "head_dim"))
        v = constrain0(v, ("batch", None, "kv_heads", "head_dim"))
    if not cross:
        q, k = _qk_normalize(p, q, k, cfg.norm_eps)
        if cfg.rope_type != "none":
            q = apply_rope(q, positions, cfg)
            k = apply_rope(k, positions, cfg)
    fl = flags or {}
    # Pin the attention compute layout: batch-sharded, seq-REPLICATED K/V/Q.
    # Without this, a seq-sharded cache out-sharding propagates backward into
    # K/V production and GSPMD all-gathers (B, S, Hk, hd) per layer; with it,
    # writing a seq-sharded cache is a free local slice (§Perf cell 3).
    constrain = fl.get("constrain")
    if constrain is not None:
        # q additionally shards its SEQ over the model axis when the config
        # enables kv_seq_shard ("kv_seq" rule): each rank computes its own q
        # rows against the full (replicated) K/V — sequence-parallel flash
        # attention without K/V gathers (§Perf cell 3, iteration 2).
        q = constrain(q, ("batch", None, "heads", "head_dim"))
        k = constrain(k, ("batch", None, "kv_heads", "head_dim"))
        v = constrain(v, ("batch", None, "kv_heads", "head_dim"))
    out = ops.flash_attention(
        q, k, v, causal=causal and not cross, window=window,
        q_block=fl.get("q_block", 512), kv_block=fl.get("kv_block", 1024),
        causal_skip=fl.get("causal_skip", True), backend=fl.get("backend"))
    out = apply_dense(p["o"], out.reshape(B, S, H * hd))
    return out, (k, v)


def attention_decode(p, cfg: ModelConfig, x, cache, *, window: Optional[int] = None,
                     kv_source_cache=None, flags=None):
    """One-token decode.  x: (B, 1, d); cache: {"k","v","len"(B,)}.

    With a ring-buffer cache (sliding window) the new KV overwrites slot
    ``len % window``.  Returns (out, new_cache).
    """
    B, _, d = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    fl = flags or {}
    q = _heads(apply_dense(p["q"], x), H, hd)
    if kv_source_cache is not None:                     # cross-attention
        k, v, lengths = kv_source_cache["k"], kv_source_cache["v"], kv_source_cache["len"]
        out = ops.decode_attention(q, k, v, lengths, backend=fl.get("backend"))
        out = apply_dense(p["o"], out.reshape(B, 1, H * hd))
        return out, cache

    k_new = _heads(apply_dense(p["k"], x), Hk, hd)
    v_new = _heads(apply_dense(p["v"], x), Hk, hd)
    q, k_new = _qk_normalize(p, q, k_new, cfg.norm_eps)
    pos = cache["len"]                                  # (B,) current positions
    if cfg.rope_type != "none":
        if cfg.rope_type == "mrope":
            pos3 = jnp.broadcast_to(pos[:, None, None], (B, 3, 1))
            q = apply_rope(q, pos3, cfg)
            k_new = apply_rope(k_new, pos3, cfg)
        else:
            q = apply_rope(q, pos[:, None], cfg)
            k_new = apply_rope(k_new, pos[:, None], cfg)
    table = fl.get("kv_table")
    if table is not None:
        # Paged KV: cache k/v are a (P, page_size, Hk, hd) block pool shared
        # by every slot; ``table`` (B, n_cols) maps each slot's logical pages
        # to physical ones (entries >= P are unmapped — the write drops, the
        # read masks).  Pages being appended into are private (the engine
        # CoW-forks shared ones before dispatch), so no two live slots ever
        # scatter to the same physical location.
        assert window is None, "paged KV cache supports global attention only"
        P, ps = cache["k"].shape[0], cache["k"].shape[1]
        n_cols = table.shape[1]
        page = pos // ps
        phys = jnp.where(page < n_cols,
                         table[jnp.arange(B), jnp.minimum(page, n_cols - 1)], P)
        k_buf = cache["k"].at[phys, pos % ps].set(
            k_new[:, 0].astype(cache["k"].dtype), mode="drop")
        v_buf = cache["v"].at[phys, pos % ps].set(
            v_new[:, 0].astype(cache["v"].dtype), mode="drop")
        out = ops.paged_attention(q, k_buf, v_buf, table, pos + 1,
                                  backend=fl.get("backend"))
        out = apply_dense(p["o"], out.reshape(B, 1, H * hd))
        return out, {"k": k_buf, "v": v_buf, "len": pos + 1}

    S_buf = cache["k"].shape[1]
    write_at = pos % S_buf if window is not None else pos
    bidx = jnp.arange(B)
    k_buf = cache["k"].at[bidx, write_at].set(k_new[:, 0].astype(cache["k"].dtype))
    v_buf = cache["v"].at[bidx, write_at].set(v_new[:, 0].astype(cache["v"].dtype))
    valid = jnp.minimum(pos + 1, S_buf)
    out = ops.decode_attention(q, k_buf, v_buf, valid, backend=fl.get("backend"))
    out = apply_dense(p["o"], out.reshape(B, 1, H * hd))
    new_cache = {"k": k_buf, "v": v_buf, "len": pos + 1}
    return out, new_cache


def attention_span(p, cfg: ModelConfig, x, cache, *, flags=None):
    """S-token decode in one dispatch (speculative-decode verification).

    x: (B, S, d); cache: {"k","v","len"(B,)} with ``len`` the valid length
    *before* the span.  All S new K/V entries are written first, then query
    position ``i`` attends causally to ``len + i + 1`` keys — numerically the
    write-then-masked-read order is indistinguishable from S sequential
    :func:`attention_decode` steps (future keys are masked to exact zeros).
    Global attention only (no ring buffer, no cross).  Returns
    (out, new_cache) with ``len`` advanced by S.
    """
    B, S, d = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    fl = flags or {}
    q = _heads(apply_dense(p["q"], x), H, hd)
    k_new = _heads(apply_dense(p["k"], x), Hk, hd)
    v_new = _heads(apply_dense(p["v"], x), Hk, hd)
    q, k_new = _qk_normalize(p, q, k_new, cfg.norm_eps)
    pos = cache["len"]                                  # (B,) span base
    positions = pos[:, None] + jnp.arange(S)[None, :]   # (B, S)
    if cfg.rope_type != "none":
        if cfg.rope_type == "mrope":
            pos3 = jnp.broadcast_to(positions[:, None, :], (B, 3, S))
            q = apply_rope(q, pos3, cfg)
            k_new = apply_rope(k_new, pos3, cfg)
        else:
            q = apply_rope(q, positions, cfg)
            k_new = apply_rope(k_new, positions, cfg)
    table = fl.get("kv_table")
    if table is not None:
        P, ps = cache["k"].shape[0], cache["k"].shape[1]
        n_cols = table.shape[1]
        page = positions // ps                          # (B, S)
        phys = jnp.where(
            page < n_cols,
            table[jnp.arange(B)[:, None], jnp.minimum(page, n_cols - 1)], P)
        k_buf = cache["k"].at[phys, positions % ps].set(
            k_new.astype(cache["k"].dtype), mode="drop")
        v_buf = cache["v"].at[phys, positions % ps].set(
            v_new.astype(cache["v"].dtype), mode="drop")
        out = ops.paged_span_attention(q, k_buf, v_buf, table, pos,
                                       backend=fl.get("backend"))
        out = apply_dense(p["o"], out.reshape(B, S, H * hd))
        return out, {"k": k_buf, "v": v_buf, "len": pos + S}
    bidx = jnp.arange(B)[:, None]
    k_buf = cache["k"].at[bidx, positions].set(k_new.astype(cache["k"].dtype),
                                               mode="drop")
    v_buf = cache["v"].at[bidx, positions].set(v_new.astype(cache["v"].dtype),
                                               mode="drop")
    out = ops.span_attention(q, k_buf, v_buf, pos, backend=fl.get("backend"))
    out = apply_dense(p["o"], out.reshape(B, S, H * hd))
    return out, {"k": k_buf, "v": v_buf, "len": pos + S}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int] = None, dtype=jnp.bfloat16):
    S = min(window, max_len) if window is not None else max_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def kv_cache_axes(window: Optional[int] = None, kv_seq_shard: bool = False):
    """Logical axes of cache leaves (for sharding the serving state).

    ``kv_seq`` maps to the model mesh axis when ShardingConfig.kv_seq_shard is
    set (flash-decode: sequence-sharded KV, partial softmax + small
    all-reduces); kv_heads are then replicated to keep the spec valid.
    """
    return {
        "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "len": ("batch",),
    }
