"""Parameter builder with logical sharding axes, norms, projections, RoPE.

Every parameter is created through :class:`Builder`, which runs the same model
code in two modes:

* ``init``  — returns initialized ``jnp`` arrays;
* ``spec``  — returns ``jax.ShapeDtypeStruct`` stand-ins *and* records each
  leaf's logical axes, from which :func:`logical_to_pspec` derives the
  ``PartitionSpec`` tree for any mesh.  One code path → value tree and
  sharding tree can never diverge.

Logical axis vocabulary: ``vocab, embed, heads, kv_heads, head_dim, mlp,
experts, expert_in, expert_mlp, layers, window, lru, conv, stage``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig, ModelConfig, ShardingConfig

# ---------------------------------------------------------------------------
# logical axis → mesh axis rules
# ---------------------------------------------------------------------------

TENSOR_AXES = ("vocab", "heads", "mlp", "experts")   # TP/EP-sharded dims


def logical_rules(mesh_cfg: MeshConfig, model_cfg: ModelConfig,
                  shard_cfg: ShardingConfig) -> dict[str, Optional[str]]:
    model_size = dict(zip(mesh_cfg.axes, mesh_cfg.shape)).get("model", 1)
    rules: dict[str, Optional[str]] = {a: None for a in (
        "embed", "head_dim", "layers", "window", "conv", "stage", "expert_mlp",
        "expert_in", "lru", "kv_heads", "moe_top",
    )}
    for a in TENSOR_AXES:
        rules[a] = "model"
    # MoE with a non-divisible expert count (e.g. 60 over 16): shard the
    # expert hidden width instead, so expert weights still distribute
    if model_cfg.moe is not None and model_cfg.moe.n_experts % model_size != 0:
        rules["experts"] = None
        rules["expert_mlp"] = "model"
    # NOTE (§Perf cell 3, iteration 4 — refuted): replicating attention
    # weights when the head count does not divide the model axis (e.g. 20
    # heads over 16) removes the mid-head reshape gathers (collective 1.76 →
    # 0.55 s) but replicates the score/PV compute (compute 1.05 → 3.16 s) —
    # net worse.  Mid-head projection sharding + pinned K/V layout wins.
    if model_cfg.n_kv_heads % model_size == 0:
        rules["kv_heads"] = "model"
    # KV-cache sequence sharding (flash-decode) claims the model axis for the
    # cache's sequence dim; kv heads must then be replicated in the cache.
    rules["kv_seq"] = "model" if shard_cfg.kv_seq_shard else None
    if shard_cfg.kv_seq_shard:
        rules["kv_heads"] = None
    # experts: GSPMD supports uneven sharding (e.g. 60 experts over 16) but an
    # uneven final shard wastes memory; still preferable to replication.
    rules["batch"] = tuple(a for a in ("pod", "data") if a in mesh_cfg.axes) or None
    # Megatron-SP style: shard the residual stream's sequence dim over the
    # model axis between blocks (saved activations shrink 16×; GSPMD inserts
    # the all-gathers around attention/MLP).
    rules["seq"] = "model" if shard_cfg.seq_shard_residual else None
    return rules


def logical_to_pspec(axes: Sequence[Optional[str]], rules: dict[str, Optional[str]]) -> P:
    return P(*[rules.get(a) if a is not None else None for a in axes])


def assignment_size(mesh_cfg: MeshConfig, assignment) -> int:
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    if assignment is None:
        return 1
    if isinstance(assignment, str):
        return sizes.get(assignment, 1)
    out = 1
    for a in assignment:
        out *= sizes.get(a, 1)
    return out


def sanitize_pspec(shape: Sequence[int], pspec: P, mesh_cfg: MeshConfig) -> P:
    """Drop mesh-axis assignments a dim cannot honour: non-divisible dims
    (e.g. 60 experts or 40 RWKV heads over a 16-way axis) fall back to
    replication, and a mesh axis already used by an earlier dim is dropped
    from later dims (one position per axis per spec)."""
    parts = list(pspec) if len(pspec) else []
    parts = parts + [None] * (len(shape) - len(parts))
    out = []
    used: set = set()
    for dim, assignment in zip(shape, parts):
        if assignment is not None:
            axes = (assignment,) if isinstance(assignment, str) else tuple(assignment)
            if used & set(axes):
                assignment = None
            elif dim % assignment_size(mesh_cfg, assignment) != 0:
                assignment = None
            else:
                used |= set(axes)
        out.append(assignment)
    return P(*out)


def spec_tree_to_pspecs(spec_tree, rules, mesh_cfg: Optional[MeshConfig] = None) -> object:
    """Map a Builder spec tree (leaves carry .logical_axes) to PartitionSpecs,
    sanitized for divisibility when a mesh config is given."""
    def to_spec(s: ParamSpec) -> P:
        p = logical_to_pspec(s.logical_axes, rules)
        return sanitize_pspec(s.shape, p, mesh_cfg) if mesh_cfg is not None else p

    return jax.tree.map(to_spec, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------

class ParamSpec:
    """Abstract parameter leaf: shape/dtype + logical axes (spec mode output)."""

    __slots__ = ("shape", "dtype", "logical_axes")

    def __init__(self, shape, dtype, logical_axes):
        self.shape = tuple(shape)
        self.dtype = dtype
        self.logical_axes = tuple(logical_axes)

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def __repr__(self):
        return f"ParamSpec({self.shape}, {self.dtype}, {self.logical_axes})"


class Builder:
    """Creates parameters; in spec mode records logical axes instead."""

    def __init__(self, mode: str, key: Optional[jax.Array] = None, dtype=jnp.float32):
        assert mode in ("init", "spec")
        self.mode = mode
        self._key = key
        self.dtype = dtype
        self._counter = 0

    def _next_key(self):
        self._counter += 1
        return jax.random.fold_in(self._key, self._counter)

    def param(self, shape: Sequence[int], axes: Sequence[Optional[str]],
              init: str = "normal", scale: float = 1.0, dtype=None):
        dtype = dtype or self.dtype
        assert len(shape) == len(axes), f"shape {shape} vs axes {axes}"
        if self.mode == "spec":
            return ParamSpec(shape, dtype, axes)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = scale / np.sqrt(fan_in)
            return (jax.random.normal(self._next_key(), shape, jnp.float32) * std).astype(dtype)
        if init == "uniform":
            return jax.random.uniform(self._next_key(), shape, jnp.float32,
                                      -scale, scale).astype(dtype)
        raise ValueError(init)


# ---------------------------------------------------------------------------
# norms & projections (functional)
# ---------------------------------------------------------------------------

def init_norm(b: Builder, d: int, kind: str, axes=("embed",)):
    p = {"scale": b.param((d,), axes, init="ones")}
    if kind == "layernorm":
        p["bias"] = b.param((d,), axes, init="zeros")
    return p


def apply_norm(p, x, kind: str, eps: float):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        out = out + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return out.astype(x.dtype)


def init_dense(b: Builder, d_in: int, d_out: int, axes, bias: bool = False,
               scale: float = 1.0, bias_axes=None):
    p = {"w": b.param((d_in, d_out), axes, scale=scale)}
    if bias:
        p["b"] = b.param((d_out,), bias_axes or (axes[-1],), init="zeros")
    return p


def apply_dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE / partial / M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rotary_pct: float, theta: float) -> np.ndarray:
    rot = int(head_dim * rotary_pct) // 2 * 2
    return 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S) or (..., 3, S) for M-RoPE."""
    hd = cfg.head_dim
    rot = int(hd * cfg.rotary_pct) // 2 * 2
    if rot == 0 or cfg.rope_type == "none":
        return x
    freqs = jnp.asarray(rope_freqs(hd, cfg.rotary_pct, cfg.rope_theta), jnp.float32)  # (rot/2,)
    if cfg.rope_type == "mrope":
        # positions (..., 3, S): temporal / height / width ids; frequency bands
        # are split into the configured sections (Qwen2-VL §2.1).
        sections = tuple(cfg.mrope_sections)
        assert sum(sections) == rot // 2, (sections, rot)
        pos_parts = []
        start = 0
        for i, sec in enumerate(sections):
            pos_parts.append(jnp.repeat(positions[..., i, :, None], sec, axis=-1))
            start += sec
        pos_f = jnp.concatenate(pos_parts, axis=-1).astype(jnp.float32)   # (..., S, rot/2)
        angles = pos_f * freqs                                            # (..., S, rot/2)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs         # (..., S, rot/2)
    cos = jnp.cos(angles)[..., None, :]   # (..., S, 1, rot/2)
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2:]
    # rotate-half convention (HF Llama/Qwen)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name in ("swiglu", "geglu"):
        raise ValueError("gated activations are applied inside the MLP, not here")
    raise ValueError(name)
