"""RWKV6 ("Finch") blocks: time-mix with data-dependent decay + channel-mix.

Follows arXiv:2404.05892: token-shift with data-dependent linear interpolation
(ddlerp, LoRA-style), per-channel decay w_t = exp(−exp(ŵ_t)), WKV6 recurrence
(chunked — :func:`repro.kernels.ops.wkv6`), per-head GroupNorm and output
gating.  Channel-mix uses squared-ReLU keying.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import Builder, apply_dense, init_dense

_MIX_NAMES = ("r", "k", "v", "w", "g")
_LORA_RANK = 32
_DECAY_LORA_RANK = 64


def init_time_mix(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    p = {
        # ddlerp: shared down-projection + per-target up-projections
        "mix_base": b.param((len(_MIX_NAMES), d), (None, "embed"), init="zeros"),
        "mix_a": b.param((d, _LORA_RANK), ("embed", None), scale=0.1),
        "mix_b": b.param((len(_MIX_NAMES), _LORA_RANK, d), (None, None, "embed"), scale=0.1),
        "r": init_dense(b, d, d, ("embed", "heads")),
        "k": init_dense(b, d, d, ("embed", "heads")),
        "v": init_dense(b, d, d, ("embed", "heads")),
        "g": init_dense(b, d, d, ("embed", "heads")),
        "o": init_dense(b, d, d, ("heads", "embed")),
        # data-dependent decay: w = exp(−exp(w0 + lora_w(x)))
        "w0": b.param((d,), ("embed",), init="uniform", scale=0.5),
        "w_a": b.param((d, _DECAY_LORA_RANK), ("embed", None), scale=0.1),
        "w_b": b.param((_DECAY_LORA_RANK, d), (None, "embed"), scale=0.1),
        "u": b.param((H, cfg.rwkv_head_dim), ("heads", "head_dim"), init="uniform", scale=0.5),
        # per-head GroupNorm over the WKV output
        "gn_scale": b.param((d,), ("embed",), init="ones"),
        "gn_bias": b.param((d,), ("embed",), init="zeros"),
    }
    return p


def _ddlerp(p, x, xx):
    """Data-dependent token-shift interpolation → one mixed input per target."""
    delta = xx - x
    base = jax.nn.tanh((x + delta * 0.5) @ p["mix_a"])              # (B, S, rank)
    outs = []
    for i, _ in enumerate(_MIX_NAMES):
        mix = p["mix_base"][i] + base @ p["mix_b"][i]               # (B, S, d)
        outs.append(x + delta * mix)
    return outs


def _group_norm(x, scale, bias, n_heads, eps=1e-5):
    """GroupNorm over heads: x (B, S, d) with d = H · hd."""
    B, S, d = x.shape
    xh = x.reshape(B, S, n_heads, d // n_heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, d) * scale + bias).astype(x.dtype)


def time_mix_full(p, cfg: ModelConfig, x, shift_state=None, wkv_state=None):
    """Full-sequence time-mix.  x: (B, S, d).
    Returns (out, (new_shift_state, new_wkv_state))."""
    B, S, d = x.shape
    H, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    prev = jnp.zeros((B, 1, d), x.dtype) if shift_state is None else shift_state[:, None, :]
    xx = jnp.concatenate([prev, x[:, :-1]], axis=1)                 # token shift
    xr, xk, xv, xw, xg = _ddlerp(p, x, xx)
    r = apply_dense(p["r"], xr).reshape(B, S, H, hd)
    k = apply_dense(p["k"], xk).reshape(B, S, H, hd)
    v = apply_dense(p["v"], xv).reshape(B, S, H, hd)
    g = jax.nn.silu(apply_dense(p["g"], xg))
    w_raw = p["w0"] + jax.nn.tanh(xw @ p["w_a"]) @ p["w_b"]
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32))).reshape(B, S, H, hd)
    out, wkv_state = ops.wkv6(r, k, v, w, p["u"], state=wkv_state)
    out = _group_norm(out.reshape(B, S, d), p["gn_scale"], p["gn_bias"], H)
    out = apply_dense(p["o"], out * g)
    return out, (x[:, -1, :], wkv_state)


def time_mix_step(p, cfg: ModelConfig, x, shift_state, wkv_state):
    """Single-token decode step.  x: (B, 1, d); shift_state: (B, d);
    wkv_state: (B, H, hd, hd)."""
    out, (new_shift, new_wkv) = time_mix_full(p, cfg, x, shift_state, wkv_state)
    return out, (new_shift, new_wkv)


def init_channel_mix(b: Builder, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mix_k": b.param((d,), ("embed",), init="zeros"),
        "mix_r": b.param((d,), ("embed",), init="zeros"),
        "k": init_dense(b, d, ff, ("embed", "mlp")),
        "v": init_dense(b, ff, d, ("mlp", "embed")),
        "r": init_dense(b, d, d, ("embed", "embed")),
    }


def channel_mix_full(p, cfg: ModelConfig, x, shift_state=None):
    B, S, d = x.shape
    prev = jnp.zeros((B, 1, d), x.dtype) if shift_state is None else shift_state[:, None, :]
    xx = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xk = x + (xx - x) * p["mix_k"]
    xr = x + (xx - x) * p["mix_r"]
    kk = jnp.square(jax.nn.relu(apply_dense(p["k"], xk)))
    out = jax.nn.sigmoid(apply_dense(p["r"], xr)) * apply_dense(p["v"], kk)
    return out, x[:, -1, :]
