"""RecurrentGemma / Griffin recurrent block (arXiv:2402.19427).

Block: x → [gate branch: linear → GeLU] ⊙ [linear → causal conv1d(width 4) →
RG-LRU] → linear out.  RG-LRU: a_t = exp(−c·softplus(Λ)·σ(W_a x_t)),
h_t = a_t h_{t−1} + √(1−a_t²)·(σ(W_x x_t) ⊙ x_t), with c = 8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import Builder, apply_dense, init_dense

_C = 8.0


def init_rglru_block(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    W = cfg.lru_width or d
    return {
        "in_gate": init_dense(b, d, W, ("embed", "mlp")),
        "in_rec": init_dense(b, d, W, ("embed", "mlp")),
        "conv_w": b.param((cfg.conv_width, W), ("conv", "mlp"), scale=0.5),
        "conv_b": b.param((W,), ("mlp",), init="zeros"),
        # gate weights: output dim sharded with the recurrence width; the
        # input dim stays replicated (one mesh axis per spec)
        "gate_a": init_dense(b, W, W, (None, "mlp")),
        "gate_x": init_dense(b, W, W, (None, "mlp")),
        "lambda": b.param((W,), ("mlp",), init="uniform", scale=1.0),
        "out": init_dense(b, W, d, ("mlp", "embed")),
    }


def _causal_conv(w, bias, x, state=None):
    """Per-channel causal conv.  x: (B, S, W); state: (B, cw−1, W) history."""
    cw = w.shape[0]
    B, S, W = x.shape
    prev = jnp.zeros((B, cw - 1, W), x.dtype) if state is None else state.astype(x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)                  # (B, S+cw−1, W)
    out = sum(xp[:, i:i + S] * w[i] for i in range(cw)) + bias
    return out.astype(x.dtype), xp[:, -(cw - 1):]


def _log_a(p, u):
    """log a_t = −c · softplus(Λ) · σ(W_a u) ≤ 0."""
    r = jax.nn.sigmoid(apply_dense(p["gate_a"], u).astype(jnp.float32))
    lam = jax.nn.softplus(p["lambda"].astype(jnp.float32))
    return -_C * lam * r


def rglru_block_full(p, cfg: ModelConfig, x, conv_state=None, h_state=None):
    """Full-sequence recurrent branch.  x: (B, S, d).
    Returns (out, (new_conv_state, new_h_state))."""
    gate = jax.nn.gelu(apply_dense(p["in_gate"], x))
    u = apply_dense(p["in_rec"], x)
    u, conv_state = _causal_conv(p["conv_w"], p["conv_b"], u, conv_state)
    a_log = _log_a(p, u)
    gate_x = jax.nn.sigmoid(apply_dense(p["gate_x"], u).astype(jnp.float32))
    inp = (gate_x * u.astype(jnp.float32)).astype(x.dtype)
    h, h_state = ops.rglru_scan(inp, a_log, state=h_state)
    out = apply_dense(p["out"], h * gate)
    return out, (conv_state, h_state)


def rglru_block_step(p, cfg: ModelConfig, x, conv_state, h_state):
    """Single-token step; identical math at S = 1."""
    return rglru_block_full(p, cfg, x, conv_state, h_state)
