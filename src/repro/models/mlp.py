"""Dense MLP variants: SwiGLU / GeGLU (gated), GeLU, squared-ReLU (Nemotron)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import Builder, apply_dense, init_dense


def init_mlp(b: Builder, cfg: ModelConfig, d: int | None = None, ff: int | None = None):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    p = {}
    if cfg.activation in ("swiglu", "geglu"):
        p["gate"] = init_dense(b, d, ff, ("embed", "mlp"))
        p["up"] = init_dense(b, d, ff, ("embed", "mlp"))
    else:
        p["up"] = init_dense(b, d, ff, ("embed", "mlp"))
    p["down"] = init_dense(b, ff, d, ("mlp", "embed"))
    return p


def apply_mlp(p, cfg: ModelConfig, x):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(apply_dense(p["gate"], x)) * apply_dense(p["up"], x)
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(apply_dense(p["gate"], x)) * apply_dense(p["up"], x)
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(apply_dense(p["up"], x))
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(apply_dense(p["up"], x)))
    else:
        raise ValueError(cfg.activation)
    return apply_dense(p["down"], h)
