"""Unified model assembly for all assigned architecture families.

One ``Model`` class covers dense / MoE / RWKV6 / RG-LRU-hybrid decoders, the
VLM backbone (embedding inputs + M-RoPE) and the audio encoder-decoder:

* homogeneous layer stacks are scanned over *pattern groups* (compile-time
  O(1) in depth); a non-divisible tail (e.g. RecurrentGemma's 38 = 12×3 + 2)
  is unrolled;
* the same block code runs in full-sequence mode (train / prefill, optionally
  emitting a cache) and single-token decode mode (consuming/updating caches);
* every parameter carries logical sharding axes (see models.layers); the
  launcher turns them into PartitionSpecs for any mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.config import MeshConfig, ModelConfig, ShardingConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (
    Builder,
    ParamSpec,
    apply_norm,
    init_norm,
    logical_rules,
    logical_to_pspec,
    sanitize_pspec,
    spec_tree_to_pspecs,
)

__all__ = ["Model", "StackedBuilder"]


@jax.custom_vjp
def _weight_barrier(tree):
    """Differentiable loop-invariant-hoisting fence for scanned weight groups.

    ``jax.lax.optimization_barrier`` keeps the CPU backend from hoisting (and
    materializing) an f32 copy of the whole stacked weights out of the scan
    body, but the primitive has no differentiation rule — the fence is an
    identity, so its gradient is the identity too.
    """
    return jax.lax.optimization_barrier(tree)


def _weight_barrier_fwd(tree):
    return jax.lax.optimization_barrier(tree), None


def _weight_barrier_bwd(_, ct):
    return (ct,)


_weight_barrier.defvjp(_weight_barrier_fwd, _weight_barrier_bwd)


class StackedBuilder:
    """Wraps a Builder so every parameter gets a leading (n_groups,) 'layers'
    dim — the whole pattern-group stack is created as one leaf for lax.scan."""

    def __init__(self, base: Builder, n: int):
        self._base = base
        self._n = n
        self.mode = base.mode

    def param(self, shape, axes, **kw):
        return self._base.param((self._n, *shape), ("layers", *axes), **kw)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _init_block(b, cfg: ModelConfig, kind: str, with_cross: bool = False):
    p = {"n1": init_norm(b, cfg.d_model, cfg.norm)}
    if kind in ("attn", "local"):
        p["attn"] = attn_mod.init_attention(b, cfg)
        if with_cross:
            p["nc"] = init_norm(b, cfg.d_model, cfg.norm)
            p["cross"] = attn_mod.init_attention(b, cfg, cross=True)
        p["n2"] = init_norm(b, cfg.d_model, cfg.norm)
        p["mlp"] = moe_mod.init_moe(b, cfg) if cfg.moe else mlp_mod.init_mlp(b, cfg)
    elif kind == "rwkv":
        p["tm"] = rwkv_mod.init_time_mix(b, cfg)
        p["n2"] = init_norm(b, cfg.d_model, cfg.norm)
        p["cm"] = rwkv_mod.init_channel_mix(b, cfg)
    elif kind == "rglru":
        p["rec"] = rglru_mod.init_rglru_block(b, cfg)
        p["n2"] = init_norm(b, cfg.d_model, cfg.norm)
        p["mlp"] = moe_mod.init_moe(b, cfg) if cfg.moe else mlp_mod.init_mlp(b, cfg)
    else:
        raise ValueError(kind)
    return p


def _mlp_or_moe(p, cfg: ModelConfig, x, flags):
    if cfg.moe:
        return moe_mod.apply_moe(p, cfg, x, dispatch=flags.get("moe_dispatch", "gather"),
                                 exact=flags.get("moe_exact", False),
                                 dp_size=flags.get("dp_size", 1),
                                 constrain=flags.get("moe_constrain"))
    return mlp_mod.apply_mlp(p, cfg, x), jnp.zeros((), jnp.float32)


def _block_full(p, cfg: ModelConfig, kind: str, x, positions, *, causal=True,
                enc_out=None, enc_positions=None, want_cache=False,
                cache_len: int = 0, flags=None):
    """Full-sequence block.  Returns (x, cache_entry_or_None, aux)."""
    flags = flags or {}
    aux = jnp.zeros((), jnp.float32)
    cache = None
    norm = lambda pn, h: apply_norm(pn, h, cfg.norm, cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        a, (k, v) = attn_mod.attention_full(p["attn"], cfg, norm(p["n1"], x), positions,
                                            causal=causal, window=window, flags=flags)
        x = x + a
        if "cross" in p and enc_out is not None:
            c, (ck, cv) = attn_mod.attention_full(p["cross"], cfg, norm(p["nc"], x),
                                                  positions, kv_source=enc_out, flags=flags)
            x = x + c
        h, a2 = _mlp_or_moe(p["mlp"], cfg, norm(p["n2"], x), flags)
        x = x + h
        aux = aux + a2
        if want_cache:
            cache = _fill_kv_cache(cfg, k, v, cache_len, cfg.window if kind == "local" else None)
            if "cross" in p and enc_out is not None:
                cache["cross"] = {"k": ck, "v": cv,
                                  "len": jnp.full((x.shape[0],), ck.shape[1], jnp.int32)}
    elif kind == "rwkv":
        h, (shift_tm, wkv) = rwkv_mod.time_mix_full(p["tm"], cfg, norm(p["n1"], x))
        x = x + h
        h, shift_cm = rwkv_mod.channel_mix_full(p["cm"], cfg, norm(p["n2"], x))
        x = x + h
        if want_cache:
            cache = {"shift_tm": shift_tm, "shift_cm": shift_cm, "wkv": wkv}
    elif kind == "rglru":
        h, (conv, hstate) = rglru_mod.rglru_block_full(p["rec"], cfg, norm(p["n1"], x))
        x = x + h
        h, a2 = _mlp_or_moe(p["mlp"], cfg, norm(p["n2"], x), flags)
        x = x + h
        aux = aux + a2
        if want_cache:
            cache = {"conv": conv, "h": hstate}
    else:
        raise ValueError(kind)
    return x, cache, aux


def _fill_kv_cache(cfg: ModelConfig, k, v, max_len: int, window: Optional[int]):
    """Place prefill K/V into a fixed-size (or ring) cache buffer."""
    B, S = k.shape[0], k.shape[1]
    size = min(window, max_len) if window else max_len
    buf_k = jnp.zeros((B, size, cfg.n_kv_heads, cfg.head_dim), k.dtype)
    buf_v = jnp.zeros_like(buf_k)
    if window:
        take = min(S, size)
        pos = jnp.arange(S - take, S)
        slot = pos % size
        buf_k = buf_k.at[:, slot].set(k[:, -take:])
        buf_v = buf_v.at[:, slot].set(v[:, -take:])
    else:
        buf_k = jax.lax.dynamic_update_slice_in_dim(buf_k, k[:, :size], 0, axis=1)
        buf_v = jax.lax.dynamic_update_slice_in_dim(buf_v, v[:, :size], 0, axis=1)
    return {"k": buf_k, "v": buf_v, "len": jnp.full((B,), S, jnp.int32)}


def _block_step(p, cfg: ModelConfig, kind: str, x, cache, flags=None):
    """Single-token decode.  Returns (x, new_cache)."""
    flags = flags or {}
    norm = lambda pn, h: apply_norm(pn, h, cfg.norm, cfg.norm_eps)
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        self_cache = {kk: cache[kk] for kk in ("k", "v", "len")}
        a, new_self = attn_mod.attention_decode(p["attn"], cfg, norm(p["n1"], x),
                                                self_cache, window=window, flags=flags)
        x = x + a
        new_cache = dict(new_self)
        if "cross" in p and "cross" in cache:
            c, _ = attn_mod.attention_decode(p["cross"], cfg, norm(p["nc"], x), None,
                                             kv_source_cache=cache["cross"], flags=flags)
            x = x + c
            new_cache["cross"] = cache["cross"]
        h, _ = _mlp_or_moe(p["mlp"], cfg, norm(p["n2"], x), flags)
        x = x + h
    elif kind == "rwkv":
        h, (shift_tm, wkv) = rwkv_mod.time_mix_step(p["tm"], cfg, norm(p["n1"], x),
                                                    cache["shift_tm"], cache["wkv"])
        x = x + h
        h, shift_cm = rwkv_mod.channel_mix_full(p["cm"], cfg, norm(p["n2"], x),
                                                cache["shift_cm"])
        x = x + h
        new_cache = {"shift_tm": shift_tm, "shift_cm": shift_cm, "wkv": wkv}
    elif kind == "rglru":
        h, (conv, hstate) = rglru_mod.rglru_block_step(p["rec"], cfg, norm(p["n1"], x),
                                                       cache["conv"], cache["h"])
        x = x + h
        h, _ = _mlp_or_moe(p["mlp"], cfg, norm(p["n2"], x), flags)
        x = x + h
        new_cache = {"conv": conv, "h": hstate}
    else:
        raise ValueError(kind)
    return x, new_cache


def _block_span(p, cfg: ModelConfig, kind: str, x, cache, flags=None):
    """S-token decode (speculative verification).  Mirrors :func:`_block_step`
    for global-attention blocks; other kinds have stateful recurrences that a
    parallel span cannot reproduce step-exactly, so they are rejected."""
    flags = flags or {}
    norm = lambda pn, h: apply_norm(pn, h, cfg.norm, cfg.norm_eps)
    if kind != "attn":
        raise ValueError(f"decode_span supports global-attention blocks only, "
                         f"got {kind!r}")
    self_cache = {kk: cache[kk] for kk in ("k", "v", "len")}
    a, new_cache = attn_mod.attention_span(p["attn"], cfg, norm(p["n1"], x),
                                           self_cache, flags=flags)
    x = x + a
    if "cross" in p:
        raise ValueError("decode_span does not support cross-attention")
    h, _ = _mlp_or_moe(p["mlp"], cfg, norm(p["n2"], x), flags)
    x = x + h
    return x, new_cache


# ---------------------------------------------------------------------------
# cache specs (abstract; concrete init via jnp.zeros of the same shapes)
# ---------------------------------------------------------------------------

def _block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      with_cross: bool, enc_len: int, dtype) -> dict:
    d = cfg.d_model
    if kind in ("attn", "local"):
        size = min(cfg.window, max_len) if kind == "local" and cfg.window else max_len
        spec = {
            "k": ParamSpec((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype,
                           ("batch", "kv_seq", "kv_heads", "head_dim")),
            "v": ParamSpec((batch, size, cfg.n_kv_heads, cfg.head_dim), dtype,
                           ("batch", "kv_seq", "kv_heads", "head_dim")),
            "len": ParamSpec((batch,), jnp.int32, ("batch",)),
        }
        if with_cross:
            spec["cross"] = {
                "k": ParamSpec((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype,
                               ("batch", "seq", "kv_heads", "head_dim")),
                "v": ParamSpec((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype,
                               ("batch", "seq", "kv_heads", "head_dim")),
                "len": ParamSpec((batch,), jnp.int32, ("batch",)),
            }
        return spec
    if kind == "rwkv":
        H = d // cfg.rwkv_head_dim
        return {
            "shift_tm": ParamSpec((batch, d), dtype, ("batch", "embed")),
            "shift_cm": ParamSpec((batch, d), dtype, ("batch", "embed")),
            "wkv": ParamSpec((batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                             jnp.float32, ("batch", "heads", "head_dim", "head_dim")),
        }
    if kind == "rglru":
        W = cfg.lru_width or d
        return {
            "conv": ParamSpec((batch, cfg.conv_width - 1, W), dtype, ("batch", "conv", "mlp")),
            "h": ParamSpec((batch, W), jnp.float32, ("batch", "mlp")),
        }
    raise ValueError(kind)


def _stack_spec(spec, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), s.dtype, ("layers", *s.logical_axes)),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass
class Model:
    cfg: ModelConfig
    shard: ShardingConfig = field(default_factory=ShardingConfig)
    mesh: Any = None          # optional jax Mesh for activation constraints

    def __post_init__(self):
        # pad the vocab to a 32-multiple so the embedding/lm_head/logits can
        # always shard over the model axis (e.g. seamless's 256206 → 256224);
        # padded columns are masked to −inf in the logits and never targeted
        self.vocab_padded = ((self.cfg.vocab_size + 31) // 32) * 32
        pat = list(self.cfg.block_pattern)
        self.pattern = pat
        if self.shard.scan_layers:
            self.n_groups = self.cfg.n_layers // len(pat)
            self.rem_kinds = self.cfg.layer_kinds()[self.n_groups * len(pat):]
        else:
            self.n_groups = 0
            self.rem_kinds = self.cfg.layer_kinds()
        self.dtype = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32

    # ---------------- parameters ----------------
    def _build(self, b: Builder):
        cfg = self.cfg
        params: dict = {
            "embed": b.param((self.vocab_padded, cfg.d_model), ("vocab", "embed"), scale=1.0),
            "final_norm": init_norm(b, cfg.d_model, cfg.norm),
        }
        with_cross = cfg.enc_dec
        if self.n_groups > 0:
            sb = StackedBuilder(b, self.n_groups)
            params["blocks"] = {
                f"b{i}": _init_block(sb, cfg, kind, with_cross)
                for i, kind in enumerate(self.pattern)
            }
        for j, kind in enumerate(self.rem_kinds):
            params[f"rem{j}"] = _init_block(b, cfg, kind, with_cross)
        if not cfg.tie_embeddings:
            params["lm_head"] = b.param((cfg.d_model, self.vocab_padded), ("embed", "vocab"))
        if cfg.enc_dec:
            ne = cfg.n_encoder_layers
            seb = StackedBuilder(b, ne)
            params["encoder"] = {"blocks": {"b0": _init_block(seb, cfg, "attn", False)},
                                 "norm": init_norm(b, cfg.d_model, cfg.norm)}
        return params

    def init(self, key) -> dict:
        return self._build(Builder("init", key, dtype=self.dtype))

    def param_specs(self) -> dict:
        return self._build(Builder("spec", dtype=self.dtype))

    def param_pspecs(self, mesh_cfg: MeshConfig) -> dict:
        rules = logical_rules(mesh_cfg, self.cfg, self.shard)
        return spec_tree_to_pspecs(self.param_specs(), rules, mesh_cfg)

    def abstract_params(self) -> dict:
        return jax.tree.map(lambda s: s.sds(), self.param_specs(),
                            is_leaf=lambda x: isinstance(x, ParamSpec))

    def param_count(self) -> int:
        import numpy as np
        return int(sum(np.prod(s.shape) for s in jax.tree.leaves(
            self.param_specs(), is_leaf=lambda x: isinstance(x, ParamSpec))))

    # ---------------- helpers ----------------
    def _constrain(self, x, axes):
        if self.mesh is None:
            return x
        mesh_cfg = MeshConfig(shape=tuple(self.mesh.shape.values()),
                              axes=tuple(self.mesh.shape.keys()))
        rules = logical_rules(mesh_cfg, self.cfg, self.shard)
        spec = sanitize_pspec(x.shape, logical_to_pspec(axes, rules), mesh_cfg)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def _embed_in(self, params, tokens_or_embeds):
        if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
            x = params["embed"][tokens_or_embeds].astype(self.dtype)
        else:
            x = tokens_or_embeds.astype(self.dtype)
        return self._constrain(x, ("batch", "seq", "embed"))

    def _logits(self, params, x):
        x = apply_norm(params["final_norm"], x, self.cfg.norm, self.cfg.norm_eps)
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        if self.vocab_padded != self.cfg.vocab_size:
            pad_mask = jnp.arange(self.vocab_padded) >= self.cfg.vocab_size
            logits = jnp.where(pad_mask, -1e30, logits)
        return self._constrain(logits, ("batch", "seq", "vocab"))

    def _positions(self, B, S, offset=0):
        pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
        pos = jnp.broadcast_to(pos, (B, S))
        if self.cfg.rope_type == "mrope":
            return jnp.broadcast_to(pos[:, None, :], (B, 3, S))   # text-only default
        return pos

    def _run_encoder(self, params, enc_embeds):
        cfg = self.cfg
        x = enc_embeds.astype(self.dtype)
        B, S = x.shape[:2]
        pos = self._positions(B, S)

        def body(h, gp):
            gp = _weight_barrier(gp)
            h, _, _ = _block_full(gp["b0"], cfg, "attn", h, pos, causal=False,
                                  flags=self._flags())
            return h, None

        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return apply_norm(params["encoder"]["norm"], x, cfg.norm, cfg.norm_eps)

    def _flags(self):
        dp_size = 1
        if self.mesh is not None:
            sizes = dict(self.mesh.shape)
            dp_size = sizes.get("pod", 1) * sizes.get("data", 1)
        return {
            "moe_dispatch": self.shard.moe_dispatch,
            "causal_skip": self.shard.causal_skip,
            "q_block": self.shard.attn_q_block,
            "kv_block": self.shard.attn_kv_block,
            "constrain": self._constrain if self.shard.pin_kv_layout else None,
            "dp_size": dp_size,
            "moe_constrain": self._constrain if self.mesh is not None else None,
        }

    # ---------------- full-sequence forward ----------------
    def forward(self, params, inputs, enc_inputs=None, positions=None):
        """inputs: tokens (B, S) int32 or embeds (B, S, d).  Returns (logits, aux)."""
        cfg = self.cfg
        x = self._embed_in(params, inputs)
        B, S = x.shape[:2]
        pos = positions if positions is not None else self._positions(B, S)
        enc_out = self._run_encoder(params, enc_inputs) if cfg.enc_dec else None
        flags = self._flags()

        def group_body(h, gp):
            # block loop-invariant hoisting of per-layer weight converts (the
            # CPU backend would otherwise materialize an f32 copy of the WHOLE
            # stacked weights; on TPU bf16 dots are native and this is free)
            gp = _weight_barrier(gp)
            aux = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(self.pattern):
                h, _, a = _block_full(gp[f"b{i}"], cfg, kind, h, pos,
                                      enc_out=enc_out, flags=flags)
                aux = aux + a
            h = self._constrain(h, ("batch", "seq", "embed"))
            return h, aux

        body = group_body
        if self.shard.remat == "block":
            body = jax.checkpoint(group_body, prevent_cse=False)
        aux_total = jnp.zeros((), jnp.float32)
        if self.n_groups > 0:
            x, auxs = jax.lax.scan(body, x, params["blocks"])
            aux_total = aux_total + auxs.sum()
        for j, kind in enumerate(self.rem_kinds):
            x, _, a = _block_full(params[f"rem{j}"], cfg, kind, x, pos,
                                  enc_out=enc_out, flags=flags)
            aux_total = aux_total + a
        return self._logits(params, x), aux_total

    def loss(self, params, batch):
        """batch: {"tokens" | "embeds", "labels", optional "enc_embeds"}.
        Next-token cross-entropy (labels already shifted); -100 masks."""
        logits, aux = self.forward(params, batch.get("tokens", batch.get("embeds")),
                                   enc_inputs=batch.get("enc_embeds"),
                                   positions=batch.get("positions"))
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * mask
        return ce.sum() / jnp.maximum(mask.sum(), 1.0) + aux

    # ---------------- caches ----------------
    def cache_specs(self, batch: int, max_len: int, enc_len: int = 0) -> dict:
        cfg = self.cfg
        spec: dict = {}
        if self.n_groups > 0:
            spec["blocks"] = {
                f"b{i}": _stack_spec(
                    _block_cache_spec(cfg, kind, batch, max_len, cfg.enc_dec, enc_len,
                                      jnp.bfloat16 if self.dtype == jnp.bfloat16 else jnp.float32),
                    self.n_groups)
                for i, kind in enumerate(self.pattern)
            }
        for j, kind in enumerate(self.rem_kinds):
            dt = jnp.bfloat16 if self.dtype == jnp.bfloat16 else jnp.float32
            spec[f"rem{j}"] = _block_cache_spec(cfg, kind, batch, max_len,
                                                cfg.enc_dec, enc_len, dt)
        return spec

    def cache_pspecs(self, mesh_cfg: MeshConfig, batch: int, max_len: int, enc_len: int = 0):
        rules = logical_rules(mesh_cfg, self.cfg, self.shard)
        return spec_tree_to_pspecs(self.cache_specs(batch, max_len, enc_len), rules, mesh_cfg)

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0) -> dict:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(batch, max_len, enc_len),
                            is_leaf=lambda x: isinstance(x, ParamSpec))

    def abstract_cache(self, batch: int, max_len: int, enc_len: int = 0) -> dict:
        return jax.tree.map(lambda s: s.sds(), self.cache_specs(batch, max_len, enc_len),
                            is_leaf=lambda x: isinstance(x, ParamSpec))

    def paged_cache_specs(self, n_pages: int, page_size: int, batch: int) -> dict:
        """Cache specs for the paged serving layout: each attention layer's
        K/V become one ``(n_pages, page_size, Hk, hd)`` block pool shared by
        all slots (the per-slot block tables live host-side in the engine's
        allocator); ``len`` stays per-slot.  Only decoder-only global-attention
        stacks qualify — recurrent state and ring buffers have no paged form.
        """
        cfg = self.cfg
        assert not cfg.enc_dec and all(k == "attn" for k in cfg.layer_kinds()), \
            "paged KV cache requires a decoder-only global-attention stack"
        dt = jnp.bfloat16 if self.dtype == jnp.bfloat16 else jnp.float32

        def block():
            kv = lambda: ParamSpec(
                (n_pages, page_size, cfg.n_kv_heads, cfg.head_dim), dt,
                ("kv_pages", "kv_page", "kv_heads", "head_dim"))
            return {"k": kv(), "v": kv(),
                    "len": ParamSpec((batch,), jnp.int32, ("batch",))}

        spec: dict = {}
        if self.n_groups > 0:
            spec["blocks"] = {f"b{i}": _stack_spec(block(), self.n_groups)
                              for i in range(len(self.pattern))}
        for j in range(len(self.rem_kinds)):
            spec[f"rem{j}"] = block()
        return spec

    def init_paged_cache(self, n_pages: int, page_size: int, batch: int) -> dict:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.paged_cache_specs(n_pages, page_size, batch),
                            is_leaf=lambda x: isinstance(x, ParamSpec))

    # ---------------- prefill ----------------
    def prefill(self, params, inputs, max_len: int, enc_inputs=None, lengths=None):
        """Run the full prompt, build caches.  Returns (last_logits, cache).

        ``lengths`` (B,): valid prompt lengths for right-padded batches.  With
        causal attention right-padding never contaminates the valid prefix;
        the returned logits are gathered at each sequence's last valid token
        and cache lengths are set per sequence.
        """
        cfg = self.cfg
        x = self._embed_in(params, inputs)
        B, S = x.shape[:2]
        pos = self._positions(B, S)
        enc_out = self._run_encoder(params, enc_inputs) if cfg.enc_dec else None
        flags = self._flags()
        cache: dict = {}

        if self.n_groups > 0:
            def group_body(h, gp):
                gp = _weight_barrier(gp)
                caches = {}
                for i, kind in enumerate(self.pattern):
                    h, c, _ = _block_full(gp[f"b{i}"], cfg, kind, h, pos, enc_out=enc_out,
                                          want_cache=True, cache_len=max_len, flags=flags)
                    caches[f"b{i}"] = c
                return h, caches

            x, caches = jax.lax.scan(group_body, x, params["blocks"])
            cache["blocks"] = caches
        for j, kind in enumerate(self.rem_kinds):
            x, c, _ = _block_full(params[f"rem{j}"], cfg, kind, x, pos, enc_out=enc_out,
                                  want_cache=True, cache_len=max_len, flags=flags)
            cache[f"rem{j}"] = c
        if lengths is not None:
            # right-padded variable-length prompts: valid only for pure
            # attention stacks (recurrent states would advance through pads)
            assert all(k in ("attn", "local") for k in cfg.layer_kinds()), \
                "variable-length prefill requires attention-only models"
            last = jnp.take_along_axis(x, (lengths - 1)[:, None, None]
                                       .astype(jnp.int32), axis=1)
            logits = self._logits(params, last)
            cache = jax.tree.map(
                lambda leaf: (jnp.broadcast_to(lengths.astype(leaf.dtype), leaf.shape)
                              if leaf.ndim >= 1 and leaf.dtype == jnp.int32
                              and leaf.shape[-1] == B else leaf),
                cache)
        else:
            logits = self._logits(params, x[:, -1:, :])
        return logits, cache

    # ---------------- decode ----------------
    def decode_step(self, params, tokens, cache, table=None):
        """tokens: (B, 1) int32 (or (B, 1, d) embeds).  Returns (logits, cache).

        ``table``: optional (B, n_cols) int32 block table switching the
        attention layers onto a paged KV cache (see ``paged_cache_specs``);
        one table serves every layer — all layers page identically.
        """
        cfg = self.cfg
        x = self._embed_in(params, tokens)
        flags = {**self._flags(), "moe_exact": True}   # no capacity drops mid-decode
        if table is not None:
            flags["kv_table"] = table
        new_cache: dict = {}
        if self.n_groups > 0:
            def group_body(h, xs):
                gp, gc = xs
                gp = _weight_barrier(gp)
                new_gc = {}
                for i, kind in enumerate(self.pattern):
                    h, nc = _block_step(gp[f"b{i}"], cfg, kind, h, gc[f"b{i}"], flags=flags)
                    new_gc[f"b{i}"] = nc
                return h, new_gc

            x, nblocks = jax.lax.scan(group_body, x, (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = nblocks
        for j, kind in enumerate(self.rem_kinds):
            x, nc = _block_step(params[f"rem{j}"], cfg, kind, x, cache[f"rem{j}"], flags=flags)
            new_cache[f"rem{j}"] = nc
        logits = self._logits(params, x)
        return logits, new_cache

    def decode_span(self, params, tokens, cache, table=None):
        """tokens: (B, S) int32 — a short run of S new tokens appended in ONE
        dispatch, returning per-position logits (B, S, V).  The speculative
        verify pass: one fused target forward scores all drafted tokens.

        Causality within the span is enforced by masking (each position sees
        only earlier keys), so the result matches S sequential
        :meth:`decode_step` calls bitwise.  Global-attention decoder-only
        models (the paged-cache constraint); ``table`` as in ``decode_step``.
        """
        cfg = self.cfg
        x = self._embed_in(params, tokens)
        flags = {**self._flags(), "moe_exact": True}
        if table is not None:
            flags["kv_table"] = table
        new_cache: dict = {}
        if self.n_groups > 0:
            def group_body(h, xs):
                gp, gc = xs
                gp = _weight_barrier(gp)
                new_gc = {}
                for i, kind in enumerate(self.pattern):
                    h, nc = _block_span(gp[f"b{i}"], cfg, kind, h, gc[f"b{i}"],
                                        flags=flags)
                    new_gc[f"b{i}"] = nc
                return h, new_gc

            x, nblocks = jax.lax.scan(group_body, x, (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = nblocks
        for j, kind in enumerate(self.rem_kinds):
            x, nc = _block_span(params[f"rem{j}"], cfg, kind, x, cache[f"rem{j}"],
                                flags=flags)
            new_cache[f"rem{j}"] = nc
        logits = self._logits(params, x)
        return logits, new_cache
