"""Mixture-of-Experts layer: top-k router, shared experts, two dispatch paths.

Dispatch paths (ShardingConfig.moe_dispatch):
  * ``gather`` (default) — capacity-based sort-free dispatch: per-(token,slot)
    ranks within the chosen expert via bincount offsets, gather to (E, C, d),
    batched expert matmuls, weighted scatter-add back.  FLOPs ≈ active-expert
    matmuls only.
  * ``dense``  — classic GShard one-hot dispatch/combine einsums.  Simple and
    exactly permutation-equivariant, but adds O(T·E·C·d) dispatch FLOPs; kept
    as the naive baseline for the perf study and as the oracle in tests.

Experts are sharded over the ``model`` mesh axis (expert parallelism); with
non-divisible expert counts (e.g. 60 over 16) GSPMD pads the final shard.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import Builder, apply_dense, init_dense


def init_moe(b: Builder, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    p = {"router": b.param((d, m.n_experts), ("embed", "experts"))}
    gated = cfg.activation in ("swiglu", "geglu")
    if gated:
        p["gate"] = b.param((m.n_experts, d, m.d_expert), ("experts", "expert_in", "expert_mlp"))
    p["up"] = b.param((m.n_experts, d, m.d_expert), ("experts", "expert_in", "expert_mlp"))
    p["down"] = b.param((m.n_experts, m.d_expert, d), ("experts", "expert_mlp", "expert_in"))
    if m.d_shared:
        p["shared"] = {
            "gate": init_dense(b, d, m.d_shared, ("embed", "mlp")),
            "up": init_dense(b, d, m.d_shared, ("embed", "mlp")),
            "down": init_dense(b, m.d_shared, d, ("mlp", "embed")),
            # Qwen2-MoE gates the shared expert with a per-token sigmoid
            "gate_proj": b.param((d, 1), ("embed", None)),
        }
    return p


def _expert_ffn(p, cfg: ModelConfig, x):
    """x: (E, C, d) -> (E, C, d) via per-expert batched matmuls."""
    if cfg.activation in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", x, p["gate"])) * jnp.einsum("ecd,edf->ecf", x, p["up"])
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", x, p["up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["down"])


def apply_moe(p, cfg: ModelConfig, x, dispatch: str = "gather", exact: bool = False,
              chunk_tokens: int = 65_536, dp_size: int = 1, constrain=None):
    """x: (B, S, d).  Returns (out, aux_loss).

    ``exact=True`` sets capacity C = T (no token drops) — used for decode
    steps, where T is tiny and a capacity-factor C would drop live requests.

    Long sequences dispatch in token chunks of ``chunk_tokens`` (lax.map):
    the gathered (E, C, d) buffers scale with the chunk, not the full batch —
    capacity limits then apply per chunk (statistically equivalent, noted in
    DESIGN.md).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)                  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style): E · Σ_e f_e · P_e
    f = jnp.zeros((m.n_experts,), jnp.float32)
    f = f.at[top_i.reshape(-1)].add(1.0) / (T * m.top_k)
    P = probs.mean(0)
    aux = m.n_experts * jnp.sum(f * P) * m.router_aux_weight

    if dispatch == "ep" and not exact:
        out = _dispatch_ep(p, cfg, xt, top_p, top_i, dp_size=dp_size,
                           constrain=constrain, chunk_tokens=chunk_tokens)
    else:
        fn = {"dense": _dispatch_dense, "gather": _dispatch_gather,
              "ep": _dispatch_gather}[dispatch]
        if exact or T <= chunk_tokens or T % chunk_tokens != 0:
            C = T if exact else min(max(1, math.ceil(T * m.top_k / m.n_experts
                                                     * m.capacity_factor)), T)
            out = fn(p, cfg, xt, top_p, top_i, C)
        else:
            n_chunks = T // chunk_tokens
            Tc = chunk_tokens
            C = min(max(1, math.ceil(Tc * m.top_k / m.n_experts * m.capacity_factor)), Tc)
            out = jax.lax.map(
                lambda args: fn(p, cfg, args[0], args[1], args[2], C),
                (xt.reshape(n_chunks, Tc, d), top_p.reshape(n_chunks, Tc, -1),
                 top_i.reshape(n_chunks, Tc, -1)),
            ).reshape(T, d)

    if m.d_shared:
        sp = p["shared"]
        h = jax.nn.silu(apply_dense(sp["gate"], xt)) * apply_dense(sp["up"], xt)
        sh = apply_dense(sp["down"], h)
        gate = jax.nn.sigmoid(xt @ sp["gate_proj"].astype(xt.dtype))
        out = out + gate * sh
    return out.reshape(B, S, d).astype(x.dtype), aux


def _pair_ranks(top_i, n_experts: int):
    """Rank of each (token, slot) pair within its expert (dispatch order)."""
    flat_e = top_i.reshape(-1)                                    # (P,)
    P = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)                      # pairs grouped by expert
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                          # (E,)
    ranks_sorted = jnp.arange(P, dtype=jnp.int32) - starts[flat_e[order]]
    ranks = jnp.zeros((P,), jnp.int32).at[order].set(ranks_sorted)
    return flat_e, ranks


def _dispatch_ep(p, cfg: ModelConfig, xt, top_p, top_i, dp_size: int = 1,
                 constrain=None, chunk_tokens: int = 65_536):
    """Expert-parallel dispatch (beyond-paper §Perf cell 1).

    Each data shard ranks and packs ITS OWN tokens into (E, C_local, d)
    locally (no cross-shard sort, no activation all-gather); one
    transpose-reshard then moves token rows to their expert shards — GSPMD
    lowers it to the canonical MoE all-to-all.  Payload per layer is exactly
    the dispatched rows (T·k·cf·d).  Capacity limits apply per shard per
    chunk (statistically equivalent for shuffled batches).

    Chunking happens INSIDE the shard dim (a flat token chunk would live
    entirely on one data shard and serialize the mesh).
    """
    m = cfg.moe
    T, d = xt.shape
    k = m.top_k
    D = dp_size if (dp_size > 1 and T % dp_size == 0) else 1
    Tl = T // D
    xt_s = xt.reshape(D, Tl, d)
    ti = top_i.reshape(D, Tl, k)
    tp = top_p.reshape(D, Tl, k)
    if constrain is not None:
        # the reshape is shard-aligned (contiguous rows per dp rank); pin it
        # so GSPMD does not materialize a gathered copy
        xt_s = constrain(xt_s, ("batch", None, None))

    def run(x_loc_all, ti_all, tp_all):
        """One chunk: x (D, Tc, d)."""
        Tc = x_loc_all.shape[1]
        C = min(max(1, math.ceil(Tc * k / m.n_experts * m.capacity_factor)), Tc)

        def shard_pack(x_loc, ti_loc, tp_loc):
            flat_e, ranks = _pair_ranks(ti_loc, m.n_experts)
            flat_w = tp_loc.reshape(-1).astype(jnp.float32)
            tok = jnp.repeat(jnp.arange(Tc, dtype=jnp.int32), k)
            keep = ranks < C
            slot = jnp.where(keep, flat_e * C + ranks, m.n_experts * C)
            slot_tok = jnp.zeros((m.n_experts * C + 1,),
                                 jnp.int32).at[slot].set(tok, mode="drop")[:-1]
            slot_w = jnp.zeros((m.n_experts * C + 1,),
                               jnp.float32).at[slot].set(flat_w, mode="drop")[:-1]
            g = x_loc[slot_tok].reshape(m.n_experts, C, d)
            g = g * (slot_w.reshape(m.n_experts, C, 1) > 0)
            return g, slot_tok, slot_w

        gathered, slot_tok, slot_w = jax.vmap(shard_pack)(x_loc_all, ti_all, tp_all)
        if constrain is not None:
            gathered = constrain(gathered, ("batch", "experts", None, None))
        # move rows to expert shards: (E, D·C, d) sharded over experts — the A2A
        h_in = gathered.transpose(1, 0, 2, 3).reshape(m.n_experts, D * C, d)
        if constrain is not None:
            h_in = constrain(h_in, ("experts", None, None))
        h = _expert_ffn(p, cfg, h_in)
        h = h.reshape(m.n_experts, D, C, d).transpose(1, 0, 2, 3)
        if constrain is not None:
            h = constrain(h, ("batch", "experts", None, None))
        h = h * slot_w.reshape(D, m.n_experts, C, 1).astype(h.dtype)

        def shard_unpack(h_loc, slot_tok_loc):
            return jnp.zeros((Tc, d), h.dtype).at[slot_tok_loc.reshape(-1)].add(
                h_loc.reshape(-1, d))

        return jax.vmap(shard_unpack)(h, slot_tok)                     # (D, Tc, d)

    chunk_local = max(chunk_tokens // D, 1)
    if Tl <= chunk_local or Tl % chunk_local != 0:
        out = run(xt_s, ti, tp)
    else:
        n_ch = Tl // chunk_local
        def chunked(t3):
            return t3.reshape(D, n_ch, chunk_local, -1).transpose(1, 0, 2, 3)
        out = jax.lax.map(lambda a: run(*a), (chunked(xt_s), chunked(ti), chunked(tp)))
        out = out.transpose(1, 0, 2, 3).reshape(D, Tl, d)
    return out.reshape(T, d)


def _dispatch_gather(p, cfg: ModelConfig, xt, top_p, top_i, C: int):
    m = cfg.moe
    T, d = xt.shape
    k = m.top_k
    flat_e, ranks = _pair_ranks(top_i, m.n_experts)               # (P,)
    flat_w = top_p.reshape(-1).astype(jnp.float32)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    keep = ranks < C
    slot = flat_e * C + ranks                                     # (P,) in [0, E*C)
    slot = jnp.where(keep, slot, m.n_experts * C)                 # dropped → OOB
    # token index per (expert, capacity) slot; empty slots point at token 0
    # with weight 0 so they contribute nothing.
    slot_tok = jnp.zeros((m.n_experts * C + 1,), jnp.int32).at[slot].set(tok, mode="drop")
    slot_w = jnp.zeros((m.n_experts * C + 1,), jnp.float32).at[slot].set(flat_w, mode="drop")
    slot_tok, slot_w = slot_tok[:-1], slot_w[:-1]
    gathered = xt[slot_tok].reshape(m.n_experts, C, d)
    gathered = gathered * (slot_w.reshape(m.n_experts, C, 1) > 0)
    h = _expert_ffn(p, cfg, gathered)                             # (E, C, d)
    h = h * slot_w.reshape(m.n_experts, C, 1).astype(h.dtype)
    out = jnp.zeros((T, d), h.dtype).at[slot_tok.reshape(-1)].add(h.reshape(-1, d))
    return out


def _dispatch_dense(p, cfg: ModelConfig, xt, top_p, top_i, C: int):
    m = cfg.moe
    T, d = xt.shape
    flat_e, ranks = _pair_ranks(top_i, m.n_experts)
    keep = (ranks < C).astype(jnp.float32)
    # combine[t, e, c] = weight of token t in expert e's capacity slot c
    onehot_e = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.float32)
    onehot_c = jax.nn.one_hot(jnp.where(ranks < C, ranks, C), C + 1,
                              dtype=jnp.float32)[..., :C]
    pair = (onehot_e[:, :, None] * onehot_c[:, None, :]) * keep[:, None, None]
    combine = (pair * top_p.reshape(-1)[:, None, None]).reshape(T, m.top_k, m.n_experts, C).sum(1)
    dispatch = (combine > 0).astype(xt.dtype)                     # (T, E, C)
    expert_in = jnp.einsum("tec,td->ecd", dispatch, xt)
    h = _expert_ffn(p, cfg, expert_in)
    return jnp.einsum("tec,ecd->td", combine.astype(h.dtype), h)
