"""repro — Robatch: cost-effective LLM routing with batch prompting, on a multi-pod JAX stack.

Layout:
    repro.core       — the paper's contribution: cost model, proxy utility, greedy scheduler.
    repro.data       — workload generators, pool simulator, tokenizer, training pipeline.
    repro.models     — unified JAX LM stack (dense / MoE / RWKV6 / RG-LRU hybrid / VLM / enc-dec).
    repro.kernels    — Pallas TPU kernels (flash attention, decode attention, WKV6, RG-LRU).
    repro.training   — optimizer (AdamW + ZeRO-1), train loop, grad accumulation.
    repro.serving    — prefill/decode engine, KV cache, batch prompting, pools, fault handling.
    repro.checkpoint — atomic pytree checkpointing with reshard-on-load.
    repro.launch     — production mesh, multi-pod dry-run, train/serve CLIs.
    repro.analysis   — roofline terms from compiled artifacts.
    repro.configs    — one module per assigned architecture (exact published shapes).
"""

__version__ = "1.0.0"
