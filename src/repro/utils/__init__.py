from repro.utils.prng import PRNGFactory
from repro.utils.tree import tree_bytes, tree_size, tree_summary
