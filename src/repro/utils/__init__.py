from repro.utils.tree import tree_size, tree_bytes, tree_summary
from repro.utils.prng import PRNGFactory
