"""Deterministic PRNG key management.

Every subsystem takes keys from a named factory so that adding a new
parameter / data stream never silently reshuffles the randomness of an
unrelated one (folding by name, not by call order).
"""
from __future__ import annotations

import hashlib

import jax


def _name_to_int(name: str) -> int:
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")


class PRNGFactory:
    """Stable named PRNG keys: key(name) is a pure function of (seed, name)."""

    def __init__(self, seed: int = 0):
        self._root = jax.random.PRNGKey(seed)
        self.seed = seed

    def key(self, name: str) -> jax.Array:
        return jax.random.fold_in(self._root, _name_to_int(name))

    def keys(self, name: str, n: int):
        return jax.random.split(self.key(name), n)
