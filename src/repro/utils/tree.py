"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import numpy as np


def tree_size(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_bytes(tree) -> int:
    """Total byte footprint of a pytree (uses declared dtypes)."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def tree_summary(tree, name: str = "tree") -> str:
    n = tree_size(tree)
    b = tree_bytes(tree)
    return f"{name}: {n / 1e6:.2f}M params, {b / 2**30:.3f} GiB"
