"""Coreset selection over query embeddings (§4 + Table 3 sensitivity).

Three algorithms, matching the paper's sensitivity study: k-center greedy
(default, Gonzalez 1985), facility location (greedy submodular, Lin & Bilmes
2009) and herding (Welling 2009).
"""
from __future__ import annotations

import numpy as np

__all__ = ["kcenter_greedy", "facility_location", "herding", "select_coreset"]


def kcenter_greedy(emb: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """Greedy 2-approx of the k-center objective: maximize coverage radius."""
    n = len(emb)
    m = min(m, n)
    rng = np.random.default_rng(seed)
    chosen = [int(rng.integers(n))]
    d2 = np.sum((emb - emb[chosen[0]]) ** 2, axis=1)
    for _ in range(m - 1):
        nxt = int(np.argmax(d2))
        chosen.append(nxt)
        d2 = np.minimum(d2, np.sum((emb - emb[nxt]) ** 2, axis=1))
    return np.array(chosen)


def facility_location(emb: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """Greedy maximization of Σ_i max_{j∈S} sim(i, j) (submodular)."""
    n = len(emb)
    m = min(m, n)
    e = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-8)
    sim = e @ e.T                              # (n, n); fine at paper scale (≤2048)
    best = np.full(n, -np.inf)
    chosen: list[int] = []
    for _ in range(m):
        # candidate j's objective = Σ_i max(best_i, sim_ij)
        gains = np.sum(np.maximum(best[:, None], sim), axis=0)
        gains[chosen] = -np.inf
        j = int(np.argmax(gains))
        chosen.append(j)
        best = np.maximum(best, sim[:, j])
    return np.array(chosen)


def herding(emb: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """Herding: iteratively pick points matching the empirical mean."""
    n = len(emb)
    m = min(m, n)
    mu = emb.mean(axis=0)
    w = mu.copy()
    chosen: list[int] = []
    mask = np.zeros(n, bool)
    for _ in range(m):
        scores = emb @ w
        scores[mask] = -np.inf
        j = int(np.argmax(scores))
        chosen.append(j)
        mask[j] = True
        w = w + mu - emb[j]
    return np.array(chosen)


_METHODS = {"kcenter": kcenter_greedy, "fl": facility_location, "herding": herding}


def select_coreset(emb: np.ndarray, m: int, method: str = "kcenter", seed: int = 0) -> np.ndarray:
    """Positions (into `emb`) of the selected coreset Q''."""
    return _METHODS[method](np.asarray(emb, np.float64), m, seed)
