"""Utility-without-batching estimators û_{i,k,1} (§4, "Estimation of the
Utility Without Batching").

Two routers, exactly as in the paper: a three-layer MLP trained with
multi-label BCE over (query embedding → per-model correctness), and a KNN
classifier.  Both map a query embedding to a vector of K estimated utilities
in [0, 1].
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optimizer import adamw

__all__ = ["MLPRouter", "KNNRouter", "train_mlp_router"]


def _init_mlp(key, dims: Sequence[int]):
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (a, b), jnp.float32) * jnp.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
    return params


def _mlp_logits(params, x):
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    last = params[-1]
    return h @ last["w"] + last["b"]


@jax.jit
def _bce_loss(params, x, y):
    logits = _mlp_logits(params, x)
    z = jax.nn.log_sigmoid(logits)
    zc = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(y * z + (1 - y) * zc)


@dataclass
class MLPRouter:
    """Three-layer MLP multi-label classifier (paper default)."""

    params: list
    embed_dim: int
    n_models: int

    def predict(self, embeddings: np.ndarray) -> np.ndarray:
        """û_{i,k,1} ∈ [0,1]^{n×K}."""
        logits = _mlp_logits(self.params, jnp.asarray(embeddings, jnp.float32))
        return np.asarray(jax.nn.sigmoid(logits), dtype=np.float64)


def train_mlp_router(
    embeddings: np.ndarray,        # (n, d) training query embeddings
    labels: np.ndarray,            # (n, K) ground-truth u_{i,k,1} ∈ {0,1}
    hidden: Sequence[int] = (256, 128),
    lr: float = 1e-3,
    weight_decay: float = 1e-4,
    epochs: int = 60,
    batch_size: int = 256,
    seed: int = 0,
    val_frac: float = 0.1,
) -> MLPRouter:
    """Minimize multi-label BCE on Q' (§4); early selection on a val split."""
    x = jnp.asarray(embeddings, jnp.float32)
    y = jnp.asarray(labels, jnp.float32)
    n, d = x.shape
    k = y.shape[1]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_val = max(1, int(n * val_frac))
    vi, ti = perm[:n_val], perm[n_val:]

    params = _init_mlp(jax.random.PRNGKey(seed), (d, *hidden, k))
    opt = adamw(lr, weight_decay=weight_decay, grad_clip=1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(_bce_loss)(params, xb, yb)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    best = (np.inf, params)
    for epoch in range(epochs):
        order = rng.permutation(ti)
        for s in range(0, len(order), batch_size):
            sel = order[s:s + batch_size]
            params, state, _ = step(params, state, x[sel], y[sel])
        val = float(_bce_loss(params, x[vi], y[vi]))
        if val < best[0]:
            best = (val, jax.tree.map(jnp.copy, params))
    return MLPRouter(params=best[1], embed_dim=d, n_models=k)


@dataclass
class KNNRouter:
    """K-nearest-neighbour multi-label classifier (paper alternative)."""

    train_embeddings: np.ndarray   # (n, d), assumed L2-normalized
    train_labels: np.ndarray       # (n, K)
    k: int = 16

    def predict(self, embeddings: np.ndarray) -> np.ndarray:
        q = np.asarray(embeddings, dtype=np.float32)
        sims = q @ self.train_embeddings.T            # cosine (normalized)
        k = min(self.k, sims.shape[1])                # tiny train sets: k ≤ n
        nn = np.argpartition(-sims, k - 1, axis=1)[:, :k]
        return self.train_labels[nn].mean(axis=1).astype(np.float64)

    @property
    def n_models(self) -> int:
        return self.train_labels.shape[1]
