"""Robatch — the unified two-stage framework (§3 overview, §4 modeling, §5 routing).

Usage::

    rb = Robatch(pool, workload)
    rb.fit()                                  # modeling stage (offline, billed once)
    result = rb.schedule(test_idx, budget)    # routing stage (online)
    outcome = execute(pool, workload, result.assignment)   # commit batches

``pool`` is any sequence of members exposing ``c_in/c_out/context_len`` and
``invoke_batch(workload, idx) -> BatchResult`` — the calibrated simulator
(:mod:`repro.data.simulator`) or the real served pool
(:mod:`repro.serving.pool`) plug in interchangeably.
"""
from __future__ import annotations

import pickle
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.coreset import select_coreset
from repro.core.pareto import CandidateSpace, build_candidate_space
from repro.core.problem import Assignment, CostModel, group_into_batches
from repro.core.router import KNNRouter, train_mlp_router
from repro.core.scaling import ModelCalibration, ProfileCache, calibrate_model
from repro.core.scheduler import ScheduleResult, greedy_schedule, greedy_schedule_vectorized
from repro.data.workload import Workload

__all__ = ["Robatch", "ExecutionOutcome", "execute", "execute_plan", "collect_router_labels"]


@dataclass
class ExecutionOutcome:
    """Result of committing an assignment through real batched invocations."""

    accuracy: float              # mean utility over the workload (objective)
    exact_cost: float            # actual billed $ (Eq. 4 accounting, partial batches real)
    n_invocations: int
    per_query_utility: np.ndarray
    wall_clock_s: float = 0.0    # scheduling overhead only (excl. LLM latency), §6.1.3


def execute_plan(pool, wl: Workload, plan, query_idx: np.ndarray) -> ExecutionOutcome:
    """Commit a physical batch plan [(State, members)]: invoke, bill actual tokens."""
    util = np.zeros(len(query_idx))
    pos_of = {int(q): i for i, q in enumerate(query_idx)}
    cost = 0.0
    for state, members in plan:
        res = pool[state.model].invoke_batch(wl, members)
        cost += res.in_tokens * pool[state.model].c_in / 1e6
        cost += res.out_tokens * pool[state.model].c_out / 1e6
        for q, u in zip(members, res.utilities):
            util[pos_of[int(q)]] = u
    return ExecutionOutcome(
        accuracy=float(util.mean()),
        exact_cost=float(cost),
        n_invocations=len(plan),
        per_query_utility=util,
    )


def execute(pool, wl: Workload, a: Assignment) -> ExecutionOutcome:
    """Commit an assignment: pack per-state batches, invoke, bill actual tokens."""
    return execute_plan(pool, wl, group_into_batches(a), a.query_idx)


def collect_router_labels(pool, wl: Workload, idx: np.ndarray) -> np.ndarray:
    """Offline b=1 evaluation of all K models on Q' → ground-truth u_{i,k,1} (§4)."""
    idx = np.asarray(idx)
    labels = np.zeros((len(idx), len(pool)))
    for k, m in enumerate(pool):
        labels[:, k] = m.evaluate(wl, idx, batch_size=1)
    return labels


@dataclass
class Robatch:
    """The full framework; see module docstring."""

    pool: Sequence
    wl: Workload
    # modeling-stage hyper-parameters (§6.1.4 defaults)
    router_kind: str = "mlp"            # mlp | knn
    router_hidden: Sequence[int] = (256, 128)
    knn_k: int = 16
    coreset_method: str = "kcenter"
    coreset_size: int = 256
    epsilon: float = 0.01               # Eq. 9 threshold
    grid_multiple: int = 4
    scaling_fit: str = "piecewise"      # piecewise | powerlaw | knn
    seed: int = 0

    # fitted artifacts
    cost_model: CostModel = None
    router: object = None
    calibrations: list[ModelCalibration] = field(default_factory=list)
    profile: ProfileCache = None
    train_labels: np.ndarray = None
    _train_idx: np.ndarray = None

    # --------------------------------------------------------------- stage 1
    def fit(self, train_part: str = "train", labels: Optional[np.ndarray] = None) -> "Robatch":
        """Modeling stage: router on Q', coreset Q'', per-model calibration."""
        self.cost_model = CostModel(self.pool, self.wl)
        tr = self.wl.subset_indices(train_part)
        self._train_idx = tr
        # (1) ground-truth b=1 labels for Q' (offline evaluation of all K models)
        if labels is None:
            labels = collect_router_labels(self.pool, self.wl, tr)
        self.train_labels = labels
        # (2) router training (û_{i,k,1})
        emb_tr = self.wl.embeddings[tr]
        if self.router_kind == "mlp":
            self.router = train_mlp_router(emb_tr, labels, hidden=tuple(self.router_hidden),
                                           seed=self.seed)
        elif self.router_kind == "knn":
            self.router = KNNRouter(train_embeddings=emb_tr.astype(np.float32),
                                    train_labels=labels, k=self.knn_k)
        else:
            raise ValueError(self.router_kind)
        # (3) coreset Q'' ⊂ Q'
        core_pos = select_coreset(emb_tr, self.coreset_size, self.coreset_method, self.seed)
        core_idx = tr[core_pos]
        self.profile = ProfileCache(self.pool, self.wl, core_idx)
        # (4) per-model calibration: b_max (Eq. 10) → b_effect (ternary / Eq. 11)
        #     → scaling fit ρ_k (Eq. 12 default)
        self.calibrations = [
            calibrate_model(self.cost_model, self.profile, k, epsilon=self.epsilon,
                            grid_multiple=self.grid_multiple, fit=self.scaling_fit,
                            coreset_emb=self.wl.embeddings[core_idx])
            for k in range(len(self.pool))
        ]
        return self

    # --------------------------------------------------------------- stage 2
    def candidate_space(self, query_idx: np.ndarray,
                        timings: Optional[dict] = None) -> CandidateSpace:
        """Eq. 8/13 candidate space for a query set.

        When ``timings`` is passed, the §6.5 stage breakdown is written into
        it (``router``: û prediction, ``proxy``: space assembly).
        """
        assert self.router is not None, "call fit() first"
        t0 = time.perf_counter()
        emb = self.wl.embeddings[np.asarray(query_idx)]
        u_hat_1 = self.router.predict(emb)
        t1 = time.perf_counter()
        space = build_candidate_space(self.cost_model, self.calibrations,
                                      query_idx, u_hat_1, query_emb=emb)
        if timings is not None:
            timings["router"] = t1 - t0
            timings["proxy"] = time.perf_counter() - t1
        return space

    def schedule(self, query_idx: np.ndarray, budget: float,
                 scheduler: str = "heap",
                 timings: Optional[dict] = None) -> ScheduleResult:
        """Routing stage: greedy Pareto climb under the budget (Alg. 1).
        ``scheduler="vectorized"`` uses the beyond-paper round-based variant
        (near-identical objective, much faster at large |Q| — fig11).
        ``timings`` optionally collects the §6.5 router/proxy/greedy/total
        latency breakdown."""
        space = self.candidate_space(query_idx, timings=timings)
        fn = greedy_schedule_vectorized if scheduler == "vectorized" else greedy_schedule
        t0 = time.perf_counter()
        res = fn(space, query_idx, budget)
        if timings is not None:
            timings["greedy"] = time.perf_counter() - t0
            timings["total"] = (timings.get("router", 0.0)
                                + timings.get("proxy", 0.0) + timings["greedy"])
        return res

    def schedule_timed(self, query_idx: np.ndarray, budget: float,
                       scheduler: str = "heap"):
        """``schedule`` plus the §6.5 latency breakdown (same code path)."""
        timings: dict = {}
        res = self.schedule(query_idx, budget, scheduler=scheduler, timings=timings)
        return res, timings

    # ------------------------------------------------------------- lifecycle
    def save_profile(self, path: str) -> None:
        """Persist the fitted control-plane state (fault tolerance: a restarted
        scheduler reloads this instead of re-billing the modeling stage)."""
        state = dict(
            router_kind=self.router_kind,
            router=self.router,
            calibrations=self.calibrations,
            train_labels=self.train_labels,
            train_idx=self._train_idx,
            workload=self.wl.name,
            pool=[m.name for m in self.pool],
        )
        with open(path, "wb") as f:
            pickle.dump(state, f)

    def load_profile(self, path: str) -> "Robatch":
        with open(path, "rb") as f:
            state = pickle.load(f)
        assert state["workload"] == self.wl.name, "profile belongs to another workload"
        assert state["pool"] == [m.name for m in self.pool], "profile belongs to another pool"
        self.cost_model = CostModel(self.pool, self.wl)
        self.router = state["router"]
        self.calibrations = state["calibrations"]
        self.train_labels = state["train_labels"]
        self._train_idx = state["train_idx"]
        return self
