"""Batch-size calibration and utility scaling functions ρ_k(b) (§4).

Implements, per pool model m_k:
  * the largest batch size b_k^max from the system-prompt share threshold ε
    (Eq. 9 rearranged to Eq. 10);
  * profiling of coreset utility at candidate batch sizes (cached — every LLM
    invocation is billed);
  * the effective batch size b_k^effect as the RCU minimizer located by
    integer ternary search over the (unimodal) RCU curve (Eq. 11, Fig. 5);
  * three fits of ρ_k(b): piecewise-linear interpolation (Eq. 12, default),
    power-law 1 − α(b−1)^β (nonlinear least squares, no scipy needed), and
    KNN linear interpolation (query-specific, §6.4.4).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.problem import CostModel
from repro.data.workload import Workload

__all__ = [
    "batch_grid", "b_max_from_epsilon", "ProfileCache", "ternary_search_rcu",
    "PiecewiseLinearScaling", "PowerLawScaling", "KNNScaling", "fit_scaling",
    "ModelCalibration", "calibrate_model",
]


def b_max_from_epsilon(cm: CostModel, k: int, idx: np.ndarray, epsilon: float) -> int:
    """Eq. (10): b_k^max = ceil(C_sys(m_k)(1−ε) / (ε · E[C_q(m_k)]))."""
    c_sys = cm.sys_cost(k)
    e_q = cm.expected_query_cost(k, idx)
    return max(1, math.ceil(c_sys * (1 - epsilon) / (epsilon * e_q)))


def batch_grid(b_max: int, multiple: int = 4) -> np.ndarray:
    """Candidate batch sizes: {1, 2} ∪ multiples of `multiple` up to b_max.

    §6.1.4: "All batch size b_k ∈ B_k used are multiples of 4" (the paper's
    running example additionally uses b=2, which we keep for small pools).
    """
    grid = [1]
    if b_max >= 2:
        grid.append(2)
    grid.extend(range(multiple, b_max + 1, multiple))
    return np.unique(np.array(grid, dtype=int))


class ProfileCache:
    """Caches coreset utility profiling per (model, batch size).

    Every probe is a real (simulated or served) set of batched invocations on
    the coreset Q''; the cache guarantees the ternary search and the scaling
    fit never re-bill a probe (§4 complexity: O(C_API Σ log b_max)).
    """

    def __init__(self, pool, wl: Workload, coreset_idx: np.ndarray, rng_seed: int = 0):
        self.pool = pool
        self.wl = wl
        self.coreset_idx = np.asarray(coreset_idx)
        self._cache: dict[tuple[int, int], np.ndarray] = {}
        self.n_probes = 0
        self.billed_tokens = 0

    def utilities(self, k: int, b: int) -> np.ndarray:
        """Per-coreset-query utilities when served at batch size b on model k."""
        key = (k, int(b))
        if key not in self._cache:
            model = self.pool[k]
            out = np.zeros(len(self.coreset_idx))
            for s in range(0, len(self.coreset_idx), int(b)):
                chunk = self.coreset_idx[s:s + int(b)]
                res = model.invoke_batch(self.wl, chunk)
                out[s:s + len(chunk)] = res.utilities
                self.billed_tokens += res.in_tokens + res.out_tokens
            self._cache[key] = out
            self.n_probes += 1
        return self._cache[key]

    def mean_utility(self, k: int, b: int) -> float:
        """Mean utility at batch size b, measured over *full* batches only.

        A trailing partial batch runs at an effectively smaller batch size; in
        the collapsed regime its (higher) accuracy creates spurious bumps in
        the ū(b) tail that would break the unimodality of the RCU curve.
        """
        u = self.utilities(k, b)
        n_full = (len(self.coreset_idx) // int(b)) * int(b)
        return float(u[:n_full].mean()) if n_full else float(u.mean())


def rcu(cm: CostModel, cache: ProfileCache, k: int, b: int) -> float:
    """Eq. (11): (C_sys + b·E[C_q]) / E[utility of the batched prompt].

    The numerator is the expected cost of one *batched prompt* of size b; the
    denominator is that prompt's expected utility, i.e. the summed utilities
    of its b queries (b · E[u_{·,k,b}]).  Equivalently: amortized per-query
    cost divided by per-query utility — decreasing while amortization wins,
    increasing once utility collapses, hence the 'V' shape of Fig. 5.
    """
    num = cm.sys_cost(k) + b * cm.expected_query_cost(k, cache.coreset_idx)
    u = cache.mean_utility(k, b)
    if u <= 1e-9:
        # collapsed regime: no utility at any price.  Must be +inf — a finite
        # floor would make the tail slowly *decreasing* (num/b → E[C_q]) and
        # break the unimodality the ternary search relies on.
        return float("inf")
    return num / (b * u)


def ternary_search_rcu(cm: CostModel, cache: ProfileCache, k: int, grid: np.ndarray) -> int:
    """Integer ternary search for argmin RCU over the batch-size grid (Fig. 5).

    The RCU curve is unimodal ('V'-shaped, §4); search runs over grid indices
    so probes stay on valid batch sizes.
    """
    lo, hi = 0, len(grid) - 1
    while hi - lo > 2:
        m1 = lo + (hi - lo) // 3
        m2 = hi - (hi - lo) // 3
        if rcu(cm, cache, k, int(grid[m1])) <= rcu(cm, cache, k, int(grid[m2])):
            hi = m2 - 1
        else:
            lo = m1 + 1
    vals = [(rcu(cm, cache, k, int(grid[j])), int(grid[j])) for j in range(lo, hi + 1)]
    return min(vals)[1]


# ---------------------------------------------------------------------------
# Scaling function fits
# ---------------------------------------------------------------------------

@dataclass
class PiecewiseLinearScaling:
    """Eq. (12): piecewise-linear interpolation of ρ_k at profiled points."""

    bs: np.ndarray         # profiled batch sizes (ascending, bs[0] == 1)
    rho: np.ndarray        # ρ_k at those points (rho[0] == 1)

    def __call__(self, b) -> np.ndarray:
        return np.interp(np.asarray(b, dtype=float), self.bs, self.rho)


@dataclass
class PowerLawScaling:
    """ρ_k(b) = 1 − α(b−1)^β, fitted by nonlinear least squares (§6.4.4)."""

    alpha: float
    beta: float

    def __call__(self, b) -> np.ndarray:
        b = np.asarray(b, dtype=float)
        return np.clip(1.0 - self.alpha * np.maximum(b - 1.0, 0.0) ** self.beta, 0.0, 1.0)


@dataclass
class KNNScaling:
    """Query-specific ρ: average utilities of nearest coreset neighbours at
    each profiled batch size (§6.4.4, "KNN linear interpolation")."""

    coreset_emb: np.ndarray           # (m, d)
    bs: np.ndarray                    # profiled batch sizes
    util_table: np.ndarray            # (m, |bs|) coreset utilities per batch size
    k: int = 8

    def per_query(self, emb: np.ndarray) -> Callable[[np.ndarray], np.ndarray]:
        """Returns rho(b) -> (n,) for the given query embeddings."""
        sims = np.asarray(emb, np.float32) @ self.coreset_emb.T
        nn = np.argpartition(-sims, min(self.k, sims.shape[1] - 1), axis=1)[:, : self.k]
        curves = self.util_table[nn].mean(axis=1)             # (n, |bs|)
        base = np.maximum(curves[:, :1], 1e-6)
        curves = np.clip(curves / base, 0.0, 1.0)

        def rho(b):
            b = float(b)
            j = int(np.searchsorted(self.bs, b, side="right")) - 1
            if j >= len(self.bs) - 1:
                return curves[:, -1]
            lo_b, hi_b = self.bs[j], self.bs[j + 1]
            t = (b - lo_b) / max(hi_b - lo_b, 1e-9)
            return curves[:, j] * (1 - t) + curves[:, j + 1] * t
        return rho


def _eq12_smooth(bs: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Eq. (12) smoothing: value anchored at u[j-1] plus the (j+1, j-1) secant."""
    rho = np.empty_like(u)
    u1 = max(u[0], 1e-6)
    for j in range(len(bs)):
        jm = max(j - 1, 0)
        jp = min(j + 1, len(bs) - 1)
        if jp == jm:
            rho[j] = u[j] / u1
            continue
        slope = (u[jp] - u[jm]) / (bs[jp] - bs[jm])
        rho[j] = (u[jm] + (bs[j] - bs[jm]) * slope) / u1
    rho[0] = 1.0
    return np.clip(rho, 0.0, 1.2)


def fit_scaling(method: str, bs: np.ndarray, u: np.ndarray,
                coreset_emb: np.ndarray | None = None,
                util_table: np.ndarray | None = None):
    """Fit ρ_k(b) from coreset mean utilities u at batch sizes bs."""
    bs = np.asarray(bs, dtype=float)
    u = np.asarray(u, dtype=float)
    if method == "piecewise":
        return PiecewiseLinearScaling(bs, _eq12_smooth(bs, u))
    if method == "powerlaw":
        rho = np.clip(u / max(u[0], 1e-6), 0.0, 1.2)
        z = np.maximum(bs - 1.0, 0.0)
        mask = z > 0
        best = (np.inf, 0.0, 1.0)
        for beta in np.linspace(0.1, 3.0, 59):
            zz = z[mask] ** beta
            denom = float(zz @ zz)
            alpha = float(zz @ (1.0 - rho[mask]) / denom) if denom > 0 else 0.0
            alpha = max(alpha, 0.0)
            resid = float(np.sum((1.0 - alpha * zz - rho[mask]) ** 2))
            if resid < best[0]:
                best = (resid, alpha, beta)
        return PowerLawScaling(alpha=best[1], beta=best[2])
    if method == "knn":
        assert coreset_emb is not None and util_table is not None
        return KNNScaling(coreset_emb=coreset_emb, bs=bs, util_table=util_table)
    raise ValueError(f"unknown scaling fit {method!r}")


# ---------------------------------------------------------------------------
# Full per-model calibration
# ---------------------------------------------------------------------------

@dataclass
class ModelCalibration:
    """Everything the routing stage needs about one pool member."""

    k: int
    b_max: int
    b_effect: int
    grid: np.ndarray              # valid batch sizes B_k = grid ≤ b_effect
    scaling: object               # ρ_k(b) callable (or KNNScaling)
    u_mean_at: dict[int, float] = field(default_factory=dict)  # profiled means
    u_std_at: dict[int, float] = field(default_factory=dict)
    # ^ per-batch-size std of the profiled coreset utilities — the calibration
    #   residual σ_k(b) the robust frontier walk penalizes (utility − λ·σ).
    #   Defaulted so profiles pickled before this field existed still load.


def calibrate_model(
    cm: CostModel,
    cache: ProfileCache,
    k: int,
    epsilon: float = 0.01,
    grid_multiple: int = 4,
    fit: str = "piecewise",
    coreset_emb: np.ndarray | None = None,
) -> ModelCalibration:
    """§4 end-to-end for one model: b_max → ternary search → ρ_k fit."""
    b_max = b_max_from_epsilon(cm, k, cache.coreset_idx, epsilon)
    # cap by the model's context window: batch prompt must fit
    ctx = cm.pool[k].context_len
    mean_q = float(cm.wl.in_tokens[cache.coreset_idx].mean())
    b_ctx = max(1, int((0.9 * ctx - cm.wl.sys_tokens) // max(mean_q, 1.0)))
    # profiling can only measure batch sizes the coreset can fill
    b_max = min(b_max, b_ctx, len(cache.coreset_idx))
    grid = batch_grid(b_max, grid_multiple)
    b_eff = ternary_search_rcu(cm, cache, k, grid)
    valid = grid[grid <= b_eff]
    # profile every valid grid point (cached; ternary search already hit many)
    u = np.array([cache.mean_utility(k, int(b)) for b in valid])
    # σ_k(b): dispersion of the per-coreset-query utilities behind each mean,
    # over the same full batches mean_utility averages — the uncertainty the
    # robust frontier walk (scheduler robust_lambda) penalizes
    u_sd = []
    for b in valid:
        uu = cache.utilities(k, int(b))
        n_full = (len(cache.coreset_idx) // int(b)) * int(b)
        uu = uu[:n_full] if n_full else uu
        u_sd.append(float(uu.std()))
    util_table = None
    if fit == "knn":
        util_table = np.stack([cache.utilities(k, int(b)) for b in valid], axis=1)
    scaling = fit_scaling(fit, valid, u, coreset_emb=coreset_emb, util_table=util_table)
    return ModelCalibration(
        k=k, b_max=b_max, b_effect=int(b_eff), grid=valid, scaling=scaling,
        u_mean_at={int(b): float(x) for b, x in zip(valid, u)},
        u_std_at={int(b): float(s) for b, s in zip(valid, u_sd)},
    )
