"""Greedy budget-constrained scheduling — Algorithm 1 (§5).

Implementations:
  * ``greedy_schedule``          — faithful Alg. 1: heap keyed by Δ (Eq. 14).
  * ``greedy_schedule_window``   — windowed/online entry point: restricts the
    candidate space to the surviving models (circuit breaking) and re-anchors
    the initial state, then runs Alg. 1 over one admission window against the
    rolling-budget slice handed down by :mod:`repro.serving.online`.
  * ``brute_force_schedule``     — exact enumeration for micro instances; used
    by the property tests to bound greedy sub-optimality and to validate the
    NP-hardness reduction (Thm. 3.2).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.core.pareto import CandidateSpace, build_frontiers
from repro.core.problem import Assignment

__all__ = ["ScheduleResult", "greedy_schedule", "greedy_schedule_vectorized",
           "greedy_schedule_window", "greedy_schedule_capped", "restrict_space",
           "take_rows", "brute_force_schedule", "attach_free_assignments"]


@dataclass
class ScheduleResult:
    assignment: Assignment
    est_utility: float           # Σ û at the chosen states (objective, Eq. 5)
    amortized_cost: float        # Σ Eq. 13 costs — what the budget tracked
    spent_budget: float          # budget consumed (== amortized_cost)
    n_upgrades: int
    infeasible: bool             # initial assignment alone exceeded the budget
    deferred_idx: np.ndarray = field(default_factory=lambda: np.empty(0, dtype=int))
    # ^ query ids pushed out of this window by per-member capacity caps
    #   (``group_caps``); the online server requeues them for the next round
    n_packed: int = 0
    # ^ queries the capacity-aware pass moved to a wider batch (or another
    #   member) to fit the caps — the autoscaler's packing-pressure signal
    deferred_by_member: dict = field(default_factory=dict)
    # ^ model index → how many of ``deferred_idx`` ITS cap pushed out; keys
    #   the backlog to the bottleneck member so a later autoscaler can grow
    #   only it (Σ values == len(deferred_idx))
    packed_by_member: dict = field(default_factory=dict)
    # ^ model index → queries the capacity pass moved off (or within) that
    #   over-cap member (Σ values == n_packed)
    n_free: int = 0
    # ^ zero-cost assignments folded into this round's accounting after the
    #   frontier walk (semantic-cache hits priced at cost=0, utility
    #   u·(1−ε(sim)) — see attach_free_assignments)
    free_utility: float = 0.0
    # ^ Σ discounted utility of those free assignments (already included in
    #   est_utility once attached)


def attach_free_assignments(res: ScheduleResult,
                            utilities) -> ScheduleResult:
    """Fold zero-cost assignments into a window's schedule accounting.

    A semantic-cache hit serves a query at zero marginal cost with utility
    ``u·(1−ε(sim))`` — the same (cost, utility) currency the frontier walk
    optimizes, just with a degenerate cost column.  The online server calls
    this after :func:`greedy_schedule_window` so the round's ``est_utility``
    covers the hits exactly like any committed upgrade, while
    ``amortized_cost``/``spent_budget`` are untouched (free assignments draw
    nothing from the bucket)."""
    utilities = [float(u) for u in utilities]
    res.n_free += len(utilities)
    res.free_utility += sum(utilities)
    res.est_utility += sum(utilities)
    return res


def _robust_view(space: CandidateSpace, robust_lambda: float,
                 cost_margin: float) -> tuple[np.ndarray, np.ndarray]:
    """(walk_cost, walk_util) the robust frontier walk decides on.

    ``robust_lambda`` penalizes each state's proxy utility by λ·σ (its
    calibration-residual std, :attr:`CandidateSpace.sigma`) — upgrades whose
    estimated gain rests on noisy calibration stop looking attractive.
    ``cost_margin`` inflates every cost by (1 + margin): the walk draws the
    budget down at worst-case prices, so a realized cost overrun up to the
    margin still lands inside the window's slice.  At λ=0 and margin=0 the
    original matrices are returned UNCHANGED (same objects), so the default
    path stays bit-identical to the point-estimate walk (property-tested).
    """
    lam = float(robust_lambda)
    walk_util = (space.util - lam * space.sigma
                 if lam > 0.0 and space.sigma is not None else space.util)
    mfac = 1.0 + float(cost_margin)
    walk_cost = space.cost * mfac if mfac != 1.0 else space.cost
    return walk_cost, walk_util


def greedy_schedule(
    space: CandidateSpace,
    query_idx: np.ndarray,
    budget: float,
    robust_lambda: float = 0.0,
    cost_margin: float = 0.0,
) -> ScheduleResult:
    """Algorithm 1.

    Every query starts at s(0) = (m_1, b_1^effect); the priority queue holds
    (−Δ, query, frontier position); upgrades are committed while budget
    remains.  A popped-but-unaffordable upgrade drops the query from the queue
    (Alg. 1 line 11–12).  Note this drop is *lossless*, not just faithful: the
    remaining budget is monotonically decreasing and frontier costs are
    ascending, so an upgrade that is unaffordable now can never become
    affordable later, and no later state of the same query can be cheaper.

    ``robust_lambda``/``cost_margin`` switch on the uncertainty-robust walk
    (see :func:`_robust_view`): frontiers, Δ gains and budget feasibility use
    the penalized utility and worst-case cost, while the returned
    ``est_utility``/``amortized_cost`` stay in raw (point-estimate) currency —
    ``spent_budget`` is the worst-case draw the walk committed to.
    """
    query_idx = np.asarray(query_idx)
    n = len(query_idx)
    walk_cost, walk_util = _robust_view(space, robust_lambda, cost_margin)
    if walk_cost is space.cost and walk_util is space.util:
        frontiers = build_frontiers(space)
    else:
        frontiers = build_frontiers(CandidateSpace(
            states=space.states, cost=walk_cost, util=walk_util,
            initial_state=space.initial_state))
    cost, util = walk_cost, walk_util

    # position of each query along its frontier (0 == initial state)
    pos = np.zeros(n, dtype=int)
    remaining = budget
    for i in range(n):
        remaining -= cost[i, frontiers[i][0]]
    infeasible = remaining < 0

    heap: list[tuple[float, int, int]] = []   # (−Δ, i, next_pos)

    def push_next(i: int):
        fr = frontiers[i]
        t = pos[i]
        if t + 1 >= len(fr):
            return
        s_now, s_next = fr[t], fr[t + 1]
        dc = cost[i, s_next] - cost[i, s_now]
        du = util[i, s_next] - util[i, s_now]
        delta = du / max(dc, 1e-12)           # Eq. 14
        heapq.heappush(heap, (-delta, i, t + 1))

    for i in range(n):
        push_next(i)

    upgrades = 0
    while heap and remaining > 0:
        _neg_delta, i, t = heapq.heappop(heap)
        if t != pos[i] + 1:
            continue                           # stale entry
        fr = frontiers[i]
        inc = cost[i, fr[t]] - cost[i, fr[t - 1]]
        if remaining - inc < 0:
            continue                           # Alg. 1 line 11–12 (lossless drop)
        pos[i] = t
        remaining -= inc
        upgrades += 1
        push_next(i)

    chosen = np.array([frontiers[i][pos[i]] for i in range(n)])
    model = np.array([space.states[j].model for j in chosen])
    batch = np.array([space.states[j].batch for j in chosen])
    est_u = float(space.util[np.arange(n), chosen].sum())
    amort = float(space.cost[np.arange(n), chosen].sum())
    return ScheduleResult(
        assignment=Assignment(query_idx=query_idx, model=model, batch=batch),
        est_utility=est_u,
        amortized_cost=amort,
        spent_budget=budget - remaining if not infeasible else amort,
        n_upgrades=upgrades,
        infeasible=bool(infeasible),
    )


def greedy_schedule_vectorized(
    space: CandidateSpace,
    query_idx: np.ndarray,
    budget: float,
    rounds: int = 64,
    robust_lambda: float = 0.0,
    cost_margin: float = 0.0,
) -> ScheduleResult:
    """Beyond-paper: round-based vectorized variant of Alg. 1.

    The paper's own latency breakdown (Fig. 12) shows the heap loop dominates
    scheduling time.  This variant commits upgrades in ROUNDS: each round
    computes every query's next-transition Δ (vectorized), argsorts once, and
    commits the affordable prefix in Δ order.  Within a round a query commits
    at most one upgrade, so the ordering differs from the global heap only
    when a query's *successive* Δs straddle other queries' — rare on real
    frontiers (Δ decreases along a frontier by construction of Pareto
    dominance).  Objective parity is property-tested ≥ heap·(1−ε); speed is
    benchmarked in fig11.
    """
    query_idx = np.asarray(query_idx)
    n = len(query_idx)
    walk_cost, walk_util = _robust_view(space, robust_lambda, cost_margin)
    if walk_cost is space.cost and walk_util is space.util:
        frontiers = build_frontiers(space)
    else:
        frontiers = build_frontiers(CandidateSpace(
            states=space.states, cost=walk_cost, util=walk_util,
            initial_state=space.initial_state))
    max_t = max(len(f) for f in frontiers)
    # pad frontiers into a dense (n, max_t) matrix of state columns
    fr = np.full((n, max_t), -1, dtype=int)
    for i, f in enumerate(frontiers):
        fr[i, : len(f)] = f
    fr_len = np.array([len(f) for f in frontiers])
    rows = np.arange(n)
    costs = np.where(fr >= 0, walk_cost[rows[:, None], np.maximum(fr, 0)], np.inf)
    utils = np.where(fr >= 0, walk_util[rows[:, None], np.maximum(fr, 0)], -np.inf)

    pos = np.zeros(n, dtype=int)
    remaining = budget - costs[:, 0].sum()
    infeasible = remaining < 0
    upgrades = 0
    for _ in range(rounds):
        has_next = pos + 1 < fr_len
        nxt = np.minimum(pos + 1, max_t - 1)
        inc = np.where(has_next, costs[rows, nxt] - costs[rows, pos], np.inf)
        du = np.where(has_next, utils[rows, nxt] - utils[rows, pos], -np.inf)
        with np.errstate(invalid="ignore"):
            delta = np.where(has_next, du / np.maximum(inc, 1e-12), -np.inf)
        order = np.argsort(-delta, kind="stable")
        inc_sorted = inc[order]
        valid = np.isfinite(inc_sorted)
        csum = np.cumsum(np.where(valid, inc_sorted, 0.0))
        affordable = valid & (csum <= remaining + 1e-12) & (delta[order] > 0)
        take = order[affordable]
        if len(take) == 0:
            break
        pos[take] += 1
        remaining -= inc[take].sum()
        upgrades += len(take)
    chosen = fr[rows, pos]
    model = np.array([space.states[j].model for j in chosen])
    batch = np.array([space.states[j].batch for j in chosen])
    est_u = float(space.util[rows, chosen].sum())
    amort = float(space.cost[rows, chosen].sum())
    return ScheduleResult(
        assignment=Assignment(query_idx=query_idx, model=model, batch=batch),
        est_utility=est_u, amortized_cost=amort,
        spent_budget=budget - remaining if not infeasible else amort,
        n_upgrades=upgrades, infeasible=bool(infeasible))


def restrict_space(space: CandidateSpace, allowed_models: set[int]) -> CandidateSpace:
    """Project a candidate space onto the states of ``allowed_models``.

    This is how circuit breaking reaches the scheduler: an open breaker
    removes every (m_k, b) state of the tripped model from the decision space,
    so rescheduled queries can only land on surviving models.  The initial
    state is re-anchored to the cheapest surviving column (total cost over the
    window) — if m_1 itself tripped, the upgrade chain now starts at the
    cheapest surviving model's state, preserving Alg. 1's anchor invariant.
    """
    keep = [j for j, s in enumerate(space.states) if s.model in allowed_models]
    if not keep:
        raise ValueError("restrict_space: no states survive the model mask")
    cost = space.cost[:, keep]
    util = space.util[:, keep]
    if space.states[space.initial_state].model in allowed_models:
        initial = keep.index(space.initial_state)
    else:
        initial = int(np.argmin(cost.sum(axis=0)))
    return CandidateSpace(states=[space.states[j] for j in keep],
                          cost=cost, util=util, initial_state=initial,
                          sigma=(space.sigma[:, keep]
                                 if space.sigma is not None else None))


def take_rows(space: CandidateSpace, rows: np.ndarray) -> CandidateSpace:
    """Row-subset of a candidate space (admission control keeps a prefix of
    the window; the deferred suffix is rescheduled next tick)."""
    rows = np.asarray(rows)
    return CandidateSpace(states=space.states, cost=space.cost[rows],
                          util=space.util[rows], initial_state=space.initial_state,
                          sigma=(space.sigma[rows]
                                 if space.sigma is not None else None))


def _apply_group_caps(res: ScheduleResult, space: CandidateSpace,
                      group_caps: dict[int, int]) -> ScheduleResult:
    """Enforce per-member batch-group capacity on a window's schedule.

    Safety net only: the capacity-aware walk (:func:`greedy_schedule_capped`,
    ``cap_mode="pack"``) packs caps into the schedule itself; this post-pass
    survives for ``cap_mode="defer"`` and for caps-unaware policies whose
    plans the online server has to bound after the fact.

    A member backed by N replicas can run N batch-groups concurrently, so one
    admission window may commit at most ``group_caps[k]`` groups to model k.
    The assignment is packed exactly like :func:`group_into_batches` (chunks
    of b per state); over-cap models keep their highest-estimated-utility
    groups and the rest are *deferred* — returned via ``deferred_idx`` so the
    server retries them next window (capacity backpressure, the same shape as
    budget backpressure — never a drop)."""
    a = res.assignment
    n = len(a.query_idx)
    state_col = {(s.model, s.batch): j for j, s in enumerate(space.states)}
    rows_by_state: dict[tuple[int, int], list[int]] = {}
    for i in range(n):
        rows_by_state.setdefault((int(a.model[i]), int(a.batch[i])), []).append(i)
    by_model: dict[int, list[tuple[float, list[int]]]] = {}
    for (k, b), rows in rows_by_state.items():
        j = state_col[(k, b)]
        for s in range(0, len(rows), b):
            chunk = rows[s:s + b]
            by_model.setdefault(k, []).append((float(space.util[chunk, j].sum()), chunk))
    overflow: list[int] = []
    deferred_by: dict[int, int] = {}
    for k, groups in by_model.items():
        cap = group_caps.get(k)
        if cap is None or len(groups) <= cap:
            continue
        groups.sort(key=lambda g: -g[0])          # stable: ties keep FCFS order
        for _u, chunk in groups[cap:]:
            overflow.extend(chunk)
            deferred_by[int(k)] = deferred_by.get(int(k), 0) + len(chunk)
    if not overflow:
        return res
    keep = np.setdiff1d(np.arange(n), np.asarray(overflow))
    chosen = np.array([state_col[(int(a.model[i]), int(a.batch[i]))] for i in keep],
                      dtype=int)
    return ScheduleResult(
        assignment=Assignment(query_idx=a.query_idx[keep], model=a.model[keep],
                              batch=a.batch[keep]),
        est_utility=float(space.util[keep, chosen].sum()),
        amortized_cost=float(space.cost[keep, chosen].sum()),
        spent_budget=float(space.cost[keep, chosen].sum()),
        n_upgrades=res.n_upgrades,
        infeasible=res.infeasible,
        deferred_idx=np.asarray(a.query_idx)[np.sort(np.asarray(overflow))],
        deferred_by_member=deferred_by,
    )


def _group_demand(model: np.ndarray, batch: np.ndarray) -> dict[int, int]:
    """Physical batch-groups each member must run for an assignment:
    ``Σ_b ceil(n_{k,b} / b)`` — exactly how :func:`group_into_batches` chunks."""
    demand: dict[int, int] = {}
    for k in np.unique(model):
        mask_k = model == k
        g = 0
        for b in np.unique(batch[mask_k]):
            g += int(np.ceil(int((mask_k & (batch == b)).sum()) / int(b)))
        demand[int(k)] = g
    return demand


def greedy_schedule_capped(
    space: CandidateSpace,
    query_idx: np.ndarray,
    budget: float,
    group_caps: dict[int, int],
    scheduler: str = "heap",
    robust_lambda: float = 0.0,
    cost_margin: float = 0.0,
) -> ScheduleResult:
    """Capacity-aware Alg. 1: pack the window instead of deferring it.

    The frontier walk runs unconstrained first; when the resulting schedule
    demands more concurrent batch-groups of a member than its cap (its
    healthy-replica count), the capacity pass re-scores that member's states
    toward *fewer, larger batches*:

    1. **Merge** — the narrowest batch in use on an over-cap member is folded
       into its next-wider sibling state (Eq. 13 cost is decreasing in b, so
       every merge refunds budget; group count is non-increasing and the
       number of distinct states strictly decreases, so the loop terminates).
    2. **Spill** — demand that exceeds even the widest packing
       (``n_k > cap_k · b_max``) moves the lowest-û overflow queries to the
       cheapest affordable state of a member with spare group capacity.
    3. **Defer** — only what neither packing nor spilling can place comes back
       in ``deferred_idx`` (the online server requeues it next window).

    When no cap binds the result is **bit-identical** to the uncapped
    schedule (property-tested), so caps cost nothing on the happy path.
    ``n_packed`` counts queries steps 1–2 moved — the capacity-pressure
    signal :class:`repro.serving.autoscale.Autoscaler` scales on.
    """
    query_idx = np.asarray(query_idx)
    fn = greedy_schedule_vectorized if scheduler == "vectorized" else greedy_schedule
    res = fn(space, query_idx, budget, robust_lambda=robust_lambda,
             cost_margin=cost_margin)
    caps = {int(k): int(c) for k, c in group_caps.items() if c is not None}
    a = res.assignment
    if all(d <= caps.get(k, d) for k, d in _group_demand(a.model, a.batch).items()):
        return res                                  # caps never bind: untouched
    # the packing passes keep deciding in the walk's currency: worst-case
    # prices draw the refunded budget down, penalized utilities rank the
    # spill victims.  mfac == 1.0 and walk_util is space.util at the default
    # λ=0/margin=0, so those paths stay bit-identical to the prior code.
    mfac = 1.0 + float(cost_margin)
    _, walk_util = _robust_view(space, robust_lambda, cost_margin)

    n = len(a.query_idx)
    state_col = {(s.model, s.batch): j for j, s in enumerate(space.states)}
    col = np.array([state_col[(int(a.model[i]), int(a.batch[i]))]
                    for i in range(n)], dtype=int)
    cols_of: dict[int, list[int]] = {}              # model → cols, batch asc
    for j, s in enumerate(space.states):
        cols_of.setdefault(int(s.model), []).append(j)
    for k in cols_of:
        cols_of[k].sort(key=lambda j: space.states[j].batch)

    active = np.ones(n, dtype=bool)
    remaining = budget - res.amortized_cost * mfac
    n_packed = 0
    deferred_rows: list[int] = []
    # both keyed by the OVER-CAP member whose cap forced the move/defer (the
    # bottleneck signal), not by where a spilled query happened to land
    packed_by: dict[int, int] = {}
    deferred_by: dict[int, int] = {}

    def used_counts(k: int) -> dict[int, int]:
        out = {}
        for j in cols_of[k]:
            c = int((active & (col == j)).sum())
            if c:
                out[j] = c
        return out

    def demand_of(k: int) -> int:
        return sum(int(np.ceil(c / space.states[j].batch))
                   for j, c in used_counts(k).items())

    def fits_one_more(k: int, j: int) -> bool:
        cap = caps.get(k)
        if cap is None:
            return True
        b = space.states[j].batch
        at_j = int((active & (col == j)).sum())
        extra = 1 if at_j % b == 0 else 0           # a new group only at multiples
        return demand_of(k) + extra <= cap

    for k in sorted(caps):
        if k not in cols_of:
            continue                                # model absent from this space
        cap = caps[k]
        # 1. merge: narrowest state in use → its next-wider sibling
        while demand_of(k) > cap:
            merged = False
            for j in sorted(used_counts(k), key=lambda j: space.states[j].batch):
                wider = [w for w in cols_of[k]
                         if space.states[w].batch > space.states[j].batch]
                if not wider:
                    continue
                w = wider[0]
                rows = np.where(active & (col == j))[0]
                remaining += float((space.cost[rows, j] - space.cost[rows, w]).sum()) * mfac
                col[rows] = w
                n_packed += len(rows)
                packed_by[k] = packed_by.get(k, 0) + len(rows)
                merged = True
                break
            if not merged:
                break                               # everything at the widest state
        over = demand_of(k) - cap
        if over <= 0:
            continue
        # 2./3. spill overflow beyond cap·b_max to members with headroom
        jw = cols_of[k][-1]
        rows_k = np.where(active & (col == jw))[0]
        order = rows_k[np.argsort(walk_util[rows_k, jw], kind="stable")]
        n_keep = max(0, cap) * int(space.states[jw].batch)
        for i in order[: max(0, len(rows_k) - n_keep)]:
            remaining += float(space.cost[i, jw]) * mfac   # refund the vacated state
            active[i] = False
            placed = False
            cand = [j for kk, js in cols_of.items() if kk != k for j in js]
            cand.sort(key=lambda j: float(space.cost[i, j]))
            for j in cand:
                kk = int(space.states[j].model)
                if caps.get(kk, 1) <= 0 or not fits_one_more(kk, j):
                    continue
                if float(space.cost[i, j]) * mfac > remaining + 1e-12:
                    continue
                col[i] = j
                active[i] = True
                remaining -= float(space.cost[i, j]) * mfac
                n_packed += 1
                packed_by[k] = packed_by.get(k, 0) + 1
                placed = True
                break
            if not placed:
                deferred_rows.append(int(i))
                deferred_by[k] = deferred_by.get(k, 0) + 1

    keep = np.where(active)[0]
    chosen = col[keep]
    model = np.array([space.states[j].model for j in chosen], dtype=int)
    batch = np.array([space.states[j].batch for j in chosen], dtype=int)
    dropped = np.sort(np.asarray(deferred_rows, dtype=int))
    return ScheduleResult(
        assignment=Assignment(query_idx=a.query_idx[keep], model=model, batch=batch),
        est_utility=float(space.util[keep, chosen].sum()),
        amortized_cost=float(space.cost[keep, chosen].sum()),
        spent_budget=float(space.cost[keep, chosen].sum()),
        n_upgrades=res.n_upgrades,
        infeasible=res.infeasible,
        deferred_idx=np.asarray(a.query_idx)[dropped],
        n_packed=n_packed,
        deferred_by_member=deferred_by,
        packed_by_member=packed_by,
    )


def greedy_schedule_window(
    space: CandidateSpace,
    query_idx: np.ndarray,
    budget: float,
    allowed_models: set[int] | None = None,
    group_caps: dict[int, int] | None = None,
    scheduler: str = "heap",
    cap_mode: str = "pack",
    robust_lambda: float = 0.0,
    cost_margin: float = 0.0,
) -> ScheduleResult:
    """One online scheduling round: Alg. 1 over a single admission window.

    The offline algorithm sees the whole test set and the whole budget; the
    online server calls this once per deadline window with (a) the queries
    that arrived inside the window and (b) the budget slice currently in the
    token bucket.  The frontier machinery is reused unchanged — only the
    candidate space is restricted to surviving models first.

    ``group_caps`` maps model index → max batch-groups this window (a
    replicated member's replica count — see
    :class:`repro.serving.pool.ReplicaSet`).  A cap of 0 removes the model
    from the window's space outright (all replicas down).  ``cap_mode="pack"``
    (the default) takes the caps into the frontier walk itself via
    :func:`greedy_schedule_capped` — over-cap members are re-packed into
    fewer, larger batches and only the truly unplaceable remainder is
    deferred; ``cap_mode="defer"`` keeps the legacy
    :func:`_apply_group_caps` post-pass (the safety net caps-unaware policies
    fall back to), which defers every over-cap group wholesale.  Either way
    the pushed-out queries come back via ``ScheduleResult.deferred_idx``.
    ``scheduler`` picks the Alg. 1 variant (``"heap"`` or ``"vectorized"``,
    as offline).
    """
    if group_caps:
        saturated = {k for k, cap in group_caps.items() if cap is not None and cap <= 0}
        if saturated:
            candidates = (set(allowed_models) if allowed_models is not None
                          else {s.model for s in space.states})
            allowed_models = candidates - saturated
            if not allowed_models:
                # every member saturated: the whole window is capacity-
                # deferred (backpressure, not a crash — retried next round)
                qi = np.asarray(query_idx)
                empty = Assignment(query_idx=qi[:0],
                                   model=np.empty(0, dtype=int),
                                   batch=np.empty(0, dtype=int))
                return ScheduleResult(assignment=empty, est_utility=0.0,
                                      amortized_cost=0.0, spent_budget=0.0,
                                      n_upgrades=0, infeasible=False,
                                      deferred_idx=qi.copy())
    if allowed_models is not None:
        space = restrict_space(space, set(allowed_models))
    if group_caps and cap_mode == "pack":
        return greedy_schedule_capped(space, query_idx, budget, group_caps,
                                      scheduler=scheduler,
                                      robust_lambda=robust_lambda,
                                      cost_margin=cost_margin)
    fn = greedy_schedule_vectorized if scheduler == "vectorized" else greedy_schedule
    res = fn(space, query_idx, budget, robust_lambda=robust_lambda,
             cost_margin=cost_margin)
    if group_caps:
        res = _apply_group_caps(res, space, group_caps)
    return res


def brute_force_schedule(space: CandidateSpace, query_idx: np.ndarray,
                         budget: float) -> ScheduleResult:
    """Exact optimum by enumeration over the *pruned frontiers* (micro instances).

    Exponential — guarded to ≤ ~2M combinations; tests use n ≤ 8, |frontier| ≤ 5.
    """
    query_idx = np.asarray(query_idx)
    n = len(query_idx)
    frontiers = build_frontiers(space)
    sizes = [len(f) for f in frontiers]
    n_comb = int(np.prod(sizes))
    if n_comb > 2_000_000:
        raise ValueError(f"instance too large for brute force: {n_comb} combinations")
    cost, util = space.cost, space.util
    best_u, best_choice = -np.inf, None
    for combo in itertools.product(*[range(s) for s in sizes]):
        c = sum(cost[i, frontiers[i][t]] for i, t in enumerate(combo))
        if c > budget + 1e-9:
            continue
        u = sum(util[i, frontiers[i][t]] for i, t in enumerate(combo))
        if u > best_u:
            best_u, best_choice = u, combo
    if best_choice is None:                    # even all-initial is infeasible
        best_choice = tuple(0 for _ in range(n))
        best_u = sum(util[i, frontiers[i][0]] for i in range(n))
    chosen = np.array([frontiers[i][t] for i, t in enumerate(best_choice)])
    model = np.array([space.states[j].model for j in chosen])
    batch = np.array([space.states[j].batch for j in chosen])
    amort = float(cost[np.arange(n), chosen].sum())
    return ScheduleResult(
        assignment=Assignment(query_idx=query_idx, model=model, batch=batch),
        est_utility=float(best_u),
        amortized_cost=amort,
        spent_budget=amort,
        n_upgrades=0,
        infeasible=amort > budget + 1e-9,
    )
