"""Adapted baselines (§6.1.2) and ablation variants (§6.3).

Routing baselines (RouteLLM, FrugalGPT) are made batch-capable by grouping the
queries routed to each model into fixed-size batches; batching baselines
(BATCHER-SIM/DIV, OBP) reuse Robatch's own non-batched router for model
assignment and then apply their grouping strategy — exactly the paper's
adaptation protocol.

Ablations: Router-Only (B_k = {1}) and Batch-Only (single fixed model m_k,
scheduling restricted to its batch-size space).
"""
from __future__ import annotations

import hashlib
from dataclasses import replace as dc_replace

import numpy as np

from repro.core.problem import Assignment, CostModel, State
from repro.core.robatch import ExecutionOutcome, Robatch
from repro.data.workload import Workload

__all__ = [
    "single_model_assignment", "vanilla_router_assignment", "routellm_assignment",
    "frugalgpt_execute", "batcher_group", "batcher_assignment_plan",
    "obp_group", "obp_plan", "router_only", "batch_only", "kmeans",
]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def kmeans(x: np.ndarray, k: int, iters: int = 25, seed: int = 0) -> np.ndarray:
    """Plain numpy k-means (cluster ids) — fully vectorized (scatter-add
    center updates; the naive per-cluster loop is O(k·n) Python at 16k-query
    scale, fig11)."""
    rng = np.random.default_rng(seed)
    x = np.asarray(x, np.float64)
    k = max(1, min(k, len(x)))
    centers = x[rng.choice(len(x), k, replace=False)]
    assign = np.zeros(len(x), dtype=int)
    x_sq = (x ** 2).sum(1)
    for _ in range(iters):
        d2 = x_sq[:, None] - 2.0 * (x @ centers.T) + (centers ** 2).sum(1)[None, :]
        new_assign = d2.argmin(1)
        if np.array_equal(new_assign, assign):
            break
        assign = new_assign
        sums = np.zeros_like(centers)
        np.add.at(sums, assign, x)
        counts = np.bincount(assign, minlength=k).astype(np.float64)
        nonzero = counts > 0
        centers[nonzero] = sums[nonzero] / counts[nonzero, None]
    return assign


def _stable_coin(tag: str, idx: np.ndarray) -> np.ndarray:
    h = int.from_bytes(hashlib.sha256(tag.encode()).digest()[:8], "little")
    x = (np.asarray(idx, dtype=np.uint64) + np.uint64(h)) * np.uint64(0x9E3779B97F4A7C15)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xC2B2AE3D27D4EB4F)
    x ^= x >> np.uint64(29)
    return (x >> np.uint64(11)).astype(np.float64) / float(1 << 53)


# ---------------------------------------------------------------------------
# single-model + vanilla-router reference points (Fig. 2)
# ---------------------------------------------------------------------------

def single_model_assignment(query_idx: np.ndarray, k: int, b: int) -> Assignment:
    query_idx = np.asarray(query_idx)
    return Assignment(query_idx=query_idx,
                      model=np.full(len(query_idx), k, dtype=int),
                      batch=np.full(len(query_idx), b, dtype=int))


def vanilla_router_assignment(rb: Robatch, query_idx: np.ndarray, tau: float,
                              b: int = 1) -> Assignment:
    """Cheapest model predicted correct with confidence ≥ τ; else best-û model."""
    query_idx = np.asarray(query_idx)
    u = rb.router.predict(rb.wl.embeddings[query_idx])          # (n, K)
    model = np.where(u.max(1) >= tau, (u >= tau).argmax(1), u.argmax(1))
    return Assignment(query_idx=query_idx, model=model.astype(int),
                      batch=np.full(len(query_idx), b, dtype=int))


# ---------------------------------------------------------------------------
# RouteLLM (adapted): strong/weak threshold router + fixed-size batching
# ---------------------------------------------------------------------------

def routellm_assignment(rb: Robatch, query_idx: np.ndarray, tau: float, b: int) -> Assignment:
    """Route to the weak (cheapest) model when its predicted win-rate ≥ τ,
    otherwise the strong (most capable) model; then batch per model at size b."""
    query_idx = np.asarray(query_idx)
    u = rb.router.predict(rb.wl.embeddings[query_idx])
    weak, strong = 0, u.shape[1] - 1
    model = np.where(u[:, weak] >= tau, weak, strong)
    return Assignment(query_idx=query_idx, model=model.astype(int),
                      batch=np.full(len(query_idx), b, dtype=int))


# ---------------------------------------------------------------------------
# FrugalGPT (adapted): cascade with a scorer; per-level fixed-size batching
# ---------------------------------------------------------------------------

def frugalgpt_execute(rb: Robatch, query_idx: np.ndarray, tau: float, b: int) -> ExecutionOutcome:
    """LLM cascade: invoke cheap→expensive, accept when the scorer approves.

    FrugalGPT's scorer is a *learned* utility estimator over the response
    (Chen et al. 2024); per the §6.1.2 adaptation protocol it shares Robatch's
    router as that estimator: accept at level k iff û_{i,k,1} ≥ τ (plus a
    small response-conditioned refinement — the scorer sees the generation,
    which carries a weak extra signal).  Billing accumulates every attempted
    level, which is exactly why cascades lose to routing at tight budgets.
    """
    wl, pool = rb.wl, rb.pool
    query_idx = np.asarray(query_idx)
    u_hat = rb.router.predict(wl.embeddings[query_idx])    # (n, K)
    hat_of = {int(q): u_hat[i] for i, q in enumerate(query_idx)}
    remaining = query_idx.copy()
    util = np.zeros(len(query_idx))
    pos_of = {int(q): i for i, q in enumerate(query_idx)}
    cost = 0.0
    n_inv = 0
    for k in range(len(pool)):
        if len(remaining) == 0:
            break
        last = k == len(pool) - 1
        accepted_mask = np.zeros(len(remaining), dtype=bool)
        for s in range(0, len(remaining), b):
            chunk = remaining[s:s + b]
            res = pool[k].invoke_batch(wl, chunk)
            n_inv += 1
            cost += res.in_tokens * pool[k].c_in / 1e6 + res.out_tokens * pool[k].c_out / 1e6
            # scorer: router estimate refined by a weak response-quality signal
            noise = _stable_coin(f"frugal::{pool[k].name}", chunk) - 0.5
            score = np.array([hat_of[int(q)][k] for q in chunk]) \
                + 0.05 * (res.utilities - 0.5) + 0.05 * noise
            take = (score >= tau) | last
            for q, u, t in zip(chunk, res.utilities, take):
                if t:
                    util[pos_of[int(q)]] = u
            accepted_mask[s:s + len(chunk)] = take
        remaining = remaining[~accepted_mask]
    return ExecutionOutcome(accuracy=float(util.mean()), exact_cost=float(cost),
                            n_invocations=n_inv, per_query_utility=util)


# ---------------------------------------------------------------------------
# BATCHER-SIM / BATCHER-DIV (adapted): router assignment + clustered batching
# ---------------------------------------------------------------------------

def batcher_group(wl: Workload, a: Assignment, b: int, mode: str = "sim",
                  seed: int = 0) -> list[tuple[State, np.ndarray]]:
    """Batches per model from k-means clusters over a fixed model assignment:
    SIM fills batches within a cluster, DIV round-robins across clusters
    (Fan et al., ICDE'24).  Shared by the legacy entry point and the
    ``batcher-sim``/``batcher-div`` registered policies (offline and per
    online window)."""
    plan = []
    for k in np.unique(a.model):
        members = a.query_idx[a.model == k]
        emb = wl.embeddings[members]
        n_clusters = max(1, len(members) // max(b, 1))
        cl = kmeans(emb, n_clusters, seed=seed)
        if mode == "sim":
            order = np.argsort(cl, kind="stable")
        elif mode == "div":
            # round-robin: sort by (rank within cluster, cluster)
            rank = np.zeros(len(members), dtype=int)
            for j in np.unique(cl):
                rank[cl == j] = np.arange((cl == j).sum())
            order = np.lexsort((cl, rank))
        else:
            raise ValueError(mode)
        ordered = members[order]
        for s in range(0, len(ordered), b):
            plan.append((State(int(k), b), ordered[s:s + b]))
    return plan


def batcher_assignment_plan(rb: Robatch, query_idx: np.ndarray, tau: float, b: int,
                            mode: str = "sim", seed: int = 0):
    """Model per query from Robatch's router (threshold τ), then
    :func:`batcher_group` clustering per model."""
    a = vanilla_router_assignment(rb, query_idx, tau, b)
    return a, batcher_group(rb.wl, a, b, mode=mode, seed=seed)


# ---------------------------------------------------------------------------
# OBP (adapted): adaptive clustering + refinement, variable batch sizes
# ---------------------------------------------------------------------------

def obp_group(wl: Workload, pool, a: Assignment, target_b: int,
              seed: int = 0) -> list[tuple[State, np.ndarray]]:
    """OBP grouping over a fixed model assignment: cluster related queries,
    refine groups to balance affinity / context length (Ji et al., VLDB'25
    adaptation).  Shared by the legacy entry point and the ``obp`` policy."""
    plan = []
    for k in np.unique(a.model):
        members = a.query_idx[a.model == k]
        emb = wl.embeddings[members]
        ctx = pool[k].context_len
        n_clusters = max(1, len(members) // max(target_b, 1))
        cl = kmeans(emb, n_clusters, seed=seed)
        for j in np.unique(cl):
            group = members[cl == j]
            # refinement: split groups whose prompt would overflow the window
            # or exceed 2× the target size; merge is implicit via cluster count
            mean_in = max(wl.in_tokens[group].mean(), 1)
            max_by_ctx = max(1, int((0.8 * ctx - wl.sys_tokens) // mean_in))
            cap = min(2 * target_b, max_by_ctx)
            for s in range(0, len(group), cap):
                chunk = group[s:s + cap]
                plan.append((State(int(k), len(chunk)), chunk))
    return plan


def obp_plan(rb: Robatch, query_idx: np.ndarray, tau: float, target_b: int,
             seed: int = 0):
    """Optimized Batch Prompting: router model assignment, then
    :func:`obp_group` adaptive clustering with variable batch sizes."""
    a = vanilla_router_assignment(rb, query_idx, tau, target_b)
    return a, obp_group(rb.wl, rb.pool, a, target_b, seed=seed)


# ---------------------------------------------------------------------------
# Ablations (§6.3)
# ---------------------------------------------------------------------------

def router_only(rb: Robatch) -> Robatch:
    """Robatch with B_k = {1}: pure model selection, no amortization."""
    clone = dc_replace(rb)
    clone.calibrations = [
        dc_replace(c, grid=np.array([1]), b_effect=1) for c in rb.calibrations
    ]
    return clone


def batch_only(rb: Robatch, k: int) -> Robatch:
    """Robatch restricted to model m_k: scheduling over its batch sizes only.

    The initial state becomes (m_k, b_k^effect): we re-index the pool to the
    single member so the scheduler's "cheapest model" is m_k itself.
    """
    sub_pool = [rb.pool[k]]
    clone = Robatch(pool=sub_pool, wl=rb.wl, router_kind=rb.router_kind, seed=rb.seed)
    clone.cost_model = CostModel(sub_pool, rb.wl)
    cal = dc_replace(rb.calibrations[k], k=0)
    clone.calibrations = [cal]
    clone.profile = rb.profile
    clone.train_labels = rb.train_labels[:, [k]] if rb.train_labels is not None else None

    class _SliceRouter:
        def __init__(self, base, col):
            self.base, self.col = base, col

        def predict(self, emb):
            return self.base.predict(emb)[:, [self.col]]

    clone.router = _SliceRouter(rb.router, k)
    return clone
