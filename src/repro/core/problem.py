"""Route-with-Batching problem: states, cost model (Eqs. 1–4, 13), assignments.

This module is deliberately framework-free (numpy only): the scheduler is a
host-side control-plane algorithm, exactly as deployed in the paper (§6.5 runs
it on a CPU next to the serving cluster).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Protocol, Sequence

import numpy as np

from repro.data.workload import Workload

__all__ = ["State", "PoolMember", "CostModel", "Assignment", "group_into_batches"]


class State(NamedTuple):
    """An execution state s = (m_k, b): model index and batch size (§3)."""

    model: int
    batch: int


class PoolMember(Protocol):
    """What the scheduler needs to know about an LLM pool member."""

    name: str
    c_in: float          # $ per 1M input tokens
    c_out: float         # $ per 1M output tokens
    context_len: int


@dataclass
class Assignment:
    """A full solution x_{i,k,b}: one state per query (Eq. 6)."""

    query_idx: np.ndarray    # (n,) workload indices this assignment covers
    model: np.ndarray        # (n,) int model index k
    batch: np.ndarray        # (n,) int batch size b

    def states(self) -> list[State]:
        return [State(int(k), int(b)) for k, b in zip(self.model, self.batch)]

    def __len__(self) -> int:
        return len(self.query_idx)


class CostModel:
    """Monetary cost accounting per Eqs. (1), (2), (4) and (13).

    Token prices are $ / 1M tokens (API convention); costs are dollars.
    """

    def __init__(self, pool: Sequence[PoolMember], wl: Workload):
        self.pool = list(pool)
        self.wl = wl
        self.K = len(self.pool)
        self._c_in = np.array([m.c_in for m in self.pool]) / 1e6
        self._c_out = np.array([m.c_out for m in self.pool]) / 1e6

    # -- Eq. (2) -------------------------------------------------------------
    def sys_cost(self, k: int) -> float:
        """C_sys(m_k): fixed system-prompt cost of one invocation of m_k."""
        return float(self.wl.sys_tokens * self._c_in[k])

    def query_cost(self, k: int, idx: np.ndarray) -> np.ndarray:
        """C_{q_i}(m_k): per-query input+output token cost (vectorized)."""
        idx = np.asarray(idx)
        return (self.wl.in_tokens[idx] * self._c_in[k]
                + self.wl.out_tokens[idx] * self._c_out[k])

    def expected_query_cost(self, k: int, idx: np.ndarray) -> float:
        """E_{q_i}[C_{q_i}(m_k)] over a query set (used by Eqs. 9–11)."""
        return float(self.query_cost(k, idx).mean())

    # -- Eq. (13): amortized per-query state cost ----------------------------
    def state_cost(self, k: int, b: int, idx: np.ndarray) -> np.ndarray:
        """C_{q_i}(s) = C_sys/b + C_{q_i}(m_k)."""
        return self.sys_cost(k) / b + self.query_cost(k, idx)

    def amortized_total(self, a: Assignment) -> float:
        """Σ_i C_{q_i}(s(q_i)) — the budget the greedy scheduler tracks."""
        total = 0.0
        for k in range(self.K):
            for b in np.unique(a.batch[a.model == k]):
                sel = (a.model == k) & (a.batch == b)
                total += float(self.state_cost(k, int(b), a.query_idx[sel]).sum())
        return total

    # -- Eq. (4): exact cost with ceiling over physical invocations ----------
    def exact_total(self, a: Assignment) -> float:
        """Σ_k Σ_b ceil(N_{k,b}/b)·C_sys(m_k) + Σ C_{q_i}(m_k)."""
        total = 0.0
        for k in range(self.K):
            mask_k = a.model == k
            for b in np.unique(a.batch[mask_k]):
                sel = mask_k & (a.batch == b)
                n_kb = int(sel.sum())
                total += np.ceil(n_kb / b) * self.sys_cost(k)
                total += float(self.query_cost(k, a.query_idx[sel]).sum())
        return total

    # -- workload-level reference points -------------------------------------
    def single_model_cost(self, k: int, idx: np.ndarray, b: int = 1) -> float:
        """Cost of serving `idx` entirely on model k at batch size b (Eq. 4)."""
        idx = np.asarray(idx)
        n_inv = np.ceil(len(idx) / b)
        return float(n_inv * self.sys_cost(k) + self.query_cost(k, idx).sum())


def group_into_batches(a: Assignment,
                       order: np.ndarray | None = None) -> list[tuple[State, np.ndarray]]:
    """Pack queries sharing a state into physical batches of that state's size.

    Returns [(state, workload-index array)] — the commit plan the serving
    engine executes.  ``order`` optionally permutes queries first (e.g. by
    similarity for BATCHER-SIM-style packing).
    """
    plan: list[tuple[State, np.ndarray]] = []
    pos = np.arange(len(a)) if order is None else np.asarray(order)
    model, batch, qidx = a.model[pos], a.batch[pos], a.query_idx[pos]
    for k in np.unique(model):
        for b in np.unique(batch[model == k]):
            sel = (model == k) & (batch == b)
            members = qidx[sel]
            for s in range(0, len(members), int(b)):
                plan.append((State(int(k), int(b)), members[s:s + int(b)]))
    return plan
