"""Candidate states, dominance pruning and per-query Pareto frontiers (§5).

Def. 5.1: state s' dominates s on q_i iff cost(s') ≤ cost(s) and û(s') ≥ û(s).
Thm. 5.3 proves pruning dominated states is lossless under amortized per-query
cost (Eq. 13) — property-tested in tests/test_scheduler.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.problem import CostModel, State
from repro.core.scaling import KNNScaling, ModelCalibration

__all__ = ["CandidateSpace", "pareto_frontier", "build_frontiers"]


@dataclass
class CandidateSpace:
    """All candidate states (m_k, b) with per-query cost and proxy utility."""

    states: list[State]           # B̃ = Σ_k |B_k| states
    cost: np.ndarray              # (n, B̃) amortized per-query cost, Eq. 13
    util: np.ndarray              # (n, B̃) proxy utility û_{i,k,b}, Eq. 8
    initial_state: int            # column index of s(0) = (m_1, b_1^effect)
    sigma: np.ndarray | None = None
    # ^ (n, B̃) per-state utility uncertainty (calibration-residual std of the
    #   proxy, ModelCalibration.u_std_at broadcast over queries); None when
    #   the calibration predates the robust walk — the scheduler's robust
    #   mode (utility − λ·σ) degrades to the point-estimate walk then


def build_candidate_space(
    cm: CostModel,
    calibrations: Sequence[ModelCalibration],
    query_idx: np.ndarray,
    u_hat_1: np.ndarray,          # (n, K) router estimates û_{i,k,1}
    query_emb: np.ndarray | None = None,
) -> CandidateSpace:
    """Assemble Eq. 8 proxies and Eq. 13 costs for every (query, state)."""
    query_idx = np.asarray(query_idx)
    n = len(query_idx)
    states: list[State] = []
    cost_cols: list[np.ndarray] = []
    util_cols: list[np.ndarray] = []
    sigma_cols: list[np.ndarray] = []
    initial = -1
    for cal in calibrations:
        k = cal.k
        if isinstance(cal.scaling, KNNScaling):
            assert query_emb is not None, "KNN scaling needs query embeddings"
            rho_fn = cal.scaling.per_query(query_emb)
        else:
            rho_fn = None
        u_std_at = getattr(cal, "u_std_at", {}) or {}
        for b in cal.grid:
            b = int(b)
            states.append(State(k, b))
            cost_cols.append(cm.state_cost(k, b, query_idx))
            if rho_fn is not None:
                rho = rho_fn(b)                      # (n,) query-specific
            else:
                rho = float(np.asarray(cal.scaling(b)))
            util_cols.append(np.clip(u_hat_1[:, k] * rho, 0.0, 1.0))
            sigma_cols.append(np.full(n, float(u_std_at.get(b, 0.0))))
        if k == 0:
            initial = states.index(State(0, int(cal.b_effect)))
    assert initial >= 0, "cheapest model must provide its effective batch size"
    return CandidateSpace(
        states=states,
        cost=np.stack(cost_cols, axis=1),
        util=np.stack(util_cols, axis=1),
        initial_state=initial,
        sigma=np.stack(sigma_cols, axis=1),
    )


def pareto_frontier(cost: np.ndarray, util: np.ndarray, keep: int | None = None) -> np.ndarray:
    """Indices of non-dominated states, sorted by ascending cost.

    A state is dominated if another has (cost ≤, util ≥) with at least one
    strict; ties keep the first occurrence. O(B̃ log B̃).
    """
    order = np.lexsort((-util, cost))          # by cost asc, then util desc
    frontier: list[int] = []
    best_u = -np.inf
    for j in order:
        if util[j] > best_u + 1e-12:
            frontier.append(int(j))
            best_u = float(util[j])
    if keep is not None and keep >= 0:
        # force-include a state (the initial state) even if dominated, as the
        # algorithm anchors the upgrade chain there (it is globally cheapest
        # for m_1's b_effect so in practice it is already on the frontier).
        if keep not in frontier:
            frontier = sorted(set(frontier) | {keep}, key=lambda j: (cost[j], -util[j]))
    return np.array(frontier, dtype=int)


def build_frontiers(space: CandidateSpace) -> list[np.ndarray]:
    """Per-query Pareto frontiers over the candidate space (Fig. 6)."""
    out = []
    for i in range(space.cost.shape[0]):
        fr = pareto_frontier(space.cost[i], space.util[i], keep=space.initial_state)
        # drop frontier entries cheaper than the initial state: the upgrade
        # chain starts at s(0) (it has the globally lowest cost; anything
        # cheaper could only exist through degenerate pricing and is unusable
        # as an "upgrade").
        start = np.where(fr == space.initial_state)[0][0]
        out.append(fr[start:])
    return out
