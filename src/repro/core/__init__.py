"""The paper's primary contribution: the Route-with-Batching problem and the
Robatch two-stage solution (modeling + greedy Pareto routing)."""

from repro.core.coreset import select_coreset
from repro.core.pareto import (
    CandidateSpace,
    build_candidate_space,
    build_frontiers,
    pareto_frontier,
)
from repro.core.problem import Assignment, CostModel, State, group_into_batches
from repro.core.robatch import (
    ExecutionOutcome,
    Robatch,
    collect_router_labels,
    execute,
    execute_plan,
)
from repro.core.router import KNNRouter, MLPRouter, train_mlp_router
from repro.core.scaling import (
    ModelCalibration,
    ProfileCache,
    b_max_from_epsilon,
    batch_grid,
    calibrate_model,
    fit_scaling,
    ternary_search_rcu,
)
from repro.core.scheduler import (
    ScheduleResult,
    brute_force_schedule,
    greedy_schedule,
    greedy_schedule_window,
    restrict_space,
)
