"""Tiny real model pool: three dense LMs of ascending capacity.

These are *actually trained and served* on CPU by examples/serve_pool.py —
the real-execution counterpart of the paper's Qwen3 4B/14B/32B API pool.
"""
from repro.config import ModelConfig, register_arch

TINY_POOL = [
    register_arch(ModelConfig(
        name=f"tiny-{tag}", family="dense", n_layers=nl, d_model=dm, n_heads=nh,
        n_kv_heads=nh, d_ff=4 * dm, vocab_size=512, rope_theta=10_000.0,
        dtype="float32", source="repro:tiny-pool"))
    for tag, nl, dm, nh in [("s", 2, 64, 2), ("m", 4, 128, 4), ("l", 4, 192, 6)]
]
