"""Qwen3-30B-A3B — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.config import ModelConfig, MoEConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                       # routed expert width (moe_intermediate_size)
    vocab_size=151_936,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
    activation="swiglu",
    norm="rmsnorm",
    qk_norm=True,                   # Qwen3 q/k RMSNorm
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
))
