"""RecurrentGemma-9B — RG-LRU + local attention, 1:2 [arXiv:2402.19427; unverified]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,                    # 12 × (rec, rec, local-attn) + 2 rec tail
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,                   # MQA
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=4096,
    conv_width=4,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    source="arXiv:2402.19427",
))
