"""Assigned architecture configs (exact published shapes) + tiny real pool.

Each module registers one ModelConfig under its assignment id; smoke tests use
``cfg.reduced()``; the dry-run exercises the full shapes abstractly.
"""
from repro.configs import (  # noqa: F401
    nemotron_4_340b,
    qwen1_5_0_5b,
    qwen1_5_4b,
    qwen2_moe_a2_7b,
    qwen2_vl_72b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    rwkv6_3b,
    seamless_m4t_large_v2,
    stablelm_1_6b,
    tiny_pool,
)
