"""Qwen1.5-0.5B — dense, QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151_936,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
))
