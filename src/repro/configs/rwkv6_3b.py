"""RWKV6 "Finch" 3B — attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                     # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,                      # 3.5 × d_model channel-mix width
    vocab_size=65_536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    rope_type="none",
    norm="layernorm",
    source="arXiv:2404.05892",
))
