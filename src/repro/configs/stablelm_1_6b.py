"""StableLM-2-1.6B — partial rotary (25%), LayerNorm
[hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    activation="swiglu",
    norm="layernorm",
    rotary_pct=0.25,
    rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
))
