"""SeamlessM4T-large-v2 backbone — enc-dec, multimodal [arXiv:2308.11596; hf].

The audio frontend is a STUB: input_specs() provides precomputed frame
embeddings for the 24-layer encoder; the 24-layer decoder (self + cross
attention) is fully implemented.  The assignment's "24L" is read as the
per-stack depth of the encoder-decoder.
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                    # decoder layers
    n_encoder_layers=24,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    activation="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    frontend="audio",
    source="arXiv:2308.11596",
))
