"""Qwen1.5-4B — dense, QKV bias [hf:Qwen/Qwen1.5-4B; hf]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151_936,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=5_000_000.0,
    source="hf:Qwen/Qwen1.5-4B",
))
