"""Qwen2-VL-72B backbone — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings; the trunk (with 3-section M-RoPE) is fully implemented.
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),    # t/h/w sections of head_dim/2
    rope_theta=1_000_000.0,
    frontend="vision",
    source="arXiv:2409.12191",
))
