"""Qwen1.5-MoE-A2.7B — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.config import ModelConfig, MoEConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                      # routed expert width
    vocab_size=151_936,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408,
                  n_shared_experts=4, d_shared=5632),   # shared width = 4×1408
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
