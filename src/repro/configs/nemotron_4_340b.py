"""Nemotron-4-340B — GQA, squared-ReLU MLP [arXiv:2402.16819; unverified]."""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab_size=256_000,
    activation="relu2",             # squared ReLU, ungated
    norm="layernorm",
    rotary_pct=0.5,
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
))
