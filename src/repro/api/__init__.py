"""Unified control-plane API: policy registry, declarative specs, gateway.

The three pieces (see docs/architecture.md, docs/policies.md):

* :mod:`repro.api.policy` — the :class:`SchedulingPolicy` protocol, the
  :class:`Plan` it produces, and the ``@register_policy`` registry.
* :mod:`repro.api.policies` — RoBatch (heap + vectorized), the five adapted
  baselines and both ablations, ported onto the protocol.  Importing
  :mod:`repro.api` registers all of them.
* :mod:`repro.api.specs` / :mod:`repro.api.gateway` — ``RunSpec`` declarative
  experiments and the ``Gateway`` facade running them offline or online.
"""

from repro.api import policies as _policies  # noqa: F401 — registers built-ins
from repro.api.gateway import Gateway
from repro.api.policy import (
    Plan,
    SchedulingPolicy,
    UnknownPolicyError,
    amortized_group_costs,
    fit_artifacts,
    get_policy,
    list_policies,
    register_policy,
)
from repro.api.specs import PolicySpec, PoolSpec, RunSpec

__all__ = [
    "Plan", "SchedulingPolicy", "UnknownPolicyError", "amortized_group_costs",
    "fit_artifacts", "get_policy", "list_policies", "register_policy",
    "PolicySpec", "PoolSpec", "RunSpec", "Gateway",
]
