"""Scheduling-policy protocol + registry — the control plane's open API.

A *policy* answers the two-dimensional routing question the paper poses —
which model AND which batch size for every query — behind one interface, so
RoBatch itself, every adapted baseline and any user-written strategy are
interchangeable at every call site (offline commit, online serving, the
``serve`` CLI, the benchmarks)::

    pol = get_policy("frugalgpt")(tau=0.6, b=8)
    pol.fit(pool, workload, artifacts=rb)       # modeling artifacts shared
    outcome = pol.run(test_idx, budget)         # plan + commit

The modeling-stage artifacts (router, per-model calibrations, cost model,
profiling cache) are fitted ONCE — as a fitted :class:`repro.core.robatch.
Robatch`, which acts as the artifact bundle — and handed to every policy via
``fit(..., artifacts=...)``; policies never re-bill the modeling stage.

Registering a strategy is one decorator::

    @register_policy("my-strategy")
    class MyStrategy(SchedulingPolicy):
        def plan(self, query_idx, budget=None, timings=None): ...

See docs/policies.md for a complete ~20-line example.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.pareto import CandidateSpace
from repro.core.problem import State, group_into_batches
from repro.core.robatch import ExecutionOutcome, Robatch, execute_plan
from repro.core.scheduler import ScheduleResult, greedy_schedule_window

__all__ = ["Plan", "SchedulingPolicy", "UnknownPolicyError", "register_policy",
           "get_policy", "list_policies", "fit_artifacts", "amortized_group_costs"]


@dataclass
class Plan:
    """What a policy decided for a query set: the physical commit plan plus
    its predicted (amortized) utility/cost accounting.

    ``groups`` is the ``[(State, members)]`` batch plan the executor commits;
    ``group_costs`` aligns with it (amortized Eq. 13 dollars per group) so the
    online server can bill held-back groups correctly.  Adaptive policies
    (FrugalGPT's cascade) cannot separate planning from execution — they
    return ``groups=None`` with ``adaptive=True`` and realize the plan inside
    :meth:`SchedulingPolicy.commit`.
    """

    query_idx: np.ndarray
    groups: Optional[list[tuple[State, np.ndarray]]]
    group_costs: Optional[list[float]] = None
    est_utility: Optional[float] = None
    est_cost: Optional[float] = None
    schedule: Optional[ScheduleResult] = None   # present for Alg.-1 policies
    adaptive: bool = False
    deferred_idx: Optional[np.ndarray] = None   # capacity-deferred query ids
    # (windowed plans under per-member group caps; the server requeues these)


def amortized_group_costs(cost_model, groups) -> list[float]:
    """Eq. 13 amortized dollars per physical group of a commit plan."""
    return [float(cost_model.state_cost(int(s.model), int(s.batch), members).sum())
            for s, members in groups]


def fit_artifacts(pool: Sequence, wl, **robatch_kwargs) -> Robatch:
    """Fit the shared modeling-stage artifacts (router, calibrations, cost
    model, profiling cache) once; the fitted Robatch IS the artifact bundle."""
    return Robatch(pool, wl, **robatch_kwargs).fit()


class SchedulingPolicy:
    """Base class / protocol for pluggable routing-with-batching strategies.

    Lifecycle: construct with strategy params → :meth:`fit` against a pool and
    workload (reusing shared artifacts when provided) → :meth:`plan` /
    :meth:`commit` / :meth:`run` offline, or :meth:`window_space` /
    :meth:`plan_window` per admission window from the online server.

    Subclasses must implement :meth:`plan`; everything else has working
    defaults.  ``exec_pool`` is the member list plans refer to by model index
    — the shared pool for most policies, a single-member view for the
    batch-only ablation.
    """

    name: str = ""                  # filled by @register_policy
    requires_budget: bool = False   # True: plan() needs a budget to be useful
    cap_mode: str = "pack"          # replica-cap handling in plan_window:
    #   "pack"  — capacity-aware Δ-heap (greedy_schedule_capped): over-cap
    #             members are re-packed into fewer, larger batches, and only
    #             the unplaceable remainder is deferred;
    #   "defer" — legacy _apply_group_caps post-pass (defer every over-cap
    #             group wholesale) — the safety-net semantics the online
    #             server also applies to caps-unaware policies
    robust: float = 0.0             # λ of the uncertainty-robust walk: each
    #   state's proxy utility is penalized by λ·σ (calibration-residual std);
    #   0 keeps the point-estimate walk bit-identical
    cost_margin: float = 0.0        # worst-case budget margin: the walk draws
    #   the window budget down at cost·(1+margin)

    # fitted attributes (set by fit())
    rb: Optional[Robatch] = None
    pool: Optional[list] = None
    wl = None
    exec_pool: Optional[list] = None
    cm = None        # cost model matching exec_pool's member indexing

    # ------------------------------------------------------------------ fit
    def fit(self, pool: Sequence, wl, artifacts: Optional[Robatch] = None,
            **fit_kwargs) -> "SchedulingPolicy":
        """Bind the policy to a pool/workload.  ``artifacts`` is a fitted
        :class:`Robatch` (the shared modeling bundle); without one the policy
        fits its own with ``fit_kwargs`` forwarded to ``Robatch``."""
        self.pool = list(pool)
        self.wl = wl
        if artifacts is None:
            artifacts = fit_artifacts(self.pool, wl, **fit_kwargs)
        assert artifacts.router is not None, "artifacts must be fitted"
        self.rb = artifacts
        self.exec_pool = self.pool
        self.cm = artifacts.cost_model
        self._post_fit()
        return self

    def _post_fit(self) -> None:
        """Hook for derived state (ablation clones, cached spaces, ...)."""

    # ------------------------------------------------------------- offline
    def plan(self, query_idx: np.ndarray, budget: Optional[float] = None,
             timings: Optional[dict] = None) -> Plan:
        """Decide (model, batch) for every query; optionally fill a latency
        breakdown into ``timings`` (at minimum ``total``)."""
        raise NotImplementedError

    def plan_timed(self, query_idx: np.ndarray,
                   budget: Optional[float] = None) -> tuple[Plan, dict]:
        """Instrumented :meth:`plan` — works for ANY registered policy; the
        Robatch family refines it with the §6.5 router/proxy/greedy split."""
        timings: dict = {}
        t0 = time.perf_counter()
        plan = self.plan(query_idx, budget, timings=timings)
        timings.setdefault("total", time.perf_counter() - t0)
        return plan, timings

    def commit(self, plan: Plan) -> ExecutionOutcome:
        """Execute a plan against ``exec_pool``, billing actual tokens."""
        assert plan.groups is not None, f"{self.name}: plan has no groups"
        return execute_plan(self.exec_pool, self.wl, plan.groups, plan.query_idx)

    def run(self, query_idx: np.ndarray,
            budget: Optional[float] = None) -> ExecutionOutcome:
        """plan + commit in one call (what ``Gateway.submit`` invokes)."""
        return self.commit(self.plan(query_idx, budget))

    # -------------------------------------------------------------- online
    def window_space(self, query_idx: np.ndarray) -> CandidateSpace:
        """Per-query candidate states for one admission window.  The online
        server restricts this to surviving (breaker-closed) models, runs
        budget admission against the initial-state column, and hands the
        restricted space back to :meth:`plan_window`."""
        raise NotImplementedError(f"{self.name} does not support online serving")

    def plan_window(self, space: CandidateSpace, query_idx: np.ndarray,
                    budget: float, caps: Optional[dict] = None) -> Plan:
        """One online scheduling round over a (restricted) window space.
        Default: windowed Alg. 1 + per-state batch packing.  ``caps`` maps
        model index → max batch-groups this window (replicated members'
        concurrency, :class:`repro.serving.pool.ReplicaSet`), handled per
        ``cap_mode`` (capacity-aware packing by default); query ids that
        still don't fit come back in ``Plan.deferred_idx`` for the server to
        requeue."""
        res = greedy_schedule_window(space, query_idx, budget, group_caps=caps,
                                     cap_mode=self.cap_mode,
                                     robust_lambda=self.robust,
                                     cost_margin=self.cost_margin)
        groups = group_into_batches(res.assignment)
        return Plan(query_idx=np.asarray(query_idx), groups=groups,
                    group_costs=amortized_group_costs(self.cm, groups),
                    est_utility=res.est_utility, est_cost=res.amortized_cost,
                    schedule=res, deferred_idx=res.deferred_idx)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type[SchedulingPolicy]] = {}


class UnknownPolicyError(KeyError):
    """Raised by :func:`get_policy` for names that were never registered."""


def register_policy(name: str):
    """Class decorator: make a :class:`SchedulingPolicy` subclass available
    as ``get_policy(name)`` (and thereby to the Gateway, the online server,
    ``serve --policy`` and the smoke suite)."""

    def deco(cls: type[SchedulingPolicy]) -> type[SchedulingPolicy]:
        if not (isinstance(cls, type) and issubclass(cls, SchedulingPolicy)):
            raise TypeError(f"@register_policy({name!r}) needs a SchedulingPolicy "
                            f"subclass, got {cls!r}")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_policy(name: str) -> type[SchedulingPolicy]:
    """Look up a registered policy class by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownPolicyError(
            f"unknown policy {name!r}; registered: {sorted(_REGISTRY)}") from None


def list_policies() -> list[str]:
    """Sorted names of every registered policy."""
    return sorted(_REGISTRY)
